//! Parallel-engine acceptance: multi-site runs on the
//! conservative-lookahead engine must be (a) bit-for-bit identical at
//! every thread count and (b) actually faster with threads where cores
//! exist.
//!
//! Determinism is the non-negotiable half: per-site worlds are seeded
//! independently of thread scheduling, inter-site messages carry
//! sender-derived ordering keys, and per-site metrics merge in fixed
//! site order — so the merged outcome checksum cannot depend on
//! `sim.threads`. The speedup half mirrors `shard_scaling.rs`:
//! best-of-3 to damp scheduler noise, ratio assert gated on visible
//! parallelism, everything else asserted unconditionally.

use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::sim::{SimDriver, SimWorkloadSpec};
use datadiffusion::driver::RunOutcome;
use datadiffusion::index::IndexBackend;
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::object::{Catalog, ObjectId};
use datadiffusion::util::units::MB;

/// An elastic 4-site config: pools churn (allocate and release
/// mid-run), so the equivalence check covers provisioner ticks,
/// executor joins/leases, and directory purges — not just the steady
/// state.
fn churn_cfg(nodes: usize, backend: IndexBackend) -> Config {
    let mut cfg = Config::with_nodes(nodes);
    cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
    cfg.index.backend = backend;
    cfg.split_into_sites(4);
    cfg.federation.skew = 0.0; // origins uniform: real cross-site traffic
    cfg.provisioner.enabled = true;
    cfg.provisioner.policy = datadiffusion::provisioner::AllocationPolicy::Adaptive;
    cfg.provisioner.min_executors = 1;
    cfg.provisioner.max_executors = nodes;
    cfg.provisioner.allocation_latency_s = 20.0;
    cfg.provisioner.idle_release_s = 15.0;
    cfg.provisioner.poll_interval_s = 2.0;
    cfg.provisioner.queue_per_executor = 2;
    cfg
}

fn churn_run(backend: IndexBackend, threads: usize) -> RunOutcome {
    let nodes = 16;
    let mut cfg = churn_cfg(nodes, backend);
    cfg.sim.threads = threads;
    let mut catalog = Catalog::new();
    for i in 0..nodes {
        catalog.insert(ObjectId(i as u64), 4 * MB);
    }
    // Bursty enough to grow the pools, spaced enough to shrink them.
    let tasks: Vec<(f64, Task)> = (0..400)
        .map(|i| {
            let burst = (i / 50) as f64 * 60.0;
            (
                burst + (i % 50) as f64 * 0.05,
                Task::with_inputs(TaskId(i), vec![ObjectId(i % nodes as u64)]),
            )
        })
        .collect();
    let spec = SimWorkloadSpec::new(tasks);
    SimDriver::new(cfg, spec, catalog).run()
}

fn assert_identical(a: &RunOutcome, b: &RunOutcome, label: &str) {
    assert_eq!(
        a.metrics.checksum(),
        b.metrics.checksum(),
        "{label}: outcome checksum must be thread-count invariant"
    );
    assert_eq!(a.events, b.events, "{label}: event counts must match");
    assert_eq!(
        a.makespan_s.to_bits(),
        b.makespan_s.to_bits(),
        "{label}: makespan must match bit-for-bit"
    );
}

#[test]
fn outcomes_identical_across_thread_counts_central() {
    let serial = churn_run(IndexBackend::Central, 1);
    assert_eq!(serial.metrics.tasks_done, 400, "run must drain");
    assert!(serial.metrics.executors_joined > 0, "pools must churn");
    for threads in [2, 4] {
        let par = churn_run(IndexBackend::Central, threads);
        assert_identical(&serial, &par, &format!("central, threads={threads}"));
    }
}

#[test]
fn outcomes_identical_across_thread_counts_chord() {
    let serial = churn_run(IndexBackend::Chord, 1);
    assert_eq!(serial.metrics.tasks_done, 400, "run must drain");
    assert!(
        serial.metrics.stabilization_msgs > 0,
        "chord joins must stabilize"
    );
    for threads in [2, 4] {
        let par = churn_run(IndexBackend::Chord, threads);
        assert_identical(&serial, &par, &format!("chord, threads={threads}"));
    }
}

/// A site-parallel workload: every input prewarmed at its home
/// executor, affinity placement keeping tasks at the caching site —
/// the four site worlds run nearly independent event streams, which is
/// the shape the window-barrier protocol must turn into wall-clock.
fn parallel_run(threads: usize) -> (RunOutcome, f64) {
    let nodes = 32;
    let tasks = 20_000u64;
    let mut cfg = Config::with_nodes(nodes);
    cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
    cfg.split_into_sites(4);
    cfg.federation.skew = 0.0;
    cfg.sim.threads = threads;
    let mut catalog = Catalog::new();
    for e in 0..nodes {
        catalog.insert(ObjectId(e as u64), MB);
    }
    let task_list: Vec<(f64, Task)> = (0..tasks)
        .map(|i| {
            (
                i as f64 * 0.0005,
                Task::with_inputs(TaskId(i), vec![ObjectId(i % nodes as u64)]),
            )
        })
        .collect();
    let mut spec = SimWorkloadSpec::new(task_list);
    spec.prewarm = (0..nodes).map(|e| (e, ObjectId(e as u64))).collect();
    let t0 = std::time::Instant::now();
    let out = SimDriver::new(cfg, spec, catalog).run();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    (out, wall)
}

#[test]
fn four_threads_speed_up_four_sites() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Best-of-3 damps scheduler noise on shared runners; the outcome
    // itself is deterministic, only the wall clock varies.
    let mut best = 0.0f64;
    for _ in 0..3 {
        let (serial, serial_wall) = parallel_run(1);
        let (par, par_wall) = parallel_run(4);
        assert_eq!(serial.metrics.tasks_done, 20_000, "threads=1 must drain");
        assert_identical(&serial, &par, "speedup workload");
        best = best.max(serial_wall / par_wall.max(1e-9));
    }
    if cores < 4 {
        eprintln!("skipping parallel-engine ratio assert: only {cores} cores visible");
        return;
    }
    assert!(
        best >= 2.0,
        "threads=4 must at least double threads=1 on the 4-site \
         site-local workload, got {best:.2}x over 3 attempts"
    );
}
