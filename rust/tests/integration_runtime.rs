//! Integration: the PJRT runtime against the AOT artifacts and the
//! python-produced golden fixture.
//!
//! Requires `make artifacts` to have run (skips, loudly, otherwise —
//! CI and `make test` always build artifacts first).

use datadiffusion::runtime::{artifacts_dir, Manifest, PjrtEngine, StackRequest};

fn engine_or_skip() -> Option<PjrtEngine> {
    match PjrtEngine::load(&artifacts_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn parse_golden() -> Option<(StackRequest, Vec<f64>, (usize, usize, usize))> {
    let path = artifacts_dir().join("golden_stack.tsv");
    let text = std::fs::read_to_string(path).ok()?;
    let mut fields = std::collections::HashMap::new();
    let mut shape = (0usize, 0usize, 0usize);
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let (name, rest) = line.split_once('\t')?;
        if name == "shape" {
            let v: Vec<usize> = rest.split_whitespace().map(|s| s.parse().unwrap()).collect();
            shape = (v[0], v[1], v[2]);
        } else {
            let vals: Vec<f64> = rest
                .split_whitespace()
                .map(|s| s.parse().unwrap())
                .collect();
            fields.insert(name.to_string(), vals);
        }
    }
    let req = StackRequest {
        raw: fields["raw"].iter().map(|&v| v as i16).collect(),
        sky: fields["sky"].iter().map(|&v| v as f32).collect(),
        cal: fields["cal"].iter().map(|&v| v as f32).collect(),
        shifts: fields["shifts"].iter().map(|&v| v as f32).collect(),
        weights: fields["weights"].iter().map(|&v| v as f32).collect(),
        depth: shape.0,
    };
    Some((req, fields.remove("output")?, shape))
}

#[test]
fn manifest_covers_table2_stack_depths() {
    let Ok(m) = Manifest::load(&artifacts_dir()) else {
        eprintln!("SKIP (run `make artifacts`)");
        return;
    };
    // Must cover depth 30 (Table 2's max locality) via some variant.
    let v = m.stack_variant(30).expect("variant for depth 30");
    assert!(v.params["n"] >= 30);
    assert!(m.of_kind("radec2xy").count() >= 1);
}

#[test]
fn pjrt_matches_python_oracle_golden() {
    let Some(engine) = engine_or_skip() else { return };
    let (req, want, (_, h, w)) = parse_golden().expect("golden fixture present");
    let got = engine.stack(&req).expect("pjrt execution");
    assert_eq!(got.len(), h * w);
    let mut max_err = 0.0f64;
    for (a, b) in got.iter().zip(&want) {
        max_err = max_err.max((*a as f64 - b).abs());
    }
    // Raw pixels are O(4096); 1e-2 absolute is ~1e-6 relative.
    assert!(max_err < 1e-2, "max |pjrt - oracle| = {max_err}");
}

#[test]
fn padding_is_exact_across_variants() {
    // depth-d request must produce identical output through any variant
    // that fits it (padding with zero weights is semantically inert).
    let Some(engine) = engine_or_skip() else { return };
    let (mut req, _, _) = parse_golden().expect("golden fixture");
    // Run the same request at its native depth (variant n=4) and as a
    // padded request (forced into a larger variant by raising depth
    // metadata is not possible directly; instead re-stack with depth
    // increased by appending explicit zero-weight slots).
    let base = engine.stack(&req).expect("base");
    let (_, h, w) = (req.depth, 100, 100);
    let px = h * w;
    req.raw.extend(std::iter::repeat(0i16).take(px * 8));
    req.sky.extend([0.0; 8]);
    req.cal.extend([0.0; 8]);
    req.shifts.extend([0.0; 16]);
    req.weights.extend([0.0; 8]);
    req.depth += 8; // now needs the n=16 variant
    let padded = engine.stack(&req).expect("padded");
    let mut max_err = 0.0f64;
    for (a, b) in base.iter().zip(&padded) {
        max_err = max_err.max((a - b).abs() as f64);
    }
    assert!(max_err < 1e-3, "padding changed the result by {max_err}");
}

#[test]
fn radec2xy_matches_gnomonic_reference() {
    let Some(engine) = engine_or_skip() else { return };
    // Gnomonic projection reference computed in Rust (same math as the
    // python oracle radec2xy_ref).
    let gnomonic = |ra: f64, dec: f64, ra0: f64, dec0: f64, s: f64| {
        let cos_c =
            dec0.sin() * dec.sin() + dec0.cos() * dec.cos() * (ra - ra0).cos();
        let x = dec.cos() * (ra - ra0).sin() / cos_c;
        let y = (dec0.cos() * dec.sin() - dec0.sin() * dec.cos() * (ra - ra0).cos()) / cos_c;
        (x * s, y * s)
    };
    let (ra0, dec0, scale) = (0.15f32, 0.05f32, 1.0e4f32);
    // 200 points: exercises chunking (artifact batch m=128) and padding.
    let ra: Vec<f32> = (0..200).map(|i| 0.001 * i as f32).collect();
    let dec: Vec<f32> = (0..200).map(|i| -0.1 + 0.001 * i as f32).collect();
    let xy = engine.radec2xy(&ra, &dec, ra0, dec0, scale).expect("radec2xy");
    assert_eq!(xy.len(), 200);
    for i in [0usize, 1, 64, 127, 128, 199] {
        let (ex, ey) = gnomonic(
            ra[i] as f64,
            dec[i] as f64,
            ra0 as f64,
            dec0 as f64,
            scale as f64,
        );
        assert!(
            (xy[i].0 as f64 - ex).abs() < 0.05 && (xy[i].1 as f64 - ey).abs() < 0.05,
            "point {i}: got {:?}, want ({ex}, {ey})",
            xy[i]
        );
    }
    // Tangent point maps to the origin.
    let o = engine.radec2xy(&[ra0], &[dec0], ra0, dec0, scale).unwrap();
    assert!(o[0].0.abs() < 1e-2 && o[0].1.abs() < 1e-2);
}

#[test]
fn rejects_malformed_requests() {
    let Some(engine) = engine_or_skip() else { return };
    let bad = StackRequest {
        raw: vec![0; 10],
        sky: vec![0.0],
        cal: vec![1.0],
        shifts: vec![0.0, 0.0],
        weights: vec![1.0],
        depth: 1,
    };
    assert!(engine.stack(&bad).is_err(), "shape mismatch must error");
    let zero = StackRequest {
        raw: vec![],
        sky: vec![],
        cal: vec![],
        shifts: vec![],
        weights: vec![],
        depth: 0,
    };
    assert!(engine.stack(&zero).is_err(), "depth 0 must error");
}

#[test]
fn stack_throughput_sanity() {
    // The request path must be fast enough that compute never dominates
    // the simulated I/O times (paper: compute <1ms + radec2xy; our CPU
    // interpret-mode kernel is slower but must stay well under the
    // ~100ms-scale I/O costs it is paired with).
    let Some(engine) = engine_or_skip() else { return };
    let (req, _, _) = parse_golden().expect("golden fixture");
    let t0 = std::time::Instant::now();
    let iters = 20;
    for _ in 0..iters {
        engine.stack(&req).expect("stack");
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    eprintln!("stack: {:.2} ms/op", per * 1e3);
    assert!(per < 0.25, "stacking took {per:.3}s/op — request path too slow");
}
