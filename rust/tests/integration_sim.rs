//! Integration: simulated end-to-end experiments at paper scale.
//!
//! These exercise the full pipeline — workload generator → dispatcher
//! core → flow-network testbed → metrics — and pin the paper's headline
//! *shapes* (who wins, roughly by how much, where crossovers fall).

use datadiffusion::analysis::figures::{run_stacking, StackConfig};
use datadiffusion::analysis::model;
use datadiffusion::config::Config;
use datadiffusion::driver::sim::SimDriver;
use datadiffusion::util::units::{gbps, MB};
use datadiffusion::workloads::astro;
use datadiffusion::workloads::microbench::{generate, MbConfig};

#[test]
fn microbench_gpfs_saturates_dd_scales() {
    // Fig 3's core contrast at 64 nodes, 100 MB files.
    let gpfs = {
        let e = generate(MbConfig::FirstAvailable, 64, 100 * MB, false, 4);
        SimDriver::new(e.config, e.spec, e.catalog).run()
    };
    let dd = {
        let e = generate(MbConfig::MaxComputeUtil100, 64, 100 * MB, false, 4);
        SimDriver::new(e.config, e.spec, e.catalog).run()
    };
    let gpfs_bps = gpfs.metrics.read_throughput_bps();
    let dd_bps = dd.metrics.read_throughput_bps();
    assert!(
        gpfs_bps < gbps(3.6),
        "GPFS must not exceed its aggregate cap: {gpfs_bps}"
    );
    assert!(
        dd_bps > 3.0 * gpfs_bps,
        "warm data diffusion must beat GPFS by a wide margin: {dd_bps} vs {gpfs_bps}"
    );
    // DD@100% should land near the local-disk envelope.
    let ideal = model::local_disk_read_bps(&Config::with_nodes(64), 64, 100 * MB);
    assert!(
        dd_bps > 0.6 * ideal,
        "DD@100% well below ideal: {dd_bps} vs {ideal}"
    );
}

#[test]
fn microbench_read_write_shape() {
    // Fig 4: GPFS r+w ~1.1 Gb/s; warm DD r+w far above it.
    let gpfs = {
        let e = generate(MbConfig::FirstAvailable, 64, 100 * MB, true, 4);
        SimDriver::new(e.config, e.spec, e.catalog).run()
    };
    let dd = {
        let e = generate(MbConfig::MaxComputeUtil100, 64, 100 * MB, true, 4);
        SimDriver::new(e.config, e.spec, e.catalog).run()
    };
    let gpfs_bps = gpfs.metrics.rw_throughput_bps();
    assert!(
        gpfs_bps < gbps(1.5),
        "GPFS r+w must sit near the paper's 1.1 Gb/s: {gpfs_bps}"
    );
    assert!(dd.metrics.rw_throughput_bps() > 5.0 * gpfs_bps);
}

#[test]
fn wrapper_caps_small_file_task_rate() {
    // Fig 5: the sandbox wrapper serializes on shared metadata and caps
    // around the paper's ~21 tasks/s at 64 nodes on tiny files.
    let e = generate(MbConfig::FirstAvailableWrapper, 64, 1, false, 4);
    let out = SimDriver::new(e.config, e.spec, e.catalog).run();
    let rate = out.metrics.task_rate();
    assert!(
        (10.0..40.0).contains(&rate),
        "wrapper rate {rate} not near the paper's ~21 tasks/s"
    );
    // No-wrapper is an order of magnitude faster.
    let e = generate(MbConfig::FirstAvailable, 64, 1, false, 4);
    let plain = SimDriver::new(e.config, e.spec, e.catalog).run();
    assert!(plain.metrics.task_rate() > 5.0 * rate);
}

#[test]
fn stacking_hit_ratio_within_90pct_of_ideal() {
    // Fig 10 at a meaningful scale: locality 10 (ideal 90%).
    let row = astro::row_for_locality(10.0);
    let out = run_stacking(128, row, StackConfig::DiffusionGz, 0.25, 7);
    let ideal = astro::ideal_hit_ratio(row.locality);
    let got = out.metrics.local_hit_ratio();
    assert!(
        got >= 0.85 * ideal,
        "hit ratio {got} below 85% of ideal {ideal}"
    );
}

#[test]
fn stacking_gpfs_load_collapses_with_locality() {
    // Fig 13: GPFS bytes per stack shrink ~linearly in locality.
    let lo = run_stacking(
        128,
        astro::row_for_locality(1.0),
        StackConfig::DiffusionGz,
        0.05,
        7,
    );
    let hi = run_stacking(
        128,
        astro::row_for_locality(30.0),
        StackConfig::DiffusionGz,
        0.25,
        7,
    );
    let per_lo = lo.metrics.gpfs_bytes as f64 / lo.metrics.tasks_done as f64;
    let per_hi = hi.metrics.gpfs_bytes as f64 / hi.metrics.tasks_done as f64;
    assert!(
        per_lo > 10.0 * per_hi,
        "GPFS bytes/stack should collapse: {per_lo} -> {per_hi}"
    );
}

#[test]
fn all_policies_complete_all_tasks() {
    use datadiffusion::coordinator::task::{Task, TaskId};
    use datadiffusion::driver::sim::SimWorkloadSpec;
    use datadiffusion::scheduler::DispatchPolicy;
    use datadiffusion::storage::object::{Catalog, ObjectId};

    for policy in [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::FirstCacheAvailable,
        DispatchPolicy::MaxCacheHit,
        DispatchPolicy::MaxComputeUtil,
    ] {
        let mut cfg = Config::with_nodes(8);
        cfg.scheduler.policy = policy;
        let mut catalog = Catalog::new();
        for i in 0..64 {
            catalog.insert(ObjectId(i % 16), MB);
        }
        let tasks: Vec<(f64, Task)> = (0..200)
            .map(|i| (0.0, Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)])))
            .collect();
        let mut spec = SimWorkloadSpec::new(tasks);
        spec.caching = policy.is_data_aware();
        let out = SimDriver::new(cfg, spec, catalog).run();
        assert_eq!(
            out.metrics.tasks_done, 200,
            "{policy:?} lost tasks"
        );
        assert_eq!(out.metrics.tasks_dispatched, 200);
    }
}

#[test]
fn deterministic_across_runs() {
    let run = || {
        run_stacking(
            64,
            astro::row_for_locality(5.0),
            StackConfig::DiffusionGz,
            0.02,
            99,
        )
    };
    let (a, b) = (run(), run());
    assert_eq!(a.metrics.tasks_done, b.metrics.tasks_done);
    assert_eq!(a.metrics.gpfs_bytes, b.metrics.gpfs_bytes);
    assert!((a.makespan_s - b.makespan_s).abs() < 1e-9);
}
