//! Shard-scaling acceptance: the sharded, batched dispatch core must
//! turn shard count into dispatch throughput. The required ratio
//! (shards=4 at least doubling shards=1 on a bursty drain) only makes
//! sense where four dispatcher threads can actually run, so the ratio
//! assert is gated on visible parallelism; everything else — full
//! retirement, identical workload across shard counts, batch and steal
//! accounting — is asserted unconditionally.

use datadiffusion::analysis::figures;

#[test]
fn sharded_dispatch_scales_on_bursty_drain() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Best-of-3 damps scheduler noise on shared runners; the workload
    // itself is deterministic, only the wall clock varies.
    let mut best = 0.0f64;
    for _ in 0..3 {
        let rows = figures::fig_shard_scaling(&[1, 4], 16_384, 32);
        assert_eq!(rows.len(), 2);
        let (one, four) = (&rows[0], &rows[1]);
        assert_eq!(one.tasks, 16_384, "shards=1 must retire the whole workload");
        assert_eq!(one.tasks, four.tasks, "same workload at both shard counts");
        assert_eq!(one.steals, 0, "one shard has nobody to steal from");
        assert!(one.batches > 0 && four.batches > 0, "batches must be accounted");
        best = best.max(four.tasks_per_s / one.tasks_per_s.max(1e-12));
    }
    if cores < 4 {
        eprintln!("skipping shard-scaling ratio assert: only {cores} cores visible");
        return;
    }
    assert!(
        best >= 2.0,
        "shards=4 must at least double shards=1 dispatch throughput on the \
         bursty drain, got {best:.2}x over 3 attempts"
    );
}
