//! Property-based invariant tests (hand-rolled generators over the
//! crate's deterministic PRNG — `proptest` is unavailable offline).
//!
//! Each property runs many randomized cases; failures print the seed so
//! a case can be replayed exactly. The case count defaults to 50 and is
//! overridable via `PROPTEST_CASES` (the nightly CI job runs 2048 for
//! deep fuzzing without slowing PR builds).

use datadiffusion::cache::store::{CacheEvent, DataCache};
use datadiffusion::cache::EvictionPolicy;
use datadiffusion::config::SchedulerConfig;
use datadiffusion::coordinator::core::FalkonCore;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::index::central::CentralIndex;
use datadiffusion::index::dht::DhtModel;
use datadiffusion::index::{ChordIndex, DataIndex};
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::sim::flownet::{FlowNetwork, FlowSpec, ResourceId};
use datadiffusion::storage::object::{Catalog, ObjectId};
use datadiffusion::util::rng::Rng;

/// Randomized cases per property: `PROPTEST_CASES` env override, else 50.
fn cases() -> u64 {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(50)
}

/// Cache invariants under random op sequences, all four policies:
/// capacity respected; hit+miss accounting conserved; every eviction
/// event names a previously-resident object; contents consistent.
#[test]
fn prop_cache_invariants() {
    for policy in [
        EvictionPolicy::Random,
        EvictionPolicy::Fifo,
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
    ] {
        for case in 0..cases() {
            let seed = 0xCAFE + case;
            let mut rng = Rng::new(seed);
            let capacity = rng.range_u64(10, 200);
            let mut cache = DataCache::new(capacity, policy, seed);
            let mut resident: std::collections::HashSet<ObjectId> =
                std::collections::HashSet::new();
            let mut accesses = 0u64;
            for _ in 0..300 {
                let obj = ObjectId(rng.below(40));
                match rng.below(3) {
                    0 => {
                        accesses += 1;
                        let hit = cache.access(obj);
                        assert_eq!(
                            hit,
                            resident.contains(&obj),
                            "[{policy:?} seed={seed}] access disagreed with model"
                        );
                    }
                    1 => {
                        let bytes = rng.range_u64(1, capacity / 2 + 1);
                        for ev in cache.insert(obj, bytes) {
                            match ev {
                                CacheEvent::Evicted(v) => {
                                    assert!(
                                        resident.remove(&v),
                                        "[{policy:?} seed={seed}] evicted non-resident {v}"
                                    );
                                }
                                CacheEvent::Inserted(v) => {
                                    resident.insert(v);
                                }
                            }
                        }
                    }
                    _ => {
                        cache.remove(obj);
                        resident.remove(&obj);
                    }
                }
                assert!(
                    cache.used_bytes() <= capacity,
                    "[{policy:?} seed={seed}] over capacity"
                );
                assert_eq!(
                    cache.len(),
                    resident.len(),
                    "[{policy:?} seed={seed}] resident-set drift"
                );
            }
            let (h, m, _) = cache.stats();
            assert_eq!(h + m, accesses, "[{policy:?} seed={seed}] hit+miss != accesses");
        }
    }
}

/// Index invariant: after any op sequence the central index equals an
/// independently maintained model map, and `drop_executor` orphans
/// exactly the objects whose only copy it held.
#[test]
fn prop_index_matches_model() {
    use std::collections::{BTreeMap, BTreeSet};
    for case in 0..cases() {
        let seed = 0xBEEF + case;
        let mut rng = Rng::new(seed);
        let mut idx = CentralIndex::new();
        let mut model: BTreeMap<ObjectId, BTreeSet<usize>> = BTreeMap::new();
        for _ in 0..400 {
            let obj = ObjectId(rng.below(30));
            let exec = rng.index(8);
            match rng.below(3) {
                0 => {
                    idx.insert(obj, exec);
                    model.entry(obj).or_default().insert(exec);
                }
                1 => {
                    idx.remove(obj, exec);
                    if let Some(s) = model.get_mut(&obj) {
                        s.remove(&exec);
                        if s.is_empty() {
                            model.remove(&obj);
                        }
                    }
                }
                _ => {
                    let orphans: BTreeSet<ObjectId> =
                        idx.drop_executor(exec).into_iter().collect();
                    let mut expect = BTreeSet::new();
                    model.retain(|o, s| {
                        s.remove(&exec);
                        if s.is_empty() {
                            expect.insert(*o);
                            false
                        } else {
                            true
                        }
                    });
                    assert_eq!(orphans, expect, "seed={seed} orphan mismatch");
                }
            }
            for (o, s) in &model {
                let locs: BTreeSet<usize> = idx.locations(*o).iter().copied().collect();
                assert_eq!(&locs, s, "seed={seed} locations mismatch for {o}");
            }
            assert_eq!(idx.len(), model.len(), "seed={seed} len mismatch");
        }
    }
}

/// Dispatcher invariant: under random submissions, completions and
/// executor churn, every submitted task is dispatched exactly once —
/// none lost, none duplicated — for every policy.
#[test]
fn prop_no_task_lost_or_duplicated() {
    use std::collections::HashMap;
    for policy in [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::FirstCacheAvailable,
        DispatchPolicy::MaxCacheHit,
        DispatchPolicy::MaxComputeUtil,
    ] {
        for case in 0..cases() {
            let seed = 0xD15C + case;
            let mut rng = Rng::new(seed);
            let mut catalog = Catalog::new();
            for i in 0..20 {
                catalog.insert(ObjectId(i), 10);
            }
            let cfg = SchedulerConfig {
                policy,
                ..SchedulerConfig::default()
            };
            let mut core = FalkonCore::new(&cfg, catalog);
            // Executors 0..4 exist initially; may churn.
            let mut live: Vec<usize> = (0..4).collect();
            for &e in &live {
                core.register_executor(e);
            }
            let mut next_exec = 4usize;
            let mut submitted = 0u64;
            let mut dispatched: HashMap<TaskId, u32> = HashMap::new();
            let mut running: Vec<(usize, TaskId, ObjectId)> = Vec::new();

            for step in 0..300 {
                match rng.below(10) {
                    0..=4 => {
                        let t = Task::with_inputs(
                            TaskId(submitted),
                            vec![ObjectId(rng.below(20))],
                        );
                        submitted += 1;
                        core.submit(t);
                    }
                    5..=7 => {
                        if !running.is_empty() {
                            let (e, id, obj) = running.swap_remove(rng.index(running.len()));
                            core.on_task_complete(e, id, &[CacheEvent::Inserted(obj)]);
                        }
                    }
                    8 => {
                        // Churn: kill a random executor (its running tasks
                        // are "completed" first — crash-free model).
                        if live.len() > 1 {
                            let e = live.swap_remove(rng.index(live.len()));
                            let mut keep = Vec::new();
                            for (re, id, obj) in running.drain(..) {
                                if re == e {
                                    core.on_task_complete(re, id, &[]);
                                    let _ = obj;
                                } else {
                                    keep.push((re, id, obj));
                                }
                            }
                            running = keep;
                            core.deregister_executor(e);
                        }
                    }
                    _ => {
                        live.push(next_exec);
                        core.register_executor(next_exec);
                        next_exec += 1;
                    }
                }
                for o in core.try_dispatch() {
                    *dispatched.entry(o.task.id).or_insert(0) += 1;
                    running.push((o.executor, o.task.id, o.task.inputs[0]));
                    assert!(
                        live.contains(&o.executor),
                        "[{policy:?} seed={seed} step={step}] dispatched to dead executor"
                    );
                }
            }
            // Drain: complete everything, keep dispatching until quiet.
            let mut guard = 0;
            while (!running.is_empty() || core.queue_len() > 0) && guard < 10_000 {
                guard += 1;
                if let Some((e, id, obj)) = running.pop() {
                    core.on_task_complete(e, id, &[CacheEvent::Inserted(obj)]);
                }
                for o in core.try_dispatch() {
                    *dispatched.entry(o.task.id).or_insert(0) += 1;
                    running.push((o.executor, o.task.id, o.task.inputs[0]));
                }
            }
            assert!(guard < 10_000, "[{policy:?} seed={seed}] drain did not quiesce");
            assert_eq!(
                dispatched.len() as u64,
                submitted,
                "[{policy:?} seed={seed}] lost tasks"
            );
            assert!(
                dispatched.values().all(|&c| c == 1),
                "[{policy:?} seed={seed}] duplicated dispatch"
            );
        }
    }
}

/// Sharding equivalence (the refactor's safety rail): a `ShardedCore`
/// at shards=1 must reproduce the single-loop `FalkonCore`'s dispatch
/// orders exactly — the same tasks to the same executors in the same
/// order — under random interleavings of submission, completion and
/// executor churn, for all four policies on both index backends.
#[test]
fn prop_sharded_equivalence() {
    use datadiffusion::config::IndexConfig;
    use datadiffusion::coordinator::sharded::ShardedCore;
    use datadiffusion::index::IndexBackend;

    for policy in [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::FirstCacheAvailable,
        DispatchPolicy::MaxCacheHit,
        DispatchPolicy::MaxComputeUtil,
    ] {
        for backend in [IndexBackend::Central, IndexBackend::Chord] {
            for case in 0..cases() {
                let seed = 0x54A2D + case;
                let mut rng = Rng::new(seed);
                let mut catalog = Catalog::new();
                for i in 0..20 {
                    catalog.insert(ObjectId(i), rng.range_u64(1, 100));
                }
                let cfg = SchedulerConfig {
                    policy,
                    ..SchedulerConfig::default()
                };
                let index_cfg = IndexConfig {
                    backend,
                    ..IndexConfig::default()
                };
                let mut mono = FalkonCore::with_index(
                    &cfg,
                    catalog.clone(),
                    datadiffusion::index::build(&index_cfg, seed),
                );
                let mut sharded = ShardedCore::with_indexes(
                    &cfg,
                    catalog,
                    vec![datadiffusion::index::build(&index_cfg, seed)],
                );
                let mut live: Vec<usize> = (0..4).collect();
                for &e in &live {
                    mono.register_executor(e);
                    sharded.register_executor(e);
                }
                let mut next_exec = 4usize;
                let mut submitted = 0u64;
                let mut running: Vec<(usize, TaskId, ObjectId)> = Vec::new();

                for step in 0..200 {
                    match rng.below(10) {
                        0..=4 => {
                            let inputs = vec![ObjectId(rng.below(20))];
                            mono.submit(Task::with_inputs(TaskId(submitted), inputs.clone()));
                            sharded.submit(Task::with_inputs(TaskId(submitted), inputs));
                            submitted += 1;
                        }
                        5..=7 => {
                            if !running.is_empty() {
                                let (e, id, obj) = running.swap_remove(rng.index(running.len()));
                                let ev = [CacheEvent::Inserted(obj)];
                                mono.on_task_complete(e, id, &ev);
                                sharded.on_task_complete(e, id, &ev);
                            }
                        }
                        8 => {
                            if live.len() > 1 {
                                let e = live.swap_remove(rng.index(live.len()));
                                let mut keep = Vec::new();
                                for (re, id, obj) in running.drain(..) {
                                    if re == e {
                                        mono.on_task_complete(re, id, &[]);
                                        sharded.on_task_complete(re, id, &[]);
                                        let _ = obj;
                                    } else {
                                        keep.push((re, id, obj));
                                    }
                                }
                                running = keep;
                                mono.deregister_executor(e);
                                sharded.deregister_executor(e);
                            }
                        }
                        _ => {
                            live.push(next_exec);
                            mono.register_executor(next_exec);
                            sharded.register_executor(next_exec);
                            next_exec += 1;
                        }
                    }
                    let a = mono.try_dispatch();
                    let b = sharded.try_dispatch();
                    assert_eq!(
                        a.len(),
                        b.len(),
                        "[{policy:?} {backend:?} seed={seed} step={step}] batch size diverged"
                    );
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(
                            (x.executor, x.task.id),
                            (y.executor, y.task.id),
                            "[{policy:?} {backend:?} seed={seed} step={step}] orders diverged"
                        );
                    }
                    for o in a {
                        running.push((o.executor, o.task.id, o.task.inputs[0]));
                    }
                }
                // Drain both in lockstep; the streams must stay identical
                // to the very last order.
                let mut guard = 0;
                while (!running.is_empty() || mono.queue_len() > 0) && guard < 10_000 {
                    guard += 1;
                    if let Some((e, id, obj)) = running.pop() {
                        let ev = [CacheEvent::Inserted(obj)];
                        mono.on_task_complete(e, id, &ev);
                        sharded.on_task_complete(e, id, &ev);
                    }
                    let a = mono.try_dispatch();
                    let b = sharded.try_dispatch();
                    assert_eq!(
                        a.len(),
                        b.len(),
                        "[{policy:?} {backend:?} seed={seed}] drain diverged"
                    );
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(
                            (x.executor, x.task.id),
                            (y.executor, y.task.id),
                            "[{policy:?} {backend:?} seed={seed}] drain orders diverged"
                        );
                    }
                    for o in a {
                        running.push((o.executor, o.task.id, o.task.inputs[0]));
                    }
                }
                assert!(guard < 10_000, "[{policy:?} {backend:?} seed={seed}] no quiesce");
                assert_eq!(mono.queue_len(), sharded.queue_len(), "residual queue drift");
            }
        }
    }
}

/// Backend invariant (the `DataIndex` contract): with the Chord cost
/// model zeroed, all four dispatch policies return byte-identical
/// `Decision`s over a `CentralIndex` and a `ChordIndex` that saw the
/// same update history — the backend may change lookup *cost* but never
/// *placement*.
#[test]
fn prop_backends_agree_on_placement() {
    use datadiffusion::scheduler::decision::SchedView;
    const N_EXEC: usize = 8;
    const N_OBJ: u64 = 16;
    let zero_cost = DhtModel {
        hop_latency_s: 0.0,
        proc_s: 0.0,
    };
    for case in 0..cases() * 2 {
        let seed = 0xC02D + case;
        let mut rng = Rng::new(seed);
        let mut central = CentralIndex::new();
        let mut chord = ChordIndex::with_nodes(N_EXEC, zero_cost, seed);
        let mut catalog = Catalog::new();
        for i in 0..N_OBJ {
            catalog.insert(ObjectId(i), rng.range_u64(1, 100));
        }
        // Mirror a random update history into both backends.
        for _ in 0..80 {
            let obj = ObjectId(rng.below(N_OBJ));
            let exec = rng.index(N_EXEC);
            match rng.below(4) {
                0..=2 => {
                    central.insert(obj, exec);
                    DataIndex::insert(&mut chord, obj, exec);
                }
                _ => {
                    central.remove(obj, exec);
                    DataIndex::remove(&mut chord, obj, exec);
                }
            }
        }
        // Random idle subset of a full executor set.
        let all: Vec<usize> = (0..N_EXEC).collect();
        let mut idle: Vec<usize> = all
            .iter()
            .copied()
            .filter(|_| rng.next_f64() < 0.5)
            .collect();
        if idle.is_empty() {
            idle.push(rng.index(N_EXEC));
        }
        idle.sort_unstable();
        let task = Task::with_inputs(
            TaskId(0),
            (0..rng.range_u64(1, 4))
                .map(|_| ObjectId(rng.below(N_OBJ)))
                .collect(),
        );
        for policy in [
            DispatchPolicy::FirstAvailable,
            DispatchPolicy::FirstCacheAvailable,
            DispatchPolicy::MaxCacheHit,
            DispatchPolicy::MaxComputeUtil,
        ] {
            let central_view = SchedView {
                idle: &idle,
                all: &all,
                index: &central,
                catalog: &catalog,
            };
            let chord_view = SchedView {
                idle: &idle,
                all: &all,
                index: &chord,
                catalog: &catalog,
            };
            assert_eq!(
                policy.decide(&task, &central_view),
                policy.decide(&task, &chord_view),
                "[{policy:?} seed={seed}] backends disagreed on placement"
            );
        }
        // And the zeroed model really is free (cost ≠ placement).
        for &obj in &task.inputs {
            let c = chord.lookup_cost(obj);
            assert_eq!(c.latency_s, 0.0, "seed={seed}: zeroed model charged time");
            assert_eq!(c.lookups, 1);
        }
    }
}

/// Churn invariant: after an arbitrary interleaving of executor
/// join/leave and cache insert/evict, mirrored into a `CentralIndex` and
/// a `ChordIndex`, (a) both backends agree on `locations()` for every
/// object, and (b) no location references a deregistered executor — the
/// elastic-pool contract the provisioner relies on (hints must never
/// target a node whose lease was released).
#[test]
fn prop_churn_backends_agree_and_no_dangling_locations() {
    use std::collections::BTreeSet;
    const N_OBJ: u64 = 24;
    let zero_cost = DhtModel {
        hop_latency_s: 0.0,
        proc_s: 0.0,
    };
    for case in 0..cases() * 2 {
        let seed = 0xC4C5 + case;
        let mut rng = Rng::new(seed);
        let mut central = CentralIndex::new();
        let mut chord = ChordIndex::new(zero_cost, seed);
        let mut live: BTreeSet<usize> = BTreeSet::new();
        let mut next_exec = 0usize;
        for step in 0..400 {
            match rng.below(8) {
                // Join: a newly provisioned executor enters both overlays.
                0..=1 => {
                    let e = next_exec;
                    next_exec += 1;
                    live.insert(e);
                    DataIndex::executor_joined(&mut central, e);
                    DataIndex::executor_joined(&mut chord, e);
                }
                // Leave: a released executor is dropped; both backends
                // must orphan exactly the same objects.
                2 => {
                    if let Some(&e) = live.iter().nth(rng.index(live.len().max(1))) {
                        live.remove(&e);
                        let a: BTreeSet<ObjectId> =
                            central.drop_executor(e).into_iter().collect();
                        let b: BTreeSet<ObjectId> =
                            DataIndex::drop_executor(&mut chord, e).into_iter().collect();
                        assert_eq!(a, b, "seed={seed} step={step}: orphan sets differ");
                    }
                }
                // Insert: a live executor caches an object.
                3..=5 => {
                    if let Some(&e) = live.iter().nth(rng.index(live.len().max(1))) {
                        let obj = ObjectId(rng.below(N_OBJ));
                        DataIndex::insert(&mut central, obj, e);
                        DataIndex::insert(&mut chord, obj, e);
                    }
                }
                // Evict: any executor (live or not — evicting from a
                // departed executor is a no-op on a purged index).
                _ => {
                    let e = rng.index(next_exec.max(1));
                    let obj = ObjectId(rng.below(N_OBJ));
                    DataIndex::remove(&mut central, obj, e);
                    DataIndex::remove(&mut chord, obj, e);
                }
            }
            for i in 0..N_OBJ {
                let obj = ObjectId(i);
                let a = central.locations(obj);
                let b = DataIndex::locations(&chord, obj);
                assert_eq!(a, b, "seed={seed} step={step}: backends disagree on {obj}");
                for &e in a {
                    assert!(
                        live.contains(&e),
                        "seed={seed} step={step}: {obj} references deregistered executor {e}"
                    );
                }
            }
            assert_eq!(
                central.len(),
                DataIndex::len(&chord),
                "seed={seed} step={step}: len drift"
            );
        }
    }
}

/// Replication invariants under churn: a [`ReplicationManager`] driving
/// mirrored Central/Chord indexes through arbitrary interleavings of
/// join/leave, organic first copies, evictions, demand and staging —
/// (a) no object ever exceeds `max_replicas` locations, (b) every
/// directive stages from a live holder to a live non-holder, (c) both
/// backends agree on every location set (so replication decisions, which
/// read the index, are backend-invariant), and (d) no location ever
/// references a departed executor.
#[test]
fn prop_replication_caps_and_liveness_under_churn() {
    use datadiffusion::config::ReplicationConfig;
    use datadiffusion::replication::{PlacementPolicy, ReplicaDirective, ReplicationManager};
    use std::collections::BTreeSet;

    const N_OBJ: u64 = 12;
    let zero = DhtModel {
        hop_latency_s: 0.0,
        proc_s: 0.0,
    };
    let policies = [
        PlacementPolicy::LeastLoaded,
        PlacementPolicy::HashSpread,
        PlacementPolicy::CoLocate,
    ];
    for case in 0..cases() * 2 {
        let seed = 0x4E94 + case;
        let mut rng = Rng::new(seed);
        let max_replicas = rng.range_u64(1, 4) as usize;
        let rcfg = ReplicationConfig {
            enabled: true,
            policy: policies[rng.index(policies.len())],
            max_replicas,
            demand_threshold: 0.5,
            ewma_alpha: 0.7,
            prestage_top_k: 2,
            max_inflight: 6,
            // Half the cases run active teardown too, so drops interleave
            // with staging, churn and demand.
            release_threshold: if rng.below(2) == 0 { 0.25 } else { 0.0 },
            ..ReplicationConfig::default()
        };
        let mut mgr = ReplicationManager::new(rcfg);
        let mut central = CentralIndex::new();
        let mut chord = ChordIndex::new(zero, seed);
        let mut live: BTreeSet<usize> = BTreeSet::new();
        let mut next_exec = 0usize;
        for step in 0..250 {
            match rng.below(10) {
                // Join: both overlays plus the manager's prestage queue.
                0..=1 => {
                    let e = next_exec;
                    next_exec += 1;
                    live.insert(e);
                    DataIndex::executor_joined(&mut central, e);
                    DataIndex::executor_joined(&mut chord, e);
                    mgr.executor_joined(e);
                }
                // Leave: locations purge identically; manager forgets it.
                2 => {
                    if let Some(&e) = live.iter().nth(rng.index(live.len().max(1))) {
                        live.remove(&e);
                        let a: BTreeSet<ObjectId> =
                            central.drop_executor(e).into_iter().collect();
                        let b: BTreeSet<ObjectId> =
                            DataIndex::drop_executor(&mut chord, e).into_iter().collect();
                        assert_eq!(a, b, "seed={seed} step={step}: orphan sets differ");
                        mgr.executor_dropped(e);
                    }
                }
                // Organic first copy (a task's cold fetch): only when the
                // object has no location, so every *additional* copy in
                // this model is manager-created and the cap is meaningful.
                3..=4 => {
                    if let Some(&e) = live.iter().nth(rng.index(live.len().max(1))) {
                        let obj = ObjectId(rng.below(N_OBJ));
                        if central.locations(obj).is_empty() {
                            DataIndex::insert(&mut central, obj, e);
                            DataIndex::insert(&mut chord, obj, e);
                        }
                    }
                }
                // Eviction (any executor, live or departed — no-op then).
                5 => {
                    let e = rng.index(next_exec.max(1));
                    let obj = ObjectId(rng.below(N_OBJ));
                    DataIndex::remove(&mut central, obj, e);
                    DataIndex::remove(&mut chord, obj, e);
                }
                // Demand signals.
                6..=7 => {
                    let obj = ObjectId(rng.below(N_OBJ));
                    for _ in 0..rng.range_u64(1, 5) {
                        mgr.note_lookup(obj);
                    }
                    if let Some(&e) = live.iter().nth(rng.index(live.len().max(1))) {
                        mgr.note_peer_fetch(obj, e);
                    }
                }
                // Evaluate: check every directive, then execute or
                // abandon it.
                _ => {
                    let executors: Vec<usize> = live.iter().copied().collect();
                    for d in mgr.evaluate(&central, &executors) {
                        match d {
                            ReplicaDirective::Stage { obj, src, dst, .. } => {
                                assert!(
                                    live.contains(&src),
                                    "seed={seed} step={step}: src {src} not live"
                                );
                                assert!(
                                    live.contains(&dst),
                                    "seed={seed} step={step}: dst {dst} not live"
                                );
                                assert!(
                                    central.locations(obj).binary_search(&src).is_ok(),
                                    "seed={seed} step={step}: src {src} does not hold {obj}"
                                );
                                assert!(
                                    central.locations(obj).binary_search(&dst).is_err(),
                                    "seed={seed} step={step}: dst {dst} already holds {obj}"
                                );
                                if rng.below(4) > 0 {
                                    DataIndex::insert(&mut central, obj, dst);
                                    DataIndex::insert(&mut chord, obj, dst);
                                }
                                mgr.on_staged(obj, dst);
                            }
                            ReplicaDirective::Drop { obj, victim } => {
                                assert!(
                                    live.contains(&victim),
                                    "seed={seed} step={step}: drop victim {victim} not live"
                                );
                                assert!(
                                    central.locations(obj).binary_search(&victim).is_ok(),
                                    "seed={seed} step={step}: victim {victim} does not hold {obj}"
                                );
                                assert!(
                                    central.locations(obj).len() > 1,
                                    "seed={seed} step={step}: drop would orphan {obj}"
                                );
                                if rng.below(4) > 0 {
                                    DataIndex::remove(&mut central, obj, victim);
                                    DataIndex::remove(&mut chord, obj, victim);
                                }
                                mgr.on_drop_done(obj, victim);
                            }
                        }
                    }
                }
            }
            for i in 0..N_OBJ {
                let obj = ObjectId(i);
                let a = central.locations(obj);
                assert_eq!(
                    a,
                    DataIndex::locations(&chord, obj),
                    "seed={seed} step={step}: backends disagree on {obj}"
                );
                assert!(
                    a.len() <= max_replicas,
                    "seed={seed} step={step}: {obj} has {} locations, cap {max_replicas}",
                    a.len()
                );
                for &e in a {
                    assert!(
                        live.contains(&e),
                        "seed={seed} step={step}: {obj} on departed executor {e}"
                    );
                }
            }
        }
    }
}

/// Scheduler-choice invariant: max-compute-util never picks an idle
/// executor with fewer cached bytes than the best idle candidate.
#[test]
fn prop_max_compute_util_picks_best_idle() {
    use datadiffusion::scheduler::decision::{Decision, SchedView};
    for case in 0..cases() * 4 {
        let seed = 0x5EED + case;
        let mut rng = Rng::new(seed);
        let mut idx = CentralIndex::new();
        let mut catalog = Catalog::new();
        for i in 0..12 {
            catalog.insert(ObjectId(i), rng.range_u64(1, 100));
        }
        let all: Vec<usize> = (0..8).collect();
        let mut idle: Vec<usize> = all
            .iter()
            .copied()
            .filter(|_| rng.next_f64() < 0.5)
            .collect();
        if idle.is_empty() {
            idle.push(rng.index(8));
        }
        idle.sort_unstable();
        for _ in 0..30 {
            idx.insert(ObjectId(rng.below(12)), rng.index(8));
        }
        let task = Task::with_inputs(
            TaskId(0),
            (0..rng.range_u64(1, 4))
                .map(|_| ObjectId(rng.below(12)))
                .collect(),
        );
        let view = SchedView {
            idle: &idle,
            all: &all,
            index: &idx,
            catalog: &catalog,
        };
        match DispatchPolicy::MaxComputeUtil.decide(&task, &view) {
            Decision::Dispatch { executor, .. } => {
                let best = idle
                    .iter()
                    .map(|&e| view.cached_bytes(&task, e))
                    .max()
                    .unwrap();
                assert_eq!(
                    view.cached_bytes(&task, executor),
                    best,
                    "seed={seed}: picked a worse idle executor"
                );
            }
            other => panic!("seed={seed}: unexpected {other:?}"),
        }
    }
}

/// Flow-network invariants under random workloads: no resource
/// oversubscribed, work conservation (a loaded resource with demand runs
/// at full capacity when every flow it carries is bottlenecked by it),
/// and all flows eventually complete.
#[test]
fn prop_flownet_conservation_and_completion() {
    for case in 0..cases() {
        let seed = 0xF10 + case;
        let mut rng = Rng::new(seed);
        let mut net = FlowNetwork::new();
        let nr = rng.range_u64(2, 12) as usize;
        let caps: Vec<f64> = (0..nr).map(|_| rng.range_f64(1e6, 1e9)).collect();
        let rs: Vec<ResourceId> = caps.iter().map(|&c| net.add_resource(c)).collect();
        let nf = rng.range_u64(1, 60) as usize;
        let mut flows = Vec::new();
        for _ in 0..nf {
            let k = rng.range_u64(1, 3.min(nr as u64)) as usize;
            let mut set = Vec::new();
            for _ in 0..k {
                let r = rs[rng.index(nr)];
                if !set.contains(&r) {
                    set.push(r);
                }
            }
            flows.push(net.start(0.0, FlowSpec::new(rng.range_u64(1, 10_000_000)).over(&set)));
        }
        // Oversubscription check at t=0.
        let mut usage = vec![0.0f64; nr];
        for &f in &flows {
            let rate = net.rate(f);
            assert!(rate > 0.0, "seed={seed}: stalled flow");
        }
        // NOTE: rates queried one by one (rate() recomputes lazily).
        for (i, &f) in flows.iter().enumerate() {
            let _ = i;
            let rate = net.rate(f);
            // Track usage via a second pass (resources private: recompute
            // from our own record of the sets is not available; instead
            // assert the completion loop below terminates, which bounds
            // rates implicitly).
            let _ = (&mut usage, rate);
        }
        // All flows complete in bounded event count.
        let mut completed = 0usize;
        let mut now = 0.0;
        let mut guard = 0;
        while let Some((t, f)) = net.next_completion(now) {
            guard += 1;
            assert!(guard <= nf * 2 + 10, "seed={seed}: completion loop diverged");
            assert!(t >= now - 1e-9, "seed={seed}: time went backwards");
            now = t;
            let left = net.remove_flow(now, f);
            assert!(left < 1.0, "seed={seed}: flow completed with {left} bytes left");
            completed += 1;
        }
        assert_eq!(completed, nf, "seed={seed}: not all flows completed");
    }
}

/// Weighted fair-share invariants over the flow network: (a) granted
/// rates never oversubscribe any resource and every flow progresses;
/// (b) the allocation is work-conserving — flows contending on one
/// resource receive exactly its capacity, split in weight proportion;
/// (c) a foreground flow's allocated rate is monotone nondecreasing in
/// its weight, for the same topology and competing load.
#[test]
fn prop_weighted_shares_conserve_capacity_and_weight_monotonicity() {
    use datadiffusion::sim::flownet::FlowId;
    for case in 0..cases() {
        let seed = 0x3E16 + case;
        let mut rng = Rng::new(seed);

        // (a) Conservation under random weighted multi-resource load.
        let mut net = FlowNetwork::new();
        let nr = rng.range_u64(2, 8) as usize;
        let caps: Vec<f64> = (0..nr).map(|_| rng.range_f64(1e6, 1e9)).collect();
        let rs: Vec<ResourceId> = caps.iter().map(|&c| net.add_resource(c)).collect();
        let nf = rng.range_u64(2, 40) as usize;
        let mut flows = Vec::new();
        for _ in 0..nf {
            let k = rng.range_u64(1, 3.min(nr as u64)) as usize;
            let mut set = Vec::new();
            for _ in 0..k {
                let r = rs[rng.index(nr)];
                if !set.contains(&r) {
                    set.push(r);
                }
            }
            let w = rng.range_f64(0.05, 2.0);
            flows.push(net.start(0.0, FlowSpec::new(rng.range_u64(1, 1_000_000)).weight(w).over(&set)));
        }
        let mut usage = vec![0.0f64; nr];
        for &f in &flows {
            let rate = net.rate(f);
            assert!(rate > 0.0, "seed={seed}: weighted flow starved");
            for r in net.flow_resources(f).to_vec() {
                usage[r.0 as usize] += rate;
            }
        }
        for (i, u) in usage.iter().enumerate() {
            assert!(
                *u <= caps[i] * (1.0 + 1e-6),
                "seed={seed}: resource {i} oversubscribed: {u} > {}",
                caps[i]
            );
        }

        // (b) Work conservation + weight proportionality on one shared
        // resource: demand exceeds capacity, so the grants must sum to
        // exactly the capacity, split w_i / Σw.
        let mut net = FlowNetwork::new();
        let cap = rng.range_f64(1e6, 1e9);
        let r = net.add_resource(cap);
        let n = rng.range_u64(1, 10) as usize;
        let ws: Vec<f64> = (0..n).map(|_| rng.range_f64(0.05, 3.0)).collect();
        let fs: Vec<FlowId> = ws
            .iter()
            .map(|&w| net.start(0.0, FlowSpec::new(1_000_000_000).weight(w).over(&[r])))
            .collect();
        let wsum: f64 = ws.iter().sum();
        let total: f64 = fs.iter().map(|&f| net.rate(f)).sum();
        assert!(
            (total - cap).abs() <= cap * 1e-6,
            "seed={seed}: not work-conserving: granted {total} of {cap}"
        );
        for (i, &f) in fs.iter().enumerate() {
            let expect = cap * ws[i] / wsum;
            assert!(
                (net.rate(f) - expect).abs() <= cap * 1e-6,
                "seed={seed}: flow {i} got {} expected {expect}",
                net.rate(f)
            );
        }

        // (c) Monotonicity: rebuild the same random topology twice, the
        // designated foreground flow at weight w then 2w + ε — its rate
        // must not decrease.
        let fg_rate = |fg_w: f64| -> f64 {
            let mut rng = Rng::new(seed ^ 0x5A5A);
            let mut net = FlowNetwork::new();
            let nr = rng.range_u64(2, 6) as usize;
            let rs: Vec<ResourceId> = (0..nr)
                .map(|_| net.add_resource(rng.range_f64(1e6, 1e9)))
                .collect();
            for _ in 0..rng.range_u64(1, 20) {
                let r = rs[rng.index(nr)];
                let w = rng.range_f64(0.05, 2.0);
                net.start(0.0, FlowSpec::new(1_000_000).weight(w).over(&[r]));
            }
            let k = rng.range_u64(1, nr as u64 + 1) as usize;
            let fg = net.start(0.0, FlowSpec::new(1_000_000).weight(fg_w).over(&rs[..k]));
            net.rate(fg)
        };
        let w1 = Rng::new(seed ^ 0x77).range_f64(0.1, 1.0);
        let lo = fg_rate(w1);
        let hi = fg_rate(2.0 * w1 + 0.1);
        assert!(
            hi >= lo * (1.0 - 1e-9),
            "seed={seed}: raising foreground weight lowered its rate: {lo} -> {hi}"
        );
    }
}

/// Transfer-plane admission invariants under arbitrary staging load and
/// executor churn, for BOTH share policies (binary, and weighted with
/// the budget as its hard cap): (a) foreground transfers are ALWAYS
/// admitted, no matter how saturated the sources are; (b) a background
/// transfer is deferred iff its source is over budget; (c) re-admission
/// only releases transfers whose source is at or under budget, staging
/// before prestage; and (d) every deferred transfer eventually runs
/// (once load drains) or is cancelled when an executor it touches is
/// released — nothing is lost and nothing leaks. Weighting composes
/// with deferral; it never changes queue behavior.
#[test]
fn prop_admission_never_starves_foreground() {
    use datadiffusion::transfer::{
        Admission, AdmissionController, ClassWeights, SharePolicy, TransferClass,
        TransferRequest, WeightedShare,
    };

    const N_EXEC: usize = 6;
    for case in 0..cases() * 2 {
        let seed = 0xAD31 + case;
        let mut rng = Rng::new(seed);
        let budget = rng.range_f64(0.05, 0.95);
        let mut ctl = if case % 2 == 0 {
            AdmissionController::new(budget)
        } else {
            AdmissionController::with_policy(Box::new(WeightedShare::new(
                budget,
                ClassWeights::default(),
            )))
        };
        // Weighting must not leak into admission: the policy's weights
        // shape flows, not queueing.
        if case % 2 == 1 {
            assert!((ctl.weight_of(TransferClass::Staging) - 0.25).abs() < 1e-12);
            assert_eq!(ctl.policy().label(), "weighted");
        }
        // Per-executor utilization the "world" currently shows.
        let mut util = [0.0f64; N_EXEC];
        let mut live: Vec<bool> = vec![true; N_EXEC];
        // Model of what must still be queued: (obj id, source).
        let mut queued: Vec<(u64, usize)> = Vec::new();
        let mut next_obj = 0u64;
        let mut submitted_bg = 0u64;
        let mut started = 0u64;
        let mut cancelled = 0u64;

        for step in 0..300u64 {
            match rng.below(10) {
                // Foreground submission: always admitted, even from a
                // fully saturated (or dead) source.
                0..=2 => {
                    let src = rng.index(N_EXEC);
                    let req = TransferRequest {
                        class: TransferClass::Foreground,
                        obj: ObjectId(u64::MAX - step),
                        src,
                        dst: (src + 1) % N_EXEC,
                        bytes: rng.range_u64(1, 1 << 20),
                    };
                    assert_eq!(
                        ctl.offer(req, util[src]),
                        Admission::Start,
                        "seed={seed} step={step}: foreground deferred at util {}",
                        util[src]
                    );
                }
                // Background submission at the source's current load.
                3..=5 => {
                    let src = rng.index(N_EXEC);
                    if !live[src] {
                        continue;
                    }
                    let class = if rng.below(2) == 0 {
                        TransferClass::Staging
                    } else {
                        TransferClass::Prestage
                    };
                    let obj = next_obj;
                    next_obj += 1;
                    submitted_bg += 1;
                    let req = TransferRequest {
                        class,
                        obj: ObjectId(obj),
                        src,
                        dst: (src + 1 + rng.index(N_EXEC - 1)) % N_EXEC,
                        bytes: rng.range_u64(1, 1 << 20),
                    };
                    let same_src_queued = queued.iter().any(|&(_, s)| s == src);
                    match ctl.offer(req, util[src]) {
                        Admission::Start => {
                            assert!(
                                util[src] <= budget,
                                "seed={seed} step={step}: admitted over budget"
                            );
                            assert!(
                                !same_src_queued,
                                "seed={seed} step={step}: jumped the deferred queue"
                            );
                            started += 1;
                        }
                        Admission::Defer => {
                            assert!(
                                util[src] > budget || same_src_queued,
                                "seed={seed} step={step}: deferred under budget"
                            );
                            queued.push((obj, src));
                        }
                    }
                }
                // Load change + re-admission round.
                6..=8 => {
                    for u in util.iter_mut() {
                        *u = rng.next_f64();
                    }
                    let back = ctl.readmit(|e| util[e]);
                    let mut seen_prestage = false;
                    for r in &back {
                        assert!(
                            util[r.src] <= budget,
                            "seed={seed} step={step}: readmitted over budget"
                        );
                        if r.class == TransferClass::Prestage {
                            seen_prestage = true;
                        } else {
                            assert!(
                                !seen_prestage,
                                "seed={seed} step={step}: prestage before staging"
                            );
                        }
                        let pos = queued.iter().position(|&(o, _)| o == r.obj.0);
                        assert!(
                            pos.is_some(),
                            "seed={seed} step={step}: readmitted unknown transfer"
                        );
                        queued.remove(pos.unwrap());
                        started += 1;
                    }
                }
                // Executor release: deferred transfers touching it are
                // cancelled (returned exactly once, removed from queue).
                _ => {
                    let e = rng.index(N_EXEC);
                    live[e] = false;
                    util[e] = 0.0;
                    for r in ctl.executor_released(e) {
                        assert!(
                            r.src == e || r.dst == e,
                            "seed={seed} step={step}: cancelled transfer not touching {e}"
                        );
                        let pos = queued.iter().position(|&(o, _)| o == r.obj.0);
                        assert!(
                            pos.is_some(),
                            "seed={seed} step={step}: cancelled unknown transfer"
                        );
                        queued.remove(pos.unwrap());
                        cancelled += 1;
                    }
                    // A released executor may come back (fresh lease).
                    if rng.below(3) == 0 {
                        live[e] = true;
                    }
                }
            }
            assert_eq!(
                ctl.deferred_len(),
                queued.len(),
                "seed={seed} step={step}: queue drift"
            );
        }

        // Liveness: drain the world — all load gone, repeated rounds
        // must eventually release every remaining deferred transfer.
        util = [0.0; N_EXEC];
        let mut guard = 0;
        while ctl.deferred_len() > 0 {
            guard += 1;
            assert!(guard <= N_EXEC * 64 + 8, "seed={seed}: drain diverged");
            let back = ctl.readmit(|e| util[e]);
            assert!(
                !back.is_empty(),
                "seed={seed}: idle sources but nothing re-admitted ({} stuck)",
                ctl.deferred_len()
            );
            for r in back {
                let pos = queued.iter().position(|&(o, _)| o == r.obj.0);
                assert!(pos.is_some(), "seed={seed}: drained unknown");
                queued.remove(pos.unwrap());
                started += 1;
            }
        }
        assert!(queued.is_empty(), "seed={seed}: model retained ghosts");
        let s = ctl.stats();
        assert_eq!(s.cancelled, cancelled, "seed={seed}: cancel count drift");
        assert_eq!(
            started,
            submitted_bg - cancelled,
            "seed={seed}: every deferred staging must run or be cancelled"
        );
    }
}

/// Workload-generator invariant: Table 2 rows keep objects/files ≈
/// locality at any scale, and generation is deterministic per seed.
#[test]
fn prop_astro_generator_locality_preserved() {
    use datadiffusion::workloads::astro;
    let cfg = datadiffusion::Config::with_nodes(4);
    for case in 0..cases() {
        let mut rng = Rng::new(0xA57 + case);
        let row = astro::TABLE2[rng.index(astro::TABLE2.len())];
        let scale = rng.range_f64(0.002, 0.2);
        let w = astro::generate(
            &cfg,
            row,
            datadiffusion::storage::object::DataFormat::Gz,
            true,
            scale,
            case,
        );
        let implied = w.objects as f64 / w.files as f64;
        assert!(
            (implied - row.locality).abs() <= row.locality * 0.5 + 1.0,
            "case={case}: locality drifted: {implied} vs {}",
            row.locality
        );
        assert_eq!(w.spec.tasks.len() as u64, w.objects);
        // Every referenced file exists in the catalog.
        for (_, t) in &w.spec.tasks {
            assert!(w.catalog.size(t.inputs[0]).is_some());
        }
    }
}

/// Calendar-queue equivalence: the bucketed `EventQueue` must pop the
/// exact (time, payload) stream a sorted model produces, under random
/// interleavings of inserts (past, near-future, exact-duplicate, and
/// far-future times) and pops — ties broken by insertion order, past
/// times clamped to the cursor, far-future times exercising the
/// overflow heap and width rebasing.
#[test]
fn prop_calendar_queue_order_matches_heap() {
    use datadiffusion::sim::engine::EventQueue;
    for case in 0..cases() {
        let seed = 0xCA1E + case;
        let mut rng = Rng::new(seed);
        let mut q: EventQueue<u64> = EventQueue::new();
        // Model: (effective time, insertion seq, payload). Pops take the
        // (time, seq)-minimum — the production tie-break.
        let mut model: Vec<(f64, u64, u64)> = Vec::new();
        let mut seq = 0u64;
        let mut now = 0.0f64;
        let mut times: Vec<f64> = Vec::new();
        let model_min = |model: &[(f64, u64, u64)]| {
            model
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0).then(a.1 .1.cmp(&b.1 .1)))
                .map(|(k, _)| k)
                .unwrap()
        };
        for _ in 0..400 {
            if model.is_empty() || rng.below(5) < 3 {
                let t_raw = match rng.below(10) {
                    0..=5 => now + rng.range_f64(0.0, 5e-3),
                    6 | 7 if !times.is_empty() => times[rng.index(times.len())],
                    8 => now + rng.range_f64(1e4, 1e7),
                    _ => now - rng.range_f64(0.0, 10.0),
                };
                times.push(t_raw);
                q.at(t_raw, seq);
                model.push((t_raw.max(now), seq, seq));
                seq += 1;
            } else {
                let k = model_min(&model);
                let (mt, _, mp) = model.remove(k);
                assert_eq!(q.pop(), Some((mt, mp)), "seed={seed}: pop mismatch");
                now = mt;
            }
            assert_eq!(q.len(), model.len(), "seed={seed}: length drift");
        }
        while !model.is_empty() {
            let k = model_min(&model);
            let (mt, _, mp) = model.remove(k);
            assert_eq!(q.pop(), Some((mt, mp)), "seed={seed}: drain mismatch");
        }
        assert!(q.pop().is_none(), "seed={seed}: queue must drain empty");
    }
}

/// Federation-layer invariant: a [`GlobalIndex`] never reports a
/// location outside the owning site's executor range, resolves
/// home-first (an on-site copy is always found with zero WAN cost), and
/// the union of the per-site directories always equals an independently
/// maintained model map — under arbitrary interleavings of insert,
/// remove and executor churn over random multi-site topologies.
#[test]
fn prop_global_index_never_escapes_site_ranges() {
    use datadiffusion::config::SiteConfig;
    use datadiffusion::federation::{GlobalIndex, SiteId, Topology};
    use std::collections::{BTreeMap, BTreeSet};

    const N_OBJ: u64 = 12;
    for case in 0..cases() * 2 {
        let seed = 0x517E + case;
        let mut rng = Rng::new(seed);
        let n_sites = rng.range_u64(2, 5) as usize;
        let site_nodes: Vec<usize> =
            (0..n_sites).map(|_| rng.range_u64(1, 8) as usize).collect();
        let total: usize = site_nodes.iter().sum();
        let mut cfg = datadiffusion::Config::with_nodes(total);
        cfg.federation.sites = site_nodes
            .iter()
            .map(|&n| SiteConfig {
                nodes: n,
                ..SiteConfig::default()
            })
            .collect();
        let topo = Topology::from_config(&cfg);
        let mut g = GlobalIndex::new(topo.clone());
        let mut model: BTreeMap<ObjectId, BTreeSet<usize>> = BTreeMap::new();

        for step in 0..250 {
            let obj = ObjectId(rng.below(N_OBJ));
            let e = rng.index(total);
            match rng.below(6) {
                0..=3 => {
                    g.insert(obj, e);
                    model.entry(obj).or_default().insert(e);
                }
                4 => {
                    g.remove(obj, e);
                    if let Some(s) = model.get_mut(&obj) {
                        s.remove(&e);
                        if s.is_empty() {
                            model.remove(&obj);
                        }
                    }
                }
                _ => {
                    g.drop_executor(e);
                    model.retain(|_, s| {
                        s.remove(&e);
                        !s.is_empty()
                    });
                }
            }

            for i in 0..N_OBJ {
                let obj = ObjectId(i);
                // (a) Each site's directory only names its own executors,
                // and the union across sites matches the model exactly.
                let mut union = BTreeSet::new();
                for s in 0..n_sites {
                    let sid = SiteId(s as u32);
                    let range = topo.executor_range(sid);
                    for &h in g.site_locations(sid, obj) {
                        assert!(
                            range.contains(&h),
                            "seed={seed} step={step}: site {s} reports {h} \
                             outside its range {range:?} for {obj}"
                        );
                        union.insert(h);
                    }
                }
                let expect = model.get(&obj).cloned().unwrap_or_default();
                assert_eq!(union, expect, "seed={seed} step={step}: {obj} drifted");

                // (b) locate(): the hit's holders sit inside the reported
                // site's range; home-first with zero WAN cost when the
                // querying site holds a copy; a miss consults every site.
                for s in 0..n_sites as u32 {
                    let from = SiteId(s);
                    let (hit, cost) = g.locate(from, obj);
                    match hit {
                        Some((site, locs)) => {
                            assert!(!locs.is_empty(), "seed={seed}: empty hit");
                            let range = topo.executor_range(site);
                            for &h in locs {
                                assert!(
                                    range.contains(&h),
                                    "seed={seed} step={step}: locate({s}) reports \
                                     {h} outside site {}'s range",
                                    site.0
                                );
                            }
                            if !g.site_locations(from, obj).is_empty() {
                                assert_eq!(site, from, "seed={seed}: not home-first");
                                assert_eq!(cost.hops, 0);
                                assert!(cost.latency_s.abs() < 1e-12);
                            }
                        }
                        None => {
                            assert!(expect.is_empty(), "seed={seed}: missed a holder");
                            assert_eq!(
                                cost.lookups as usize, n_sites,
                                "seed={seed}: miss must consult every directory"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Federated backend equivalence: two [`FedCore`]s over the same
/// multi-site topology — one with per-site Central slices, one with
/// per-site Chord overlays — produce identical site routing, identical
/// dispatch streams and identical location views under random
/// interleavings of submission, completion, cross-site staging and
/// executor churn. The DHT changes lookup *cost*, never *placement*,
/// and the federation layer must preserve that contract site by site.
#[test]
fn prop_federated_site_backends_agree_under_churn_and_staging() {
    use datadiffusion::config::SiteConfig;
    use datadiffusion::federation::FedCore;
    use datadiffusion::index::IndexBackend;
    use std::collections::BTreeSet;

    const N_OBJ: u64 = 16;
    for case in 0..cases() {
        let seed = 0xFED5 + case;
        let mut rng = Rng::new(seed);
        let n_sites = rng.range_u64(2, 4) as usize;
        let site_nodes: Vec<usize> =
            (0..n_sites).map(|_| rng.range_u64(2, 6) as usize).collect();
        let total: usize = site_nodes.iter().sum();
        let mut cfg = datadiffusion::Config::with_nodes(total);
        cfg.seed = seed;
        cfg.federation.sites = site_nodes
            .iter()
            .map(|&n| SiteConfig {
                nodes: n,
                ..SiteConfig::default()
            })
            .collect();
        cfg.federation.skew = rng.range_f64(0.0, 1.0);
        let mut catalog = Catalog::new();
        for i in 0..N_OBJ {
            catalog.insert(ObjectId(i), rng.range_u64(1, 100));
        }
        let mut fa = {
            let mut c = cfg.clone();
            c.index.backend = IndexBackend::Central;
            FedCore::new(&c, catalog.clone())
        };
        let mut fb = {
            let mut c = cfg.clone();
            c.index.backend = IndexBackend::Chord;
            FedCore::new(&c, catalog)
        };
        let mut live: Vec<usize> = (0..total).collect();
        for &e in &live {
            fa.register_executor_with(e, 2);
            fb.register_executor_with(e, 2);
        }
        let mut dead: Vec<usize> = Vec::new();
        let mut submitted = 0u64;
        let mut running: Vec<(usize, TaskId, ObjectId)> = Vec::new();

        let dispatch_both = |fa: &mut FedCore,
                                 fb: &mut FedCore,
                                 running: &mut Vec<(usize, TaskId, ObjectId)>,
                                 tag: &str| {
            let a = fa.try_dispatch();
            let b = fb.try_dispatch();
            assert_eq!(a.len(), b.len(), "seed={seed} {tag}: batch size diverged");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(
                    (x.executor, x.task.id),
                    (y.executor, y.task.id),
                    "seed={seed} {tag}: dispatch streams diverged"
                );
            }
            for o in a {
                running.push((o.executor, o.task.id, o.task.inputs[0]));
            }
        };

        for step in 0..200 {
            match rng.below(10) {
                // Submission: both federations must route the task to the
                // same site (routing reads the backend-independent global
                // directory plus per-site load, which agree inductively).
                0..=3 => {
                    let inputs = vec![ObjectId(rng.below(N_OBJ))];
                    let t = TaskId(submitted);
                    submitted += 1;
                    let sa = fa.submit(Task::with_inputs(t, inputs.clone()));
                    let sb = fb.submit(Task::with_inputs(t, inputs));
                    assert_eq!(sa, sb, "seed={seed} step={step}: site routing diverged");
                }
                // Completion caches the input on the finishing executor.
                4..=6 => {
                    if !running.is_empty() {
                        let (e, id, obj) = running.swap_remove(rng.index(running.len()));
                        let ev = [CacheEvent::Inserted(obj)];
                        fa.on_task_complete(e, id, &ev);
                        fb.on_task_complete(e, id, &ev);
                    }
                }
                // Cross-site staging traffic outside task completion: a
                // replica lands on (or is evicted from) a random live
                // executor, exercising the global-directory mirror.
                7..=8 => {
                    let e = live[rng.index(live.len())];
                    let obj = ObjectId(rng.below(N_OBJ));
                    let ev = if rng.below(4) == 0 {
                        [CacheEvent::Evicted(obj)]
                    } else {
                        [CacheEvent::Inserted(obj)]
                    };
                    fa.apply_cache_events(e, &ev);
                    fb.apply_cache_events(e, &ev);
                }
                // Churn: retire an executor (finish its work first — the
                // provisioner only releases quiescent nodes), or re-admit
                // a previously retired one.
                _ => {
                    if !dead.is_empty() && rng.below(2) == 0 {
                        let e = dead.swap_remove(rng.index(dead.len()));
                        live.push(e);
                        fa.register_executor_with(e, 2);
                        fb.register_executor_with(e, 2);
                    } else if live.len() > 1 {
                        let e = live.swap_remove(rng.index(live.len()));
                        let mut keep = Vec::new();
                        for (re, id, obj) in running.drain(..) {
                            if re == e {
                                fa.on_task_complete(re, id, &[]);
                                fb.on_task_complete(re, id, &[]);
                                let _ = obj;
                            } else {
                                keep.push((re, id, obj));
                            }
                        }
                        running = keep;
                        let a: BTreeSet<ObjectId> =
                            fa.deregister_executor(e).into_iter().collect();
                        let b: BTreeSet<ObjectId> =
                            fb.deregister_executor(e).into_iter().collect();
                        assert_eq!(a, b, "seed={seed} step={step}: orphan sets differ");
                        dead.push(e);
                    }
                }
            }
            dispatch_both(&mut fa, &mut fb, &mut running, "step");
            assert_eq!(
                fa.queue_len(),
                fb.queue_len(),
                "seed={seed} step={step}: queue drift"
            );
            // Location views agree from every live executor's vantage.
            for &e in &live {
                for i in 0..N_OBJ {
                    let obj = ObjectId(i);
                    assert_eq!(
                        fa.locations_for(e, obj),
                        fb.locations_for(e, obj),
                        "seed={seed} step={step}: backends disagree on {obj} from {e}"
                    );
                }
            }
        }
        // Drain both in lockstep; the streams must stay identical to the
        // very last order.
        let mut guard = 0;
        while (!running.is_empty() || fa.queue_len() > 0) && guard < 10_000 {
            guard += 1;
            if let Some((e, id, obj)) = running.pop() {
                let ev = [CacheEvent::Inserted(obj)];
                fa.on_task_complete(e, id, &ev);
                fb.on_task_complete(e, id, &ev);
            }
            dispatch_both(&mut fa, &mut fb, &mut running, "drain");
        }
        assert!(guard < 10_000, "seed={seed}: federations did not quiesce");
        assert_eq!(fa.queue_len(), fb.queue_len(), "residual queue drift");
        assert_eq!(
            fa.cross_site_tasks(),
            fb.cross_site_tasks(),
            "seed={seed}: cross-site placement counts diverged"
        );
    }
}

/// Reference from-scratch progressive filling over an explicit record of
/// live flows — the same arithmetic as the network's fill loop, written
/// against this test's own bookkeeping rather than the network's state.
fn reference_rates(
    caps: &[f64],
    flows: &[(datadiffusion::sim::flownet::FlowId, Vec<usize>, f64)],
) -> Vec<f64> {
    let mut cap = caps.to_vec();
    let mut wsum = vec![0.0f64; caps.len()];
    for (_, set, w) in flows {
        for &r in set {
            wsum[r] += w;
        }
    }
    let mut rates = vec![0.0f64; flows.len()];
    let mut unfixed: Vec<usize> = (0..flows.len()).collect();
    while !unfixed.is_empty() {
        let mut share = f64::INFINITY;
        for i in 0..caps.len() {
            if wsum[i] > 1e-12 {
                let s = cap[i] / wsum[i];
                if s < share {
                    share = s;
                }
            }
        }
        if !share.is_finite() {
            break;
        }
        let mut keep = Vec::new();
        for &j in &unfixed {
            let (_, set, w) = &flows[j];
            let bottlenecked = set
                .iter()
                .any(|&i| wsum[i] > 1e-12 && cap[i] / wsum[i] <= share + 1e-9);
            if bottlenecked {
                rates[j] = w * share;
                for &i in set {
                    cap[i] -= w * share;
                    wsum[i] -= w;
                }
            } else {
                keep.push(j);
            }
        }
        assert!(keep.len() < unfixed.len(), "reference filling must shrink");
        unfixed = keep;
    }
    rates
}

/// Incremental-refill equivalence: after every start/remove of a random
/// churn sequence (weighted flows over random resource subsets, shared
/// and disjoint components mixed), each live flow's rate matches an
/// independent from-scratch progressive filling over the whole network.
/// (Debug builds additionally cross-check inside the network after every
/// refill; this property pins the behaviour from outside the crate.)
#[test]
fn prop_incremental_rates_match_full_recompute() {
    use datadiffusion::sim::flownet::FlowId;
    for case in 0..cases() {
        let seed = 0x1FC2 + case;
        let mut rng = Rng::new(seed);
        let mut net = FlowNetwork::new();
        let nr = rng.range_u64(2, 10) as usize;
        let caps: Vec<f64> = (0..nr).map(|_| rng.range_f64(1e6, 1e9)).collect();
        let rs: Vec<ResourceId> = caps.iter().map(|&c| net.add_resource(c)).collect();
        let mut live: Vec<(FlowId, Vec<usize>, f64)> = Vec::new();
        let mut now = 0.0f64;
        for step in 0..80 {
            now += rng.range_f64(0.0, 1e-3);
            if live.is_empty() || rng.below(3) > 0 {
                let k = rng.range_u64(1, 3.min(nr as u64)) as usize;
                let mut set: Vec<usize> = Vec::new();
                for _ in 0..k {
                    let r = rng.index(nr);
                    if !set.contains(&r) {
                        set.push(r);
                    }
                }
                let weight = rng.range_f64(0.25, 4.0);
                let ids: Vec<ResourceId> = set.iter().map(|&i| rs[i]).collect();
                let bytes = rng.range_u64(1, 10_000_000);
                let f = net.start(now, FlowSpec::new(bytes).weight(weight).over(&ids));
                live.push((f, set, weight));
            } else {
                let i = rng.index(live.len());
                let (f, _, _) = live.swap_remove(i);
                net.remove_flow(now, f);
            }
            let expect = reference_rates(&caps, &live);
            for (j, &(f, _, _)) in live.iter().enumerate() {
                let got = net.rate(f);
                let tol = 1e-6 + 1e-9 * got.abs().max(expect[j].abs());
                assert!(
                    (got - expect[j]).abs() <= tol,
                    "seed={seed} step={step}: flow {j} rate {got} != reference {}",
                    expect[j]
                );
            }
        }
    }
}
