//! Integration: live mode with real files, real gzip, real byte movement.
//!
//! (PJRT-backed stacking is covered by `integration_runtime.rs`; these
//! tests focus on the storage/caching/scheduling plumbing with synthetic
//! tasks so they stay fast.)

use std::path::PathBuf;

use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::live::{synth_object_bytes, LiveStore};
use datadiffusion::storage::object::{DataFormat, ObjectId};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dd_it_live_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn warm_pass_hits_caches_cold_pass_does_not() {
    let root = tmp("warmcold");
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz).unwrap();
    for i in 0..6 {
        store.populate(ObjectId(i), 10_000).unwrap();
    }
    let cfg = Config::with_nodes(3);
    // Two passes over the same 6 objects.
    let tasks: Vec<Task> = (0..12)
        .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 6)]))
        .collect();
    let out = LiveCluster::new(cfg, store, root.join("work"), None)
        .run(tasks)
        .unwrap();
    assert_eq!(out.metrics.tasks_done, 12);
    // 6 cold misses; the rest resolved from caches (own or peer).
    assert!(out.metrics.gpfs_misses >= 6);
    assert!(
        out.metrics.cache_hits + out.metrics.peer_hits >= 4,
        "second pass should mostly hit: {:?}",
        (out.metrics.cache_hits, out.metrics.peer_hits, out.metrics.gpfs_misses)
    );
    let _ = std::fs::remove_dir_all(root);
}

#[test]
fn gz_store_moves_fewer_bytes_than_fit() {
    // The same objects stored compressed vs raw: persistent-storage
    // traffic must shrink accordingly (paper's GZ-vs-FIT axis).
    let mut gz_bytes = 0u64;
    let mut fit_bytes = 0u64;
    for (format, acc) in [(DataFormat::Gz, &mut gz_bytes), (DataFormat::Fit, &mut fit_bytes)] {
        let root = tmp(format.label());
        let mut store = LiveStore::create(root.join("gpfs"), format).unwrap();
        for i in 0..4 {
            store.populate(ObjectId(i), 20_000).unwrap();
        }
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable; // no caching
        let tasks: Vec<Task> = (0..4)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        *acc = out.metrics.gpfs_bytes;
        let _ = std::fs::remove_dir_all(root);
    }
    // Synthetic pixels compress ~1.7x (real SDSS images reach ~3x); the
    // invariant under test is the *direction*, with real headroom.
    assert!(
        (gz_bytes as f64) < 0.7 * fit_bytes as f64,
        "gzip should shrink persistent reads: {gz_bytes} vs {fit_bytes}"
    );
}

#[test]
fn data_integrity_survives_cache_hops() {
    // An object fetched via GPFS → cache → peer cache must decompress to
    // exactly the generator's bytes (checked inside read_object_file via
    // the magic header; here we check full content end-to-end).
    let root = tmp("integrity");
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz).unwrap();
    store.populate(ObjectId(0), 5_000).unwrap();
    let cfg = Config::with_nodes(2);
    // Many tasks over one object: forces peer copies between the 2 nodes.
    let tasks: Vec<Task> = (0..10)
        .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(0)]))
        .collect();
    let out = LiveCluster::new(cfg, store, root.join("work"), None)
        .run(tasks)
        .unwrap();
    assert_eq!(out.metrics.tasks_done, 10);
    // Verify both cache dirs' copies decode to the synthetic source.
    for e in 0..2 {
        let p = root.join("work").join(format!("cache{e}")).join("obj0.fits.gz");
        if p.exists() {
            let raw =
                datadiffusion::storage::live::read_object_file(&p, DataFormat::Gz).unwrap();
            assert_eq!(raw, synth_object_bytes(ObjectId(0), 5_000));
        }
    }
    let _ = std::fs::remove_dir_all(root);
}

#[test]
fn missing_object_fails_loudly() {
    let root = tmp("missing");
    let store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
    let cfg = Config::with_nodes(1);
    let tasks = vec![Task::with_inputs(TaskId(0), vec![ObjectId(404)])];
    let err = LiveCluster::new(cfg, store, root.join("work"), None)
        .run(tasks)
        .unwrap_err();
    assert!(err.to_string().contains("obj404"), "{err}");
    let _ = std::fs::remove_dir_all(root);
}

#[test]
fn eviction_under_tiny_cache_keeps_progress() {
    let root = tmp("evict");
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
    for i in 0..8 {
        store.populate(ObjectId(i), 10_000).unwrap();
    }
    let mut cfg = Config::with_nodes(2);
    // Cache fits ~2 objects (10_000 px * 2B + header ≈ 20KB each).
    cfg.cache.capacity_bytes = 45_000;
    let tasks: Vec<Task> = (0..24)
        .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 8)]))
        .collect();
    let out = LiveCluster::new(cfg, store, root.join("work"), None)
        .run(tasks)
        .unwrap();
    assert_eq!(out.metrics.tasks_done, 24, "evictions must not stall work");
    // Cache dirs must respect the capacity (at most ~2 files each).
    for e in 0..2 {
        let dir = root.join("work").join(format!("cache{e}"));
        let count = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert!(count <= 3, "cache{e} holds {count} files, capacity ~2");
    }
    let _ = std::fs::remove_dir_all(root);
}
