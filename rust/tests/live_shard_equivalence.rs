//! Live shard equivalence + scaling acceptance.
//!
//! The per-shard dispatcher threads (`--shards >= 2` in the live driver)
//! must be a pure concurrency change: the same workload pushed through
//! the single coordinator loop and through 2- and 4-shard planes has to
//! retire every task with identical cache/storage accounting — totals,
//! not orderings, since shard loops interleave freely. On top of that,
//! the whole point of the restructure is throughput: on a machine with
//! visible parallelism, four dispatcher loops must at least double the
//! single loop's dispatch rate on a coordination-bound workload.

use std::path::PathBuf;

use datadiffusion::analysis::figures;
use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::coordinator::Metrics;
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::live::LiveStore;
use datadiffusion::storage::object::{DataFormat, ObjectId};

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dd_it_lse_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Run `tasks` through a fresh store of `n_objects` populated objects at
/// the given shard count and return the summary metrics.
fn run_live(
    tag: &str,
    shards: usize,
    nodes: usize,
    policy: DispatchPolicy,
    n_objects: u64,
    tasks: Vec<Task>,
) -> Metrics {
    let root = tmp(&format!("{tag}_s{shards}"));
    let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
    for i in 0..n_objects {
        store.populate(ObjectId(i), 2_000).unwrap();
    }
    let mut cfg = Config::with_nodes(nodes);
    cfg.scheduler.policy = policy;
    cfg.coordinator.shards = shards;
    let out = LiveCluster::new(cfg, store, root.join("work"), None)
        .run(tasks)
        .unwrap();
    let _ = std::fs::remove_dir_all(root);
    out.metrics
}

/// Two passes over 16 objects on a single executor: pass one misses to
/// GPFS, pass two hits the executor's own cache, and with one slot the
/// schedule is sequential — so every counter below is exact, not a
/// bound. At `shards = 4` the lone executor lives on shard 0 while the
/// tasks hash across all four shards, so any task routed to shards 1–3
/// can only retire through `ShardPlane::steal_into`: full retirement
/// plus a nonzero steal count proves the cross-thread steal path.
#[test]
fn single_executor_totals_identical_across_shard_counts() {
    let mk_tasks = || -> Vec<Task> {
        (0..32)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)]))
            .collect()
    };
    let baseline = run_live("one", 1, 1, DispatchPolicy::MaxComputeUtil, 16, mk_tasks());
    assert_eq!(baseline.tasks_done, 32);
    assert_eq!(baseline.gpfs_misses, 16, "first pass misses every object");
    assert_eq!(baseline.cache_hits, 16, "second pass hits the local cache");
    assert_eq!(baseline.peer_hits, 0, "one executor has no peers");
    assert_eq!(baseline.replicas_created, 0);
    for shards in [2usize, 4] {
        let m = run_live("one", shards, 1, DispatchPolicy::MaxComputeUtil, 16, mk_tasks());
        assert_eq!(m.tasks_done, baseline.tasks_done, "shards={shards}");
        assert_eq!(m.cache_hits, baseline.cache_hits, "shards={shards}");
        assert_eq!(m.peer_hits, baseline.peer_hits, "shards={shards}");
        assert_eq!(m.gpfs_misses, baseline.gpfs_misses, "shards={shards}");
        assert_eq!(m.gpfs_bytes, baseline.gpfs_bytes, "shards={shards}");
        assert_eq!(m.local_bytes, baseline.local_bytes, "shards={shards}");
        assert_eq!(m.replicas_created, 0, "shards={shards}");
        // 16 distinct objects hash over 4 ring points; all landing on
        // the executor's shard would need a degenerate hash.
        if shards == 4 {
            assert!(
                m.dispatch_stolen_tasks > 0,
                "a single-executor 4-shard run must move work across shards"
            );
        }
    }
}

/// Distinct objects under the location-unaware policy: no caching, no
/// peer traffic, so byte accounting is exact at every shard count and
/// every executor count — the multi-executor counterpart of the test
/// above (here shards own disjoint executor slices and real report
/// traffic arrives on four channels concurrently).
#[test]
fn multi_executor_totals_identical_across_shard_counts() {
    let mk_tasks = || -> Vec<Task> {
        (0..24)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i)]))
            .collect()
    };
    let baseline = run_live("many", 1, 4, DispatchPolicy::FirstAvailable, 24, mk_tasks());
    assert_eq!(baseline.tasks_done, 24);
    assert_eq!(baseline.gpfs_misses, 24, "distinct objects all miss");
    assert_eq!(baseline.cache_hits + baseline.peer_hits, 0);
    for shards in [2usize, 4] {
        let m = run_live("many", shards, 4, DispatchPolicy::FirstAvailable, 24, mk_tasks());
        assert_eq!(m.tasks_done, baseline.tasks_done, "shards={shards}");
        assert_eq!(m.gpfs_misses, baseline.gpfs_misses, "shards={shards}");
        assert_eq!(m.cache_hits + m.peer_hits, 0, "shards={shards}");
        assert_eq!(m.gpfs_bytes, baseline.gpfs_bytes, "shards={shards}");
        assert_eq!(m.local_bytes, baseline.local_bytes, "shards={shards}");
    }
}

/// Throughput acceptance: four dispatcher loops must at least double
/// the single loop on a coordination-bound workload (zero-I/O tasks,
/// real executor threads — see `fig_live_shard_scaling`). Best-of-3
/// damps scheduler noise; the ratio assert is gated on visible cores,
/// the accounting asserts are unconditional.
#[test]
fn live_sharded_dispatch_scales() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut best = 0.0f64;
    for _ in 0..3 {
        let rows = figures::fig_live_shard_scaling(&[1, 4], 4_096, 4).unwrap();
        assert_eq!(rows.len(), 2);
        let (one, four) = (&rows[0], &rows[1]);
        assert_eq!(one.tasks, 4_096, "shards=1 must retire the whole batch");
        assert_eq!(one.tasks, four.tasks, "same workload at both shard counts");
        assert!(one.busy_s == 0.0, "the single loop does not meter itself");
        assert!(four.busy_s > 0.0, "shard loops must meter dispatch busy time");
        best = best.max(four.tasks_per_s / one.tasks_per_s.max(1e-12));
    }
    if cores < 4 {
        eprintln!("skipping live shard-scaling ratio assert: only {cores} cores visible");
        return;
    }
    assert!(
        best >= 2.0,
        "live --shards 4 must at least double the single dispatcher loop, \
         got {best:.2}x over 3 attempts"
    );
}
