//! Figure 9: stacking performance at HIGH data locality (30), 2–128
//! CPUs, data diffusion vs GPFS, GZ vs FIT.
//!
//! Paper shape: data diffusion shows near-ideal speedup (time/stack/CPU
//! roughly flat as CPUs grow) in both formats, while GPFS behaves as in
//! Figure 8 (degrading past its saturation point).

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::fmt_secs;

fn main() {
    bench_header(
        "Figure 9: time/stack/CPU at locality 30, 2-128 CPUs",
        "DD ≈ flat (near-ideal speedup); GPFS degrades past saturation",
    );
    let scale = figures::env_scale();
    let cpus = [2usize, 4, 8, 16, 32, 64, 128];
    let rows = figures::fig8_fig9(30.0, &cpus, scale);
    let mut csv = CsvWriter::new(
        results_dir().join("fig9_locality_high.csv"),
        &["config", "cpus", "time_per_stack_s", "hit_ratio"],
    );
    println!("workload scale: {scale} (DD_SCALE to change)\n");
    println!("{:<24} {:>6} {:>16} {:>8}", "config", "cpus", "time/stack/cpu", "hit%");
    for r in &rows {
        println!(
            "{:<24} {:>6} {:>16} {:>7.1}%",
            r.config,
            r.cpus,
            fmt_secs(r.time_per_stack_s),
            r.hit_ratio * 100.0
        );
        csv.rowf(&[&r.config, &r.cpus, &r.time_per_stack_s, &r.hit_ratio]);
    }
    let path = csv.finish().expect("write csv");

    let get = |config: &str, cpus: usize| {
        rows.iter()
            .find(|r| r.config == config && r.cpus == cpus)
            .map(|r| r.time_per_stack_s)
            .unwrap_or(f64::NAN)
    };
    let dd2 = get("Data Diffusion (GZ)", 2);
    let dd128 = get("Data Diffusion (GZ)", 128);
    let gpfs128 = get("GPFS (FIT)", 128);
    println!(
        "\nshape: DD(GZ) 128-vs-2 CPU degradation = {:.2}x (paper: ~flat); \
         DD(GZ) beats GPFS(FIT) at 128 CPUs by {:.1}x",
        dd128 / dd2,
        gpfs128 / dd128
    );
    println!("wrote {}", path.display());
}
