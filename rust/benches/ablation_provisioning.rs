//! Ablation: dynamic resource provisioning policies (the paper's §6
//! future work — its experiments hold the pool static).
//!
//! A scripted arrival scenario (burst → lull → burst) drives the DRP
//! with each allocation policy against the simulated GRAM4-like cluster
//! provider. Reported: executors over time, allocation count, and the
//! executor-seconds consumed vs a static full-size pool — the trade the
//! paper motivates (dedicated performance without dedicated cost).

use datadiffusion::config::ProvisionerConfig;
use datadiffusion::provisioner::{AllocationPolicy, ClusterProvider, ProvisionAction, Provisioner};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};

/// Queue length over time: 0–60s burst of work, 60–180s drain/lull,
/// 180–240s second burst, then quiet.
fn queue_at(t: f64) -> usize {
    if t < 60.0 {
        (t * 4.0) as usize
    } else if t < 180.0 {
        (240.0 - (t - 60.0) * 2.0).max(0.0) as usize
    } else if t < 240.0 {
        ((t - 180.0) * 6.0) as usize
    } else {
        0
    }
}

fn main() {
    bench_header(
        "Ablation: DRP allocation policies under a bursty arrival pattern",
        "paper §6: dynamic provisioning should track demand; static pools waste idle resources",
    );
    let mut csv = CsvWriter::new(
        results_dir().join("ablation_provisioning.csv"),
        &["policy", "peak_executors", "allocations", "executor_seconds", "static_executor_seconds"],
    );
    let horizon = 400.0;
    let max_nodes = 64;
    println!(
        "{:>14} {:>10} {:>12} {:>16} {:>16} {:>8}",
        "policy", "peak", "allocations", "exec-seconds", "static-seconds", "saving"
    );
    for policy in [
        AllocationPolicy::OneAtATime,
        AllocationPolicy::Adaptive,
        AllocationPolicy::AllAtOnce,
    ] {
        let mut drp = Provisioner::new(ProvisionerConfig {
            policy,
            min_executors: 0,
            max_executors: max_nodes,
            allocation_latency_s: 40.0,
            idle_release_s: 30.0,
            queue_per_executor: 4,
            ..ProvisionerConfig::default()
        });
        let mut cluster = ClusterProvider::new(max_nodes, 40.0);
        let mut pending: Vec<(f64, Vec<usize>)> = Vec::new();
        let mut live: Vec<usize> = Vec::new();
        let mut exec_seconds = 0.0;
        let mut allocations = 0u64;
        let mut peak = 0usize;
        let dt = 1.0;
        let mut t = 0.0;
        while t < horizon {
            // Deliver finished allocations.
            pending.retain(|(ready, nodes)| {
                if *ready <= t {
                    drp.on_allocated(nodes.len());
                    live.extend(nodes.iter().copied());
                    false
                } else {
                    true
                }
            });
            let queued = queue_at(t);
            // Idle bookkeeping: when there is no queue, every live
            // executor is idle and a release candidate.
            if queued == 0 {
                for &e in &live {
                    drp.note_idle(e, t);
                }
            } else {
                for &e in &live {
                    drp.note_busy(e);
                }
            }
            for action in drp.evaluate(queued, t) {
                match action {
                    ProvisionAction::Allocate { count } => {
                        allocations += 1;
                        let grant = cluster.allocate(t, count);
                        pending.push((grant.ready_at, grant.nodes));
                    }
                    ProvisionAction::Release { executors } => {
                        for e in executors {
                            live.retain(|&x| x != e);
                            cluster.release(e);
                            drp.on_released(e);
                        }
                    }
                }
            }
            peak = peak.max(live.len());
            exec_seconds += live.len() as f64 * dt;
            t += dt;
        }
        let static_seconds = max_nodes as f64 * horizon;
        println!(
            "{:>14} {:>10} {:>12} {:>16.0} {:>16.0} {:>7.0}%",
            format!("{policy:?}"),
            peak,
            allocations,
            exec_seconds,
            static_seconds,
            (1.0 - exec_seconds / static_seconds) * 100.0
        );
        csv.rowf(&[
            &format!("{policy:?}"),
            &peak,
            &allocations,
            &exec_seconds,
            &static_seconds,
        ]);
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nfinding: adaptive tracks the bursts with few allocation calls and releases\n\
         during the lull — the 'benefit of dedicated hardware without the cost' trade\n\
         the paper's introduction argues for."
    );
    println!("wrote {}", path.display());
}
