//! Figure 8: stacking performance at LOW data locality (1.38), 2–128
//! CPUs, data diffusion vs GPFS, GZ vs FIT.
//!
//! Paper shape: with locality this low, data diffusion and GPFS perform
//! similarly (most data still comes from persistent storage on the cold
//! pass), with diffusion pulling ahead as CPUs grow; uncompressed is
//! better at small CPU counts, compressed wins at scale (GPFS saturates
//! at ~16 CPUs for FIT, ~128 for GZ).

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::fmt_secs;

fn main() {
    bench_header(
        "Figure 8: time/stack/CPU at locality 1.38, 2-128 CPUs",
        "DD ≈ GPFS at low locality, growing advantage with CPUs; GZ beats FIT at scale",
    );
    let scale = figures::env_scale();
    let cpus = [2usize, 4, 8, 16, 32, 64, 128];
    let rows = figures::fig8_fig9(1.38, &cpus, scale);
    let mut csv = CsvWriter::new(
        results_dir().join("fig8_locality_low.csv"),
        &["config", "cpus", "time_per_stack_s", "hit_ratio"],
    );
    println!("workload scale: {scale} (DD_SCALE to change)\n");
    println!("{:<24} {:>6} {:>16} {:>8}", "config", "cpus", "time/stack/cpu", "hit%");
    for r in &rows {
        println!(
            "{:<24} {:>6} {:>16} {:>7.1}%",
            r.config,
            r.cpus,
            fmt_secs(r.time_per_stack_s),
            r.hit_ratio * 100.0
        );
        csv.rowf(&[&r.config, &r.cpus, &r.time_per_stack_s, &r.hit_ratio]);
    }
    let path = csv.finish().expect("write csv");

    let get = |config: &str, cpus: usize| {
        rows.iter()
            .find(|r| r.config == config && r.cpus == cpus)
            .map(|r| r.time_per_stack_s)
            .unwrap_or(f64::NAN)
    };
    println!(
        "\nshape: at 128 CPUs, DD(GZ)/GPFS(GZ) time ratio = {:.2} (paper: <1, modest gap)",
        get("Data Diffusion (GZ)", 128) / get("GPFS (GZ)", 128)
    );
    println!("wrote {}", path.display());
}
