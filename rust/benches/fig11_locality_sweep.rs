//! Figure 11: stacking time per stack per CPU at 128 CPUs as data
//! locality varies 1–30, data diffusion vs GPFS, plus the single-node
//! ideal.
//!
//! Paper shape: GPFS improves somewhat with locality but stays far from
//! ideal; data diffusion approaches the ideal once locality exceeds ~10.

use datadiffusion::analysis::figures;
use datadiffusion::analysis::model;
use datadiffusion::config::presets;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::fmt_secs;

fn main() {
    bench_header(
        "Figure 11: time/stack/CPU vs locality (1-30), 128 CPUs",
        "DD approaches the ideal beyond locality ~10; GPFS stays far above it",
    );
    let scale = figures::env_scale();
    println!("workload scale: {scale} (DD_SCALE to change)\n");
    let rows = figures::fig11_sweep(128, scale);
    let cfg = presets::stacking(128);
    let ideal = model::ideal_stack_time_s(&cfg, true);
    let mut csv = CsvWriter::new(
        results_dir().join("fig11_locality_sweep.csv"),
        &["config", "locality", "time_per_stack_s", "ideal_s"],
    );
    println!("{:<24} {:>8} {:>16} {:>12}", "config", "locality", "time/stack/cpu", "ideal");
    for r in &rows {
        println!(
            "{:<24} {:>8} {:>16} {:>12}",
            r.config,
            r.locality,
            fmt_secs(r.time_per_stack_s),
            fmt_secs(ideal)
        );
        csv.rowf(&[&r.config, &r.locality, &r.time_per_stack_s, &ideal]);
    }
    let path = csv.finish().expect("write csv");

    let get = |config: &str, loc: f64| {
        rows.iter()
            .find(|r| r.config == config && (r.locality - loc).abs() < 1e-9)
            .map(|r| r.time_per_stack_s)
            .unwrap_or(f64::NAN)
    };
    println!(
        "\nshape: at locality 30, DD(GZ) is {:.1}x ideal (paper: close to ideal) \
         while GPFS(GZ) is {:.1}x ideal",
        get("Data Diffusion (GZ)", 30.0) / ideal,
        get("GPFS (GZ)", 30.0) / ideal
    );
    println!("wrote {}", path.display());
}
