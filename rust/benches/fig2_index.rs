//! Figure 2: centralized in-memory index vs distributed P-RLS.
//!
//! Paper: hash-table inserts 1–3 µs, lookups 0.25–1 µs (1M–8M entries),
//! upper bound ~4.18M lookups/s on one node; P-RLS (log-fit to Chervenak
//! et al.) needs >32K nodes to match that aggregate throughput.
//!
//! We *measure* our Rust `CentralIndex` and combine it with the same
//! P-RLS latency model the paper uses — and then go one step further
//! than the paper's analytic argument: the same data-aware workload is
//! run through the real dispatch path under both the centralized and the
//! Chord index backend (`--index central|chord` on the CLI), so the
//! central-vs-distributed comparison is also *measured on scheduled
//! runs*, not only on closed-form curves.

use datadiffusion::analysis::figures;
use datadiffusion::index::central::CentralIndex;
use datadiffusion::index::dht::{ChordRing, DhtModel};
use datadiffusion::index::prls::PrlsModel;
use datadiffusion::storage::object::ObjectId;
use datadiffusion::util::bench::{bench_header, black_box, time_it};
use datadiffusion::util::csv::{results_dir, CsvWriter};

fn main() {
    bench_header(
        "Figure 2: P-RLS vs central hash-table index (1M entries)",
        "central index ~4.18M lookups/s; P-RLS crossover >32K nodes",
    );

    // Build a 1M-entry index (paper's Figure 2 sizing).
    const ENTRIES: u64 = 1_000_000;
    let mut idx = CentralIndex::new();
    let t_insert = time_it("build 1M-entry index", 0, 1, || {
        idx = CentralIndex::new();
        for i in 0..ENTRIES {
            idx.insert(ObjectId(i), (i % 128) as usize);
        }
    });
    let insert_us = t_insert.secs.mean() / ENTRIES as f64 * 1e6;

    // Measured lookup throughput.
    const LOOKUPS: u64 = 1_000_000;
    let mut acc = 0usize;
    let t_lookup = time_it("1M lookups", 1, 5, || {
        for i in 0..LOOKUPS {
            acc += black_box(idx.locations(ObjectId((i * 7919) % ENTRIES)).len());
        }
    });
    black_box(acc);
    let lookup_us = t_lookup.secs.mean() / LOOKUPS as f64 * 1e6;
    let central_rate = 1.0 / (t_lookup.secs.mean() / LOOKUPS as f64);

    println!("measured insert: {insert_us:.3} us/op (paper: 1-3 us)");
    println!("measured lookup: {lookup_us:.3} us/op (paper: 0.25-1 us)");
    println!("central index:   {central_rate:.3e} lookups/s (paper: 4.18e6)");

    // P-RLS model and crossover.
    let model = PrlsModel::fit();
    let crossover = model.crossover_nodes(central_rate);
    println!(
        "P-RLS log fit: latency(n) = {:.4}ms + {:.4}ms*ln(n); latency(1M nodes) = {:.1}ms",
        model.a * 1e3,
        model.b * 1e3,
        model.latency(1_000_000) * 1e3
    );
    match crossover {
        Some(n) => println!("P-RLS crossover vs our measured index: {n} nodes (paper: >32K)"),
        None => println!("P-RLS never catches up within 2^30 nodes"),
    }

    // Chord DHT (the paper's other distributed candidate): hop counts are
    // *measured* on a real finger-table ring, then costed per hop.
    let dht_model = DhtModel::default();

    let mut csv = CsvWriter::new(
        results_dir().join("fig2_index.csv"),
        &[
            "nodes",
            "prls_latency_ms",
            "prls_agg_lookups_per_s",
            "dht_latency_ms",
            "dht_agg_lookups_per_s",
            "central_lookups_per_s",
        ],
    );
    println!(
        "\n{:>9} {:>15} {:>16} {:>14} {:>16} {:>18}",
        "nodes", "P-RLS latency", "P-RLS lookups/s", "DHT latency", "DHT lookups/s", "central lookups/s"
    );
    let mut n = 1u64;
    while n <= 1 << 20 {
        let lat = model.latency(n);
        let agg = model.aggregate_throughput(n);
        // Building million-node rings is cheap enough (fingers are 64
        // entries/node) but cap measurement cost at 2^16 and extrapolate
        // the ½·log2(N) hop law beyond.
        let (dht_lat, dht_agg) = if n <= 1 << 16 {
            let ring = ChordRing::new(n as usize, 7);
            (
                dht_model.lookup_latency_s(&ring),
                dht_model.aggregate_lookups_per_s(&ring),
            )
        } else {
            let hops = 0.5 * (n as f64).log2();
            let per_hop = dht_model.hop_latency_s + dht_model.proc_s;
            (hops * per_hop, n as f64 / (hops * per_hop))
        };
        println!(
            "{n:>9} {:>13.3}ms {:>16.3e} {:>12.3}ms {:>16.3e} {:>18.3e}",
            lat * 1e3,
            agg,
            dht_lat * 1e3,
            dht_agg,
            central_rate
        );
        csv.rowf(&[
            &n,
            &(lat * 1e3),
            &agg,
            &(dht_lat * 1e3),
            &dht_agg,
            &central_rate,
        ]);
        n *= 4;
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nDHT note: Chord hops measured on the ring ≈ 0.5*log2(N); even with LAN hop\n\
         latencies the single-node in-memory index wins until O(100K) nodes — the\n\
         paper's §3.2.3 conclusion holds for both P-RLS and DHT designs."
    );
    println!("wrote {}", path.display());

    // Measured companion: the same workload scheduled end-to-end under
    // each index backend through the real dispatch path (shared emitter
    // with `falkon sweep --figure 2`).
    println!("\nmeasured central-vs-chord on real scheduled runs (max-compute-util):");
    let rows = figures::fig2_measured(&[4, 16, 64], 8);
    let mpath = figures::emit_fig2_measured(&rows, &results_dir()).expect("write csv");
    println!(
        "\nmeasured note: at these scales the chord overlay charges O(log N) hops per\n\
         lookup while the central index stays sub-microsecond — the distributed\n\
         design only pays off once aggregate load exceeds one node's capacity\n\
         (the >32K-node crossover above).\nwrote {}",
        mpath.display()
    );
}
