//! Figure 13: data movement per stacking operation by source, as
//! locality varies, 128 CPUs.
//!
//! Paper shape (compressed data): GPFS bytes per stack fall from ~2 MB
//! at locality 1 to ~0.066 MB at locality 30; cache-to-cache rises from
//! 0 to ~0.4 MB; the rest is local. Total load on shared infrastructure
//! collapses — that is why diffusion scales.

use datadiffusion::analysis::figures::{self, StackConfig};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::workloads::astro;

fn main() {
    bench_header(
        "Figure 13: data movement per stacking by source vs locality, 128 CPUs",
        "GPFS MB/stack: ~2.0 at L=1 -> ~0.066 at L=30; c2c: 0 -> ~0.42; rest local",
    );
    let scale = figures::env_scale();
    println!("workload scale: {scale} (DD_SCALE to change)\n");
    let mut csv = CsvWriter::new(
        results_dir().join("fig13_data_movement.csv"),
        &["locality", "local_mb_per_stack", "c2c_mb_per_stack", "gpfs_mb_per_stack", "baseline_gpfs_mb_per_stack"],
    );
    println!(
        "{:>8} {:>16} {:>16} {:>16} {:>20}",
        "locality", "local MB/stack", "c2c MB/stack", "GPFS MB/stack", "baseline GPFS/stack"
    );
    let mut first_gpfs = f64::NAN;
    let mut last_gpfs = f64::NAN;
    for row in astro::TABLE2 {
        let dd = figures::run_stacking(128, row, StackConfig::DiffusionGz, scale, 20080610);
        let base = figures::run_stacking(128, row, StackConfig::GpfsGz, scale, 20080610);
        let n = dd.metrics.tasks_done.max(1) as f64;
        let local = dd.metrics.local_bytes as f64 / n / 1e6;
        let c2c = dd.metrics.c2c_bytes as f64 / n / 1e6;
        let gpfs = dd.metrics.gpfs_bytes as f64 / n / 1e6;
        let base_gpfs = base.metrics.gpfs_bytes as f64 / base.metrics.tasks_done.max(1) as f64 / 1e6;
        if row.locality == 1.0 {
            first_gpfs = gpfs;
        }
        if row.locality == 30.0 {
            last_gpfs = gpfs;
        }
        println!(
            "{:>8} {:>16.3} {:>16.3} {:>16.3} {:>20.3}",
            row.locality, local, c2c, gpfs, base_gpfs
        );
        csv.rowf(&[&row.locality, &local, &c2c, &gpfs, &base_gpfs]);
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nshape: DD GPFS-bytes per stack falls {:.0}x from locality 1 to 30 \
         (paper: 2MB -> 0.066MB ≈ 30x)",
        first_gpfs / last_gpfs
    );
    println!("wrote {}", path.display());
}
