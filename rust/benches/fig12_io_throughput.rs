//! Figure 12: aggregate I/O throughput of the stacking application at
//! 128 CPUs as locality varies, split by source (local / cache-to-cache /
//! GPFS), vs the GPFS-only baseline.
//!
//! Paper shape: data diffusion reaches ~39 Gb/s at high locality (almost
//! all local), 10x the GPFS baseline's ~4 Gb/s; GPFS-sourced bytes shrink
//! with locality while cache-to-cache stays modest (the scheduler keeps
//! hits local).

use datadiffusion::analysis::figures::{self, StackConfig};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::fmt_bps;
use datadiffusion::workloads::astro;

fn main() {
    bench_header(
        "Figure 12: aggregate I/O throughput by source vs locality, 128 CPUs",
        "DD total ~10x GPFS baseline at high locality; local >> cache-to-cache",
    );
    let scale = figures::env_scale();
    println!("workload scale: {scale} (DD_SCALE to change)\n");
    let mut csv = CsvWriter::new(
        results_dir().join("fig12_io_throughput.csv"),
        &["locality", "dd_local_mbps", "dd_c2c_mbps", "dd_gpfs_mbps", "dd_total_mbps", "gpfs_only_mbps"],
    );
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>14} {:>14}",
        "locality", "DD local", "DD c2c", "DD gpfs", "DD total", "GPFS-only"
    );
    let mut last: Option<(f64, f64)> = None;
    for row in astro::TABLE2 {
        let dd = figures::run_stacking(128, row, StackConfig::DiffusionGz, scale, 20080610);
        let base = figures::run_stacking(128, row, StackConfig::GpfsGz, scale, 20080610);
        let span = dd.makespan_s.max(1e-9);
        let local = dd.metrics.local_bytes as f64 * 8.0 / span;
        let c2c = dd.metrics.c2c_bytes as f64 * 8.0 / span;
        let gpfs = dd.metrics.gpfs_bytes as f64 * 8.0 / span;
        let total = local + c2c + gpfs;
        let base_bps = base.metrics.read_throughput_bps();
        println!(
            "{:>8} {:>14} {:>14} {:>14} {:>14} {:>14}",
            row.locality,
            fmt_bps(local),
            fmt_bps(c2c),
            fmt_bps(gpfs),
            fmt_bps(total),
            fmt_bps(base_bps)
        );
        csv.rowf(&[
            &row.locality,
            &(local / 1e6),
            &(c2c / 1e6),
            &(gpfs / 1e6),
            &(total / 1e6),
            &(base_bps / 1e6),
        ]);
        last = Some((total, base_bps));
    }
    let path = csv.finish().expect("write csv");
    if let Some((total, base)) = last {
        println!(
            "\nshape: at locality 30, DD aggregate = {:.1}x the GPFS baseline (paper ~10x: 39 vs 4 Gb/s)",
            total / base
        );
    }
    println!("wrote {}", path.display());
}
