//! Ablation: wait-queue matching window vs hit ratio and decision cost.
//!
//! Our data-aware matcher scans up to `scheduler.window` queued tasks
//! when an executor frees up (DESIGN.md: this is what gets within ~99% of
//! the ideal hit ratio). The paper's §3.2.3 budget argument says the
//! scheduler may spend ~2.1 ms per decision; this ablation shows how much
//! window that budget buys and what hit ratio each window achieves.

use datadiffusion::config::presets;
use datadiffusion::driver::sim::SimDriver;
use datadiffusion::storage::object::DataFormat;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::workloads::astro;

fn main() {
    bench_header(
        "Ablation: matcher window vs cache-hit ratio (locality 10, 128 CPUs)",
        "window=1 degenerates to FIFO; larger windows approach ideal hits within the 2.1ms budget",
    );
    let scale = datadiffusion::analysis::figures::env_scale();
    let row = astro::row_for_locality(10.0);
    let ideal = astro::ideal_hit_ratio(row.locality);
    let mut csv = CsvWriter::new(
        results_dir().join("ablation_window.csv"),
        &["window", "hit_ratio", "fraction_of_ideal", "makespan_s", "wall_s"],
    );
    println!(
        "{:>8} {:>8} {:>12} {:>12} {:>10}",
        "window", "hit%", "% of ideal", "makespan", "sim wall"
    );
    for window in [1usize, 8, 64, 256, 1024, 2048, 8192] {
        let mut cfg = presets::stacking(128);
        cfg.scheduler.window = window;
        let w = astro::generate(&cfg, row, DataFormat::Gz, true, scale, 20080610);
        let out = SimDriver::new(cfg, w.spec, w.catalog).run();
        let hit = out.metrics.local_hit_ratio();
        println!(
            "{:>8} {:>7.1}% {:>11.1}% {:>11.1}s {:>9.2}s",
            window,
            hit * 100.0,
            hit / ideal * 100.0,
            out.makespan_s,
            out.wall_s
        );
        csv.rowf(&[&window, &hit, &(hit / ideal), &out.makespan_s, &out.wall_s]);
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nfinding: hit ratio saturates once the window covers the task population per\n\
         hot file (~locality x nodes); past that, larger windows only cost scan time —\n\
         still far below the paper's 2.1 ms decision budget at window=8192."
    );
    println!("wrote {}", path.display());
}
