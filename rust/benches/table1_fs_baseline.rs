//! Table 1 + §4.2 file-system baseline study.
//!
//! Reprints the testbed (Table 1) and validates the storage substrate
//! against the paper's measured envelopes:
//!
//! * GPFS read tops out at ~3.4 Gb/s (saturated by ~8 clients);
//! * GPFS read+write tops out at ~1.1 Gb/s;
//! * aggregate local-disk read scales linearly (~76 Gb/s at 162 nodes);
//! * local read+write ~25 Gb/s at 162 nodes.

use datadiffusion::config::{presets, Config};
use datadiffusion::sim::flownet::{FlowNetwork, FlowSpec};
use datadiffusion::storage::testbed::{SimTestbed, TransferKind};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::{fmt_bps, MB};

/// Measure steady-state aggregate bandwidth with `n` concurrent flows of
/// one kind (plus optional write leg).
fn aggregate(cfg: &Config, n: usize, rw: bool, local: bool) -> f64 {
    let mut tb = SimTestbed::new(cfg);
    let mut flows = Vec::new();
    for node in 0..n {
        let read_kind = if local {
            TransferKind::LocalRead { node }
        } else {
            TransferKind::GpfsRead { node }
        };
        let rs = tb.resource_set(read_kind);
        flows.push(tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs)));
        if rw {
            let write_kind = if local {
                TransferKind::LocalWrite { node }
            } else {
                TransferKind::GpfsWrite { node }
            };
            let rs = tb.resource_set(write_kind);
            flows.push(tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs)));
        }
    }
    flows.iter().map(|&f| tb.net.rate(f)).sum()
}

fn main() {
    bench_header(
        "Table 1 testbed + §4.2 file-system baselines",
        "GPFS read ~3.4Gb/s (sat. at 8 nodes); r+w ~1.1Gb/s; local read ~76Gb/s @162 nodes",
    );
    println!("Table 1 platforms:");
    for p in presets::TABLE1 {
        println!(
            "  {:<12} {:>3} nodes | {:<22} | {:>4} | {}",
            p.name, p.nodes, p.processors, p.memory, p.network
        );
    }

    let mut csv = CsvWriter::new(
        results_dir().join("table1_fs_baseline.csv"),
        &["nodes", "gpfs_read_mbps", "gpfs_rw_mbps", "local_read_mbps", "local_rw_mbps"],
    );
    println!(
        "\n{:>6} {:>14} {:>14} {:>14} {:>14}",
        "nodes", "GPFS read", "GPFS r+w", "local read", "local r+w"
    );
    for n in [1usize, 2, 4, 8, 16, 32, 64, 128, 162] {
        let cfg = Config::with_nodes(n);
        let gr = aggregate(&cfg, n, false, false);
        let grw = aggregate(&cfg, n, true, false);
        let lr = aggregate(&cfg, n, false, true);
        let lrw = aggregate(&cfg, n, true, true);
        println!(
            "{n:>6} {:>14} {:>14} {:>14} {:>14}",
            fmt_bps(gr),
            fmt_bps(grw),
            fmt_bps(lr),
            fmt_bps(lrw)
        );
        csv.rowf(&[&n, &(gr / 1e6), &(grw / 1e6), &(lr / 1e6), &(lrw / 1e6)]);
    }
    let path = csv.finish().expect("write csv");

    // Shape checks against the paper's §4.2 numbers.
    let cfg = Config::with_nodes(162);
    let gpfs8 = aggregate(&Config::with_nodes(8), 8, false, false);
    let gpfs64 = aggregate(&Config::with_nodes(64), 64, false, false);
    let local162 = aggregate(&cfg, 162, false, true);
    println!(
        "\nshape: GPFS read saturation 8->64 nodes gain = {:.1}% (paper: <6%)",
        (gpfs64 / gpfs8 - 1.0) * 100.0
    );
    println!(
        "shape: local/GPFS read ratio at 162 nodes = {:.0}x (paper: ~22x)",
        local162 / gpfs64
    );

    // Flow-network micro-throughput (supports the sim-speed target).
    let t0 = std::time::Instant::now();
    let mut net = FlowNetwork::new();
    let r = net.add_resource(1e9);
    let mut completions = 0u64;
    for i in 0..20_000u64 {
        let f = net.start(i as f64, FlowSpec::new(1_000).over(&[r]));
        if let Some((t, id)) = net.next_completion(i as f64) {
            net.remove_flow(t, id);
            completions += 1;
        }
        let _ = f;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nflownet: {} start/complete cycles in {:.3}s ({:.0}/s)",
        completions,
        dt,
        completions as f64 / dt
    );
    println!("wrote {}", path.display());
}
