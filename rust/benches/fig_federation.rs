//! Federation figure: ship-task vs ship-data placement across a
//! (site count × WAN bandwidth × origin skew) grid.
//!
//! Each cell runs the same prewarmed round-robin workload under all
//! three placement modes. Pilot-Data affinity ships tasks to the site
//! already caching their inputs; the always-home and random-site
//! baselines ship 32 MB objects over the shared WAN links instead. The
//! finding the figure pins: affinity wins on makespan AND WAN bytes at
//! every multi-site cell, and the gap widens as the WAN thins.
//!
//! Grid is env-tunable: `DD_FED_SITES`, `DD_FED_WAN_GBPS`,
//! `DD_FED_SKEW` (comma-separated), `DD_FED_NODES`, `DD_TPN`, and
//! `DD_THREADS` (engine worker threads every cell runs at; 0 = one per
//! core — outcomes are thread-count invariant). Defaults keep the
//! bench in seconds.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn env_list<T: std::str::FromStr + Copy>(name: &str, default: &[T]) -> Vec<T> {
    match std::env::var(name) {
        Ok(s) => {
            let parsed: Vec<T> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
            if parsed.is_empty() {
                default.to_vec()
            } else {
                parsed
            }
        }
        Err(_) => default.to_vec(),
    }
}

fn env_num<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    bench_header(
        "federation: affinity vs always-home vs random-site placement",
        "affinity wins makespan and WAN bytes at every multi-site cell",
    );
    let sites = env_list("DD_FED_SITES", &[2usize, 4]);
    let wan = env_list("DD_FED_WAN_GBPS", &[0.25f64, 1.0]);
    let skew = env_list("DD_FED_SKEW", &[0.0f64, 0.8]);
    let nodes = env_num("DD_FED_NODES", 16usize);
    let tpn = env_num("DD_TPN", 8usize);
    let threads = match env_num("DD_THREADS", 1usize) {
        0 => std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
        n => n,
    };
    let rows = figures::fig_federation(&sites, &wan, &skew, nodes, tpn, threads);
    let path = figures::emit_federation(&rows, &results_dir()).expect("write csv");

    // Summarize the headline comparison: per multi-site cell, affinity's
    // makespan and WAN bytes against the better of the two baselines.
    let mut cells = 0usize;
    let mut won_both = 0usize;
    for a in rows.iter().filter(|r| r.placement == "affinity" && r.sites > 1) {
        let mut best_base_makespan = f64::INFINITY;
        let mut best_base_wan = u64::MAX;
        for b in rows.iter().filter(|r| {
            r.placement != "affinity"
                && r.sites == a.sites
                && r.wan_gbps == a.wan_gbps
                && r.skew == a.skew
        }) {
            best_base_makespan = best_base_makespan.min(b.makespan_s);
            best_base_wan = best_base_wan.min(b.wan_bytes);
        }
        cells += 1;
        if a.makespan_s < best_base_makespan && a.wan_bytes < best_base_wan {
            won_both += 1;
        }
    }
    println!(
        "\nfinding: affinity won makespan AND WAN bytes in {won_both}/{cells} multi-site cells.\nwrote {}",
        path.display()
    );
}
