//! Figure 5: read and read+write throughput vs file size (1B–1GB) on 64
//! nodes, for Model (GPFS), first-available, and first-available+wrapper.
//!
//! Paper shape: for small files (1B–10MB) the wrapper configuration is an
//! order of magnitude slower than the others — every task pays
//! mkdir+symlink+rmdir against shared metadata, capping the cluster at
//! ~21 tasks/s; at 100MB the wrapper cost amortizes away.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::{fmt_bps, fmt_bytes};
use datadiffusion::workloads::microbench::FILE_SIZES;

fn main() {
    bench_header(
        "Figure 5: throughput vs file size (1B-1GB), 64 nodes",
        "wrapper caps at ~21 tasks/s on small files (10x below no-wrapper); converges at 100MB+",
    );
    let rows = figures::fig5(&FILE_SIZES, figures::env_tpn());
    let mut csv = CsvWriter::new(
        results_dir().join("fig5_filesize_sweep.csv"),
        &["config", "variant", "file_bytes", "throughput_mbps", "tasks_per_s"],
    );
    println!(
        "{:<44} {:>4} {:>10} {:>14} {:>10}",
        "config", "rw", "size", "throughput", "tasks/s"
    );
    for r in &rows {
        let variant = if r.read_write { "rw" } else { "r" };
        println!(
            "{:<44} {:>4} {:>10} {:>14} {:>10.1}",
            r.config,
            variant,
            fmt_bytes(r.file_bytes),
            fmt_bps(r.bps),
            r.tasks_per_s
        );
        csv.rowf(&[
            &r.config,
            &variant,
            &r.file_bytes,
            &(r.bps / 1e6),
            &r.tasks_per_s,
        ]);
    }
    let path = csv.finish().expect("write csv");

    // Shape check: wrapper tasks/s on tiny files ≈ paper's 21/s cap.
    let wrapper_small = rows
        .iter()
        .find(|r| {
            r.config.contains("Wrapper") && !r.read_write && r.file_bytes == 1
        })
        .map(|r| r.tasks_per_s)
        .unwrap_or(f64::NAN);
    let plain_small = rows
        .iter()
        .find(|r| {
            r.config == "Falkon (first-available)" && !r.read_write && r.file_bytes == 1
        })
        .map(|r| r.tasks_per_s)
        .unwrap_or(f64::NAN);
    println!(
        "\nshape: wrapper small-file rate = {wrapper_small:.1} tasks/s (paper ~21); \
         no-wrapper = {plain_small:.1} tasks/s ({:.0}x)",
        plain_small / wrapper_small
    );
    println!("wrote {}", path.display());
}
