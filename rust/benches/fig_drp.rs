//! DRP figure: demand-response of the three allocation policies (§3.1).
//!
//! A square-burst workload (two bursts separated by a lull longer than
//! the idle-release timeout) is scheduled end-to-end with the executor
//! pool elastic, once per allocation policy. Reported per policy:
//! throughput, peak pool, allocation requests, executors joined/released
//! mid-run, idle executor-seconds (over-provisioning cost) and
//! allocation-wait executor-seconds (provisioning latency cost) — the
//! "dedicated performance without dedicated cost" trade the paper's
//! introduction argues for, measured on real scheduled runs the way
//! `fig2_index` measures the index backends. Table + CSVs come from the
//! same `figures::emit_drp` the `falkon sweep --figure drp` command uses.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn main() {
    bench_header(
        "DRP figure: allocation policies under bursty demand (§3.1)",
        "elastic pool tracks demand; policies trade idle-cost vs response time",
    );
    let nodes = std::env::var("DD_DRP_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let tasks = std::env::var("DD_DRP_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400u64);
    let rows = figures::fig_drp(nodes, tasks);
    let (path, tpath) = figures::emit_drp(&rows, &results_dir()).expect("write csv");
    println!(
        "\nfinding: one-at-a-time serializes growth behind the allocation latency,\n\
         all-at-once answers fastest but idles the most executor-seconds, and\n\
         adaptive tracks the backlog with few requests — the pool shrinks in the\n\
         lull and recovers (cache-cold) in the second burst.\nwrote {} and {}",
        path.display(),
        tpath.display()
    );
}
