//! Dispatcher throughput and data-aware decision latency (§3.1/§3.2.3).
//!
//! Paper: Falkon's non-data-aware dispatcher sustains ~3800 tasks/s; for
//! the data-aware scheduler not to become the bottleneck it must decide
//! within ~2.1 ms (≈3700 index updates or ≈8700 lookups).

use datadiffusion::analysis::figures;
use datadiffusion::cache::store::CacheEvent;
use datadiffusion::config::{IndexConfig, SchedulerConfig};
use datadiffusion::coordinator::core::FalkonCore;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::index::IndexBackend;
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::object::{Catalog, ObjectId};
use datadiffusion::util::bench::{bench_header, black_box, time_it};
use datadiffusion::util::csv::{results_dir, CsvWriter};

const EXECUTORS: usize = 128;
const TASKS: u64 = 100_000;
const OBJECTS: u64 = 10_000;

fn run_policy(policy: DispatchPolicy, data_aware_state: bool) -> (f64, f64) {
    run_policy_with(policy, data_aware_state, IndexBackend::Central)
}

fn run_policy_with(
    policy: DispatchPolicy,
    data_aware_state: bool,
    backend: IndexBackend,
) -> (f64, f64) {
    let mut catalog = Catalog::new();
    for i in 0..OBJECTS {
        catalog.insert(ObjectId(i), 2_000_000);
    }
    let cfg = SchedulerConfig {
        policy,
        ..SchedulerConfig::default()
    };
    let index_cfg = IndexConfig {
        backend,
        ..IndexConfig::default()
    };
    let mut core = FalkonCore::with_index(&cfg, catalog, datadiffusion::index::build(&index_cfg, 7));
    for e in 0..EXECUTORS {
        core.register_executor(e);
    }
    if data_aware_state {
        // Populate the index as a warmed 128-node cluster would be.
        for i in 0..OBJECTS {
            core.apply_cache_events(
                (i % EXECUTORS as u64) as usize,
                &[CacheEvent::Inserted(ObjectId(i))],
            );
        }
    }
    for i in 0..TASKS {
        core.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i % OBJECTS)]));
    }
    // Drain: dispatch + completion in lockstep (steady-state shape).
    let t0 = std::time::Instant::now();
    let mut done = 0u64;
    let mut pending: Vec<(usize, TaskId, ObjectId)> = Vec::new();
    while done < TASKS {
        let orders = core.try_dispatch();
        if orders.is_empty() && pending.is_empty() {
            break;
        }
        for o in orders {
            pending.push((o.executor, o.task.id, o.task.inputs[0]));
        }
        // Complete one task per loop to keep exactly one slot churning.
        if let Some((e, id, obj)) = pending.pop() {
            done += 1;
            core.on_task_complete(e, id, &[CacheEvent::Inserted(obj)]);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    (done as f64 / secs, secs / done as f64)
}

fn main() {
    bench_header(
        "Dispatcher throughput + decision latency (§3.1, §3.2.3)",
        "non-data-aware ~3800 tasks/s; data-aware decision < 2.1 ms",
    );
    let mut csv = CsvWriter::new(
        results_dir().join("dispatch_throughput.csv"),
        &["policy", "tasks_per_s", "decision_us"],
    );
    for (policy, warm) in [
        (DispatchPolicy::FirstAvailable, false),
        (DispatchPolicy::FirstCacheAvailable, true),
        (DispatchPolicy::MaxComputeUtil, true),
        (DispatchPolicy::MaxCacheHit, true),
    ] {
        let (rate, per) = run_policy(policy, warm);
        let per_us = per * 1e6;
        println!(
            "{:<24} {:>12.0} tasks/s {:>12.1} us/decision {}",
            policy.label(),
            rate,
            per_us,
            if per_us < 2100.0 { "(within 2.1ms budget)" } else { "(OVER 2.1ms budget)" }
        );
        csv.rowf(&[&policy.label(), &rate, &per_us]);
    }

    // Backend indirection check: the same data-aware drain through the
    // trait object with the chord backend (routing per charged lookup).
    // The central rows above already go through `Box<dyn DataIndex>`, so
    // central-vs-chord isolates backend cost, and comparing the central
    // rows against a pre-refactor checkout isolates the indirection.
    // Both locations()-scored policies are covered: since the dispatch
    // hot path scores only executors holding >=1 input (O(replicas), not
    // O(executors)), these rows double as the no-regression proof for
    // that rewrite on a 128-executor registry.
    println!();
    for policy in [DispatchPolicy::MaxComputeUtil, DispatchPolicy::MaxCacheHit] {
        for backend in [IndexBackend::Central, IndexBackend::Chord] {
            let (rate, per) = run_policy_with(policy, true, backend);
            let label = format!("{}@{}", policy.label(), backend.label());
            println!(
                "{:<28} {:>12.0} tasks/s {:>12.1} us/decision",
                label,
                rate,
                per * 1e6
            );
            csv.rowf(&[&label, &rate, &(per * 1e6)]);
        }
    }

    // Sharded dispatch core: the same kind of data-aware drain through
    // 1/2/4/8 dispatcher shards (one batched decision pass per shard
    // wake-up, bounded cross-shard steals). The rows ride along in this
    // CSV as `sharded@N` and also land in fig_shard_scaling.csv — the
    // `falkon sweep --figure shards` emitter.
    println!();
    let rows = figures::fig_shard_scaling(&[1, 2, 4, 8], 32_768, EXECUTORS);
    for r in &rows {
        csv.rowf(&[&format!("sharded@{}", r.shards), &r.tasks_per_s, &r.decision_us]);
    }
    match figures::emit_shard_scaling(&rows, &results_dir()) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("error writing shard CSV: {e}"),
    }

    // Live driver, per-shard dispatcher threads: the same axis through
    // real executor threads and real channels (zero-I/O tasks, so the
    // coordination plane is what's measured). `live-sharded@1` is the
    // single coordinator loop; >=2 runs one dispatcher thread per shard.
    println!();
    match figures::fig_live_shard_scaling(&[1, 2, 4], 8_192, 4) {
        Ok(rows) => {
            for r in &rows {
                println!(
                    "{:<28} {:>12.0} tasks/s   busy {:>7.3}s   steals {:>6}",
                    format!("live-sharded@{}", r.shards),
                    r.tasks_per_s,
                    r.busy_s,
                    r.steals
                );
                csv.rowf(&[
                    &format!("live-sharded@{}", r.shards),
                    &r.tasks_per_s,
                    &(r.wall_s / r.tasks.max(1) as f64 * 1e6),
                ]);
            }
        }
        Err(e) => eprintln!("error running live shard axis: {e}"),
    }

    // Raw index ops (the §3.2.3 microbenchmark).
    let mut catalog = Catalog::new();
    catalog.insert(ObjectId(0), 1);
    let mut idx = datadiffusion::index::central::CentralIndex::new();
    for i in 0..1_000_000u64 {
        idx.insert(ObjectId(i), (i % 128) as usize);
    }
    let mut acc = 0usize;
    let r = time_it("index lookups x1M", 1, 3, || {
        for i in 0..1_000_000u64 {
            acc += black_box(idx.locations(ObjectId(i)).len());
        }
    });
    black_box(acc);
    println!(
        "index lookup: {:.3} us ({:.2}M lookups/s; paper: 0.25-1 us, 4.18M/s)",
        r.secs.mean(),
        1.0 / r.secs.mean()
    );
    let path = csv.finish().expect("write csv");
    println!("wrote {}", path.display());
}
