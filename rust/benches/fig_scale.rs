//! Simulator-scalability figure: wall-clock, events/sec, and peak RSS
//! over an (executors × tasks) grid of full data-aware runs.
//!
//! Measures the engine itself — the calendar event queue and the
//! incremental per-component flow refill — not the testbed physics: the
//! workload is all cache-local reads, so every grid cell is pure
//! event-loop + flow-network throughput. Sub-linear events/sec
//! degradation as the grid grows is what makes 10⁵-executor /
//! 10⁷-event runs feasible.
//!
//! Grid is env-tunable: `DD_SCALE_NODES` and `DD_SCALE_TASKS`
//! (comma-separated), plus `DD_SCALE_SITES` (federation sites per cell)
//! and `DD_THREADS` (comma-separated engine-thread axis; each cell's
//! speedup column is relative to its first entry). The default keeps CI
//! runtimes in seconds; nightly runs the 10⁴-executor cell and a
//! threads=1-vs-cores comparison.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn env_list<T: std::str::FromStr + Copy>(name: &str, default: &[T]) -> Vec<T> {
    match std::env::var(name) {
        Ok(s) => {
            let parsed: Vec<T> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
            if parsed.is_empty() {
                default.to_vec()
            } else {
                parsed
            }
        }
        Err(_) => default.to_vec(),
    }
}

fn env_num<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    bench_header(
        "simulator scale: events/sec and peak RSS across the grid",
        "events/sec degrades sub-linearly in executors; RSS stays compact",
    );
    // Smallest-first: peak_rss_mb is a process high-water mark, so this
    // ordering makes the RSS column read as per-cell peaks.
    let nodes = env_list("DD_SCALE_NODES", &[64usize, 256, 1024]);
    let tasks = env_list("DD_SCALE_TASKS", &[10_000u64]);
    let sites = env_num("DD_SCALE_SITES", 1usize);
    let threads: Vec<usize> = env_list("DD_THREADS", &[1usize])
        .into_iter()
        .map(|n: usize| {
            if n == 0 {
                std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
            } else {
                n
            }
        })
        .collect();
    let rows = figures::fig_scale(&nodes, &tasks, sites, &threads);
    let path = figures::emit_scale(&rows, &results_dir()).expect("write csv");
    if let (Some(first), Some(last)) = (rows.first(), rows.last()) {
        println!(
            "\nfinding: {}x executor growth moved events/sec by {:.2}x\n\
             (calendar queue + per-component refill keep per-event cost flat).\nwrote {}",
            last.executors as f64 / first.executors as f64,
            last.events_per_s / first.events_per_s.max(1e-9),
            path.display()
        );
    }
}
