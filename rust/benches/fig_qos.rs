//! QoS figure: staging admission control on vs off.
//!
//! The same saturating staging workload — task bursts queueing on a hot
//! holder's egress while the replication manager stages copies *from
//! that same holder* — is scheduled end-to-end with the transfer plane's
//! admission budget disabled (1.0) and enabled (0.35). Reported per
//! (mode, nodes): foreground p99/mean task latency, replicas staged,
//! stagings deferred — the claim that data diffusion must never starve
//! the foreground work it exists to accelerate, measured on real runs.
//! Table + CSV come from the same `figures::emit_qos` the
//! `falkon sweep --figure qos` command uses.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn main() {
    bench_header(
        "QoS: staging admission control on vs off",
        "the admission budget protects foreground p99 under staging load",
    );
    let max_nodes = std::env::var("DD_QOS_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let bursts = std::env::var("DD_QOS_BURSTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20usize);
    let nodes_list: Vec<usize> = [4usize, 8, 16, 32]
        .into_iter()
        .filter(|&n| n <= max_nodes.max(4))
        .collect();
    let rows = figures::fig_qos(&nodes_list, bursts);
    let path = figures::emit_qos(&rows, &results_dir()).expect("write csv");
    println!(
        "\nfinding: unmetered staging rides the same egress as the foreground fetches\n\
         queued on each holder, stretching the burst tail; the admission budget defers\n\
         staging to the inter-burst gaps, so p99 tightens and replication still lands\n\
         its copies.\nwrote {}",
        path.display()
    );
}
