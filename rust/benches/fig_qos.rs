//! QoS figure: the transfer share-policy axis — off / binary / weighted.
//!
//! The same saturating staging workload — task bursts queueing on a hot
//! holder's egress while the replication manager stages copies *from
//! that same holder* — is scheduled end-to-end three ways: unmetered
//! (`off`), start-time binary deferral (budget 0.35), and weighted
//! per-class fair shares (staging at weight 0.25 for its whole flow
//! lifetime, no deferral). Reported per (mode, nodes): foreground
//! p50/p90/p99/mean task latency, per-class bytes and staging rate,
//! replicas staged, stagings deferred — the claim that data diffusion
//! must never starve the foreground work it exists to accelerate, and
//! that weighted shares buy binary's tail protection without binary's
//! stop-start staging throughput, measured on real runs. Table + CSV
//! come from the same `figures::emit_qos` the `falkon sweep --figure
//! qos` command uses.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn main() {
    bench_header(
        "QoS: share policy off vs binary vs weighted",
        "weighted shares hold foreground p99 at binary's level without stop-start staging",
    );
    let max_nodes = std::env::var("DD_QOS_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let bursts = std::env::var("DD_QOS_BURSTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20usize);
    let nodes_list: Vec<usize> = [4usize, 8, 16, 32]
        .into_iter()
        .filter(|&n| n <= max_nodes.max(4))
        .collect();
    let rows = figures::fig_qos(&nodes_list, bursts);
    let path = figures::emit_qos(&rows, &results_dir()).expect("write csv");
    println!(
        "\nfinding: unmetered staging rides the same egress as the foreground fetches\n\
         queued on each holder, stretching the burst tail; binary deferral tightens the\n\
         tail by stop-starting staging into the gaps; weighted fair shares keep the tail\n\
         at binary's level while staging flows continuously at its class weight.\nwrote {}",
        path.display()
    );
}
