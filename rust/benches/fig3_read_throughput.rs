//! Figure 3: read throughput, 100 MB files, seven configurations,
//! 1–64 nodes.
//!
//! Paper shape: max-compute-util @ 100% locality scales linearly to
//! 61.7 Gb/s at 64 nodes (~94% of the local-disk ideal on their disks);
//! GPFS saturates at ~3.1–3.4 Gb/s beyond 8 nodes; even
//! first-cache-available @ 100% beats GPFS past 16 nodes.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::util::units::fmt_bps;
use datadiffusion::workloads::microbench::NODE_COUNTS;

fn main() {
    bench_header(
        "Figure 3: read throughput of 100MB files, 1-64 nodes",
        "DD@100% ≈ linear (≈94% of local-disk ideal); GPFS flat ≈3.4Gb/s past 8 nodes",
    );
    let rows = figures::fig3_fig4(false, &NODE_COUNTS, figures::env_tpn());
    let mut csv = CsvWriter::new(
        results_dir().join("fig3_read_throughput.csv"),
        &["config", "nodes", "throughput_mbps"],
    );
    println!("{:<48} {:>6} {:>14}", "config", "nodes", "throughput");
    for r in &rows {
        println!("{:<48} {:>6} {:>14}", r.config, r.nodes, fmt_bps(r.bps));
        csv.rowf(&[&r.config, &r.nodes, &(r.bps / 1e6)]);
    }
    let path = csv.finish().expect("write csv");

    // Shape checks (who wins, by what factor).
    let get = |config: &str, nodes: usize| {
        rows.iter()
            .find(|r| r.config == config && r.nodes == nodes)
            .map(|r| r.bps)
            .unwrap_or(f64::NAN)
    };
    let dd64 = get("Falkon (max-compute-util; 100% locality)", 64);
    let ideal64 = get("Model (local disk)", 64);
    let gpfs64 = get("Model (persistent storage)", 64);
    println!("\nshape: DD@100%/ideal at 64 nodes = {:.1}% (paper ~94%)", dd64 / ideal64 * 100.0);
    println!("shape: DD@100%/GPFS at 64 nodes  = {:.1}x (paper ~20x)", dd64 / gpfs64);
    println!("wrote {}", path.display());
}
