//! Figure 10: cache-hit performance of the data-aware scheduler at 128
//! CPUs, localities 1–30, vs the ideal ratio 1 − 1/locality.
//!
//! Paper claim: "the data-aware scheduler can get within 90% of the
//! ideal cache hit ratios in all cases."

use datadiffusion::analysis::figures::{self, StackConfig};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::workloads::astro;

fn main() {
    bench_header(
        "Figure 10: data-aware scheduler cache-hit ratio vs ideal, 128 CPUs",
        "measured within 90% of ideal (1 - 1/locality) for all workloads",
    );
    let scale = figures::env_scale();
    println!("workload scale: {scale} (DD_SCALE to change; 1.0 = full Table 2)\n");
    let mut csv = CsvWriter::new(
        results_dir().join("fig10_cache_hits.csv"),
        &["locality", "ideal_hit", "measured_local_hit", "measured_any_hit", "fraction_of_ideal"],
    );
    println!(
        "{:>8} {:>10} {:>14} {:>16} {:>16}",
        "locality", "ideal", "local hits", "local+c2c hits", "% of ideal"
    );
    let mut worst: f64 = f64::INFINITY;
    for row in astro::TABLE2 {
        let out = figures::run_stacking(128, row, StackConfig::DiffusionGz, scale, 20080610);
        let ideal = astro::ideal_hit_ratio(row.locality);
        let local = out.metrics.local_hit_ratio();
        let any = out.metrics.any_hit_ratio();
        let frac = if ideal > 0.0 { local / ideal } else { 1.0 };
        if ideal > 0.0 {
            worst = worst.min(frac);
        }
        println!(
            "{:>8} {:>9.1}% {:>13.1}% {:>15.1}% {:>15.1}%",
            row.locality,
            ideal * 100.0,
            local * 100.0,
            any * 100.0,
            frac * 100.0
        );
        csv.rowf(&[&row.locality, &ideal, &local, &any, &frac]);
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nshape: worst fraction of ideal = {:.1}% (paper: >=90% — use DD_SCALE=1.0 for the full workload)",
        worst * 100.0
    );
    println!("wrote {}", path.display());
}
