//! Flow-network churn microbench: cost of one remove+start pair at
//! 10², 10³, and 10⁴ concurrent flows.
//!
//! The shape mirrors a large cluster at steady state: each executor
//! streams from its own disk (disjoint single-flow components) and a
//! quarter of them also cross their rack's shared uplink (components of
//! at most one rack). Incremental refill makes the churn cost scale
//! with the touched component, not with the total flow count — per-op
//! time should stay near-flat from 10² to 10⁴ flows, where a full
//! recompute per churn grows ~100x.

use datadiffusion::sim::flownet::{FlowId, FlowNetwork, FlowSpec, ResourceId};
use datadiffusion::util::bench::{bench_header, black_box, time_it};
use datadiffusion::util::units::MB;

/// Executors per shared rack uplink: bounds the largest connected
/// component at ~RACK/4 flows regardless of total flow count.
const RACK: usize = 64;

fn churn_at(n: usize, iters: usize) {
    let mut net = FlowNetwork::new();
    let racks: Vec<ResourceId> = (0..n.div_ceil(RACK)).map(|_| net.add_resource(10e9)).collect();
    let disks: Vec<ResourceId> = (0..n).map(|_| net.add_resource(470e6)).collect();
    let start = |net: &mut FlowNetwork, t: f64, i: usize| -> FlowId {
        if i % 4 == 0 {
            net.start(t, FlowSpec::new(100 * MB).over(&[disks[i], racks[i / RACK]]))
        } else {
            net.start(t, FlowSpec::new(100 * MB).over(&[disks[i]]))
        }
    };
    let mut flows: Vec<FlowId> = (0..n).map(|i| start(&mut net, 0.0, i)).collect();
    let mut t = 0.0f64;
    let mut k = 0usize;
    let r = time_it(&format!("churn @ {n:>5} flows (remove+start)"), 64, iters, || {
        t += 1e-4;
        let i = k % n;
        black_box(net.remove_flow(t, flows[i]));
        flows[i] = start(&mut net, t, i);
        k += 1;
    });
    println!("{}", r.report());
}

fn main() {
    bench_header(
        "flownet churn: incremental refill vs concurrent flow count",
        "per-churn cost tracks the touched component, near-flat in total flows",
    );
    for &n in &[100usize, 1_000, 10_000] {
        churn_at(n, 2_000);
    }
}
