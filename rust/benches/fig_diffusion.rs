//! Data-diffusion figure: demand-driven replication on vs off.
//!
//! The same bursty hot-set workload (two bursts separated by a lull that
//! churns the elastic pool) is scheduled end-to-end at several cache-node
//! counts, once with the passive index only and once with the
//! `ReplicationManager` staging copies in response to demand. Reported
//! per (mode, nodes): aggregate read throughput, local/any hit ratio,
//! replicas staged, replica hits — the paper's headline claim (aggregate
//! I/O bandwidth scaling with cache nodes) measured on real runs.
//! Table + CSV come from the same `figures::emit_diffusion` the
//! `falkon sweep --figure diffusion` command uses.

use datadiffusion::analysis::figures;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::results_dir;

fn main() {
    bench_header(
        "Data diffusion: demand-driven replication on vs off",
        "replication lifts hit ratio and scales aggregate read bandwidth",
    );
    let max_nodes = std::env::var("DD_DIFF_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16usize);
    let tpn = std::env::var("DD_DIFF_TPN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48usize);
    let nodes_list: Vec<usize> = [2usize, 4, 8, 16, 32, 64]
        .into_iter()
        .filter(|&n| n <= max_nodes.max(2))
        .collect();
    let rows = figures::fig_diffusion(&nodes_list, tpn);
    let path = figures::emit_diffusion(&rows, &results_dir()).expect("write csv");
    println!(
        "\nfinding: without replication the post-churn pool hammers the surviving\n\
         holders (peer fetches on the task critical path); with it, joiners are\n\
         pre-staged and hot replica sets widen, so locality recovers and aggregate\n\
         read bandwidth scales with the cache-node count.\nwrote {}",
        path.display()
    );
}
