//! Parallel-engine throughput: events/sec on a 4-site federated
//! workload at 1, 2, 4, and 8 worker threads.
//!
//! The workload is the site-parallel shape (every input prewarmed at
//! its home executor, affinity placement keeping tasks site-local), so
//! the four site worlds run nearly independent event streams and the
//! measurement isolates the window-barrier protocol: rounds of
//! min-reduction + barrier against windows of real event work. Speedup
//! flattening past the site count is expected — the engine caps worker
//! threads at one per site.
//!
//! Every row is asserted bit-for-bit identical to the threads=1
//! outcome before it is reported: a speedup that changes the physics
//! is a bug, not a result.
//!
//! Env-tunable: `DD_PAR_NODES` (total executors), `DD_PAR_TASKS`,
//! `DD_PAR_THREADS` (comma-separated thread axis).

use datadiffusion::config::Config;
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::sim::{SimDriver, SimWorkloadSpec};
use datadiffusion::driver::RunOutcome;
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::object::{Catalog, ObjectId};
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::units::MB;

const SITES: usize = 4;

fn env_num<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn env_list(name: &str, default: &[usize]) -> Vec<usize> {
    match std::env::var(name) {
        Ok(s) => {
            let parsed: Vec<usize> = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
            if parsed.is_empty() {
                default.to_vec()
            } else {
                parsed
            }
        }
        Err(_) => default.to_vec(),
    }
}

fn run(nodes: usize, tasks: u64, threads: usize) -> (RunOutcome, f64) {
    let mut cfg = Config::with_nodes(nodes);
    cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
    cfg.split_into_sites(SITES);
    cfg.federation.skew = 0.0;
    cfg.sim.threads = threads;
    let mut catalog = Catalog::new();
    for e in 0..nodes {
        catalog.insert(ObjectId(e as u64), MB);
    }
    let task_list: Vec<(f64, Task)> = (0..tasks)
        .map(|i| {
            (
                i as f64 * 0.0005,
                Task::with_inputs(TaskId(i), vec![ObjectId(i % nodes as u64)]),
            )
        })
        .collect();
    let mut spec = SimWorkloadSpec::new(task_list);
    spec.prewarm = (0..nodes).map(|e| (e, ObjectId(e as u64))).collect();
    let t0 = std::time::Instant::now();
    let out = SimDriver::new(cfg, spec, catalog).run();
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    (out, wall)
}

fn main() {
    bench_header(
        "parallel engine: events/sec, 4 federation sites across thread counts",
        "speedup grows to the site count, outcomes bit-for-bit identical",
    );
    let nodes = env_num("DD_PAR_NODES", 32usize);
    let tasks = env_num("DD_PAR_TASKS", 20_000u64);
    let threads = env_list("DD_PAR_THREADS", &[1, 2, 4, 8]);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    println!(
        "{:<8} {:>10} {:>10} {:>12} {:>8}",
        "threads", "events", "wall", "events/s", "speedup"
    );
    let mut baseline: Option<(u64, f64, f64)> = None; // (checksum, makespan, wall)
    for &t in &threads {
        let t = t.max(1);
        let (out, wall) = run(nodes, tasks, t);
        assert_eq!(out.metrics.tasks_done, tasks, "threads={t} must drain the run");
        let (sum, makespan, base_wall) =
            *baseline.get_or_insert((out.metrics.checksum(), out.makespan_s, wall));
        assert_eq!(
            out.metrics.checksum(),
            sum,
            "threads={t} outcome diverged from the serial run"
        );
        assert_eq!(
            out.makespan_s.to_bits(),
            makespan.to_bits(),
            "threads={t} makespan diverged from the serial run"
        );
        println!(
            "{:<8} {:>10} {:>9.3}s {:>12.0} {:>7.2}x",
            t,
            out.events,
            wall,
            out.events as f64 / wall,
            base_wall / wall
        );
    }
    println!(
        "\nfinding: {cores} cores visible; the thread axis caps at the {SITES}-site\n\
         decomposition — rows past threads={SITES} measure barrier overhead only."
    );
}
