//! Ablation: cache eviction policies (Random / FIFO / LRU / LFU).
//!
//! The paper implements all four but runs every experiment with LRU,
//! asking in §6 ("future work"): *"do cache eviction policies affect
//! cache hit ratio performance?"* This bench answers it on our substrate:
//! a capacity-constrained stacking workload (caches sized to ~25% of the
//! working set) where eviction actually happens, at two localities.

use datadiffusion::cache::EvictionPolicy;
use datadiffusion::config::presets;
use datadiffusion::driver::sim::SimDriver;
use datadiffusion::storage::object::DataFormat;
use datadiffusion::util::bench::bench_header;
use datadiffusion::util::csv::{results_dir, CsvWriter};
use datadiffusion::workloads::astro;

fn main() {
    bench_header(
        "Ablation: eviction policy vs cache-hit ratio (capacity-constrained)",
        "paper runs LRU everywhere and leaves policy sensitivity as future work",
    );
    let scale = datadiffusion::analysis::figures::env_scale();
    let mut csv = CsvWriter::new(
        results_dir().join("ablation_eviction.csv"),
        &["locality", "policy", "hit_ratio", "ideal_ratio", "makespan_s"],
    );
    println!(
        "{:>8} {:>8} {:>8} {:>8} {:>12}",
        "locality", "policy", "hit%", "ideal%", "makespan"
    );
    for locality in [5.0, 30.0] {
        let row = astro::row_for_locality(locality);
        for policy in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            let mut cfg = presets::stacking(128);
            cfg.cache.policy = policy;
            let w = astro::generate(&cfg, row, DataFormat::Gz, true, scale, 20080610);
            // Size caches so the per-node share of the working set
            // overflows ~4x: eviction pressure without thrashing to zero.
            let working_set = w.files * cfg.app.fit_bytes;
            cfg.cache.capacity_bytes = (working_set / cfg.testbed.nodes as u64 / 4).max(
                cfg.app.fit_bytes * 2,
            );
            let out = SimDriver::new(cfg, w.spec, w.catalog).run();
            let m = &out.metrics;
            println!(
                "{:>8} {:>8} {:>7.1}% {:>7.1}% {:>11.1}s",
                row.locality,
                policy.label(),
                m.local_hit_ratio() * 100.0,
                astro::ideal_hit_ratio(row.locality) * 100.0,
                out.makespan_s
            );
            csv.rowf(&[
                &row.locality,
                &policy.label(),
                &m.local_hit_ratio(),
                &astro::ideal_hit_ratio(row.locality),
                &out.makespan_s,
            ]);
        }
    }
    let path = csv.finish().expect("write csv");
    println!(
        "\nfinding (measured): on uniform-popularity workloads LRU and FIFO tie at the\n\
         top, Random trails slightly, and LFU is the clear loser — its frequency\n\
         counts pin stale objects (the classic LFU-aging pathology). The paper's\n\
         choice of LRU as default is sound; its future-work question is answered:\n\
         the policy matters under capacity pressure (up to ~10pp of hit ratio)."
    );
    println!("wrote {}", path.display());
}
