//! The Falkon dispatcher core: wait queue + executor registry +
//! cache-location index + dispatch policy, as pure synchronous state.
//!
//! Both drivers (discrete-event simulation and live threads) feed this
//! same structure, which is the point: the paper's *contribution* — the
//! data-aware scheduling logic — is one implementation exercised under
//! two substrates. Drivers call in on every state change and carry out
//! the returned [`DispatchOrder`]s.
//!
//! The index is any [`DataIndex`] backend chosen at construction
//! ([`FalkonCore::with_index`]); backends change lookup *cost*, never
//! placement, so the scheduling behavior is backend-invariant while the
//! charged index latency (shipped on every order as
//! [`DispatchOrder::cost`]) is not.

use crate::util::fxhash::FxHashMap;

use crate::cache::store::CacheEvent;
use crate::config::{ReplicationConfig, SchedulerConfig};
use crate::coordinator::task::{Task, TaskId};
use crate::index::central::{CentralIndex, ExecutorId};
use crate::index::{ControlTraffic, DataIndex, LookupCost};
use crate::replication::{ReplicaDirective, ReplicationManager};
use crate::scheduler::decision::{BatchScratch, Decision, LocationHints, SchedView};
use crate::scheduler::queue::WaitQueue;
use crate::scheduler::DispatchPolicy;
use crate::storage::object::{Catalog, ObjectId};

/// A dispatch the driver must carry out.
#[derive(Debug, Clone)]
pub struct DispatchOrder {
    /// The task to run.
    pub task: Task,
    /// Where to run it.
    pub executor: ExecutorId,
    /// Data-location hints to ship along (empty for first-available).
    pub hints: LocationHints,
    /// Simulated index cost behind this dispatch (one location lookup per
    /// input for data-aware policies; [`LookupCost::ZERO`] otherwise).
    /// The sim driver charges `cost.latency_s` into the event timeline.
    pub cost: LookupCost,
}

/// Executor slot accounting. An executor (node) may run several tasks
/// concurrently — one per CPU (§5 uses dual-CPU nodes: 128 CPUs on 64
/// nodes). It is "idle" (dispatchable) while `busy < capacity`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Slots {
    capacity: usize,
    busy: usize,
}

/// The dispatcher core.
pub struct FalkonCore {
    policy: DispatchPolicy,
    window: usize,
    queue: WaitQueue,
    index: Box<dyn DataIndex>,
    catalog: Catalog,
    slots: FxHashMap<ExecutorId, Slots>,
    idle: Vec<ExecutorId>, // sorted: executors with a free slot
    all: Vec<ExecutorId>,  // sorted
    /// Demand-driven replication manager (None: passive index only).
    repl: Option<ReplicationManager>,
    /// Reusable scoring scratch: a batch of k decisions per wake-up
    /// shares one accumulator allocation instead of building k.
    scratch: BatchScratch,
    submitted: u64,
    dispatched: u64,
    completed: u64,
}

impl FalkonCore {
    /// New core with the given policy and object catalog, over a
    /// zero-cost [`CentralIndex`] (the historical default).
    pub fn new(cfg: &SchedulerConfig, catalog: Catalog) -> Self {
        FalkonCore::with_index(cfg, catalog, Box::new(CentralIndex::new()))
    }

    /// New core over an explicit index backend (see [`crate::index::build`]
    /// for constructing one from an `IndexConfig`).
    pub fn with_index(cfg: &SchedulerConfig, catalog: Catalog, index: Box<dyn DataIndex>) -> Self {
        FalkonCore {
            policy: cfg.policy,
            window: cfg.window.max(1),
            queue: WaitQueue::new(),
            index,
            catalog,
            slots: FxHashMap::default(),
            idle: Vec::new(),
            all: Vec::new(),
            repl: None,
            scratch: BatchScratch::default(),
            submitted: 0,
            dispatched: 0,
            completed: 0,
        }
    }

    /// The dispatch policy in force.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// The object catalog.
    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// The cache-location index (read access for metrics/benches).
    pub fn index(&self) -> &dyn DataIndex {
        self.index.as_ref()
    }

    /// Drain the index backend's accumulated control-plane traffic
    /// (Chord stabilization messages and misroute counts; zero on the
    /// centralized backend). Drivers harvest this periodically — and once
    /// at run end — into [`crate::coordinator::metrics::Metrics`].
    pub fn take_index_control(&mut self) -> ControlTraffic {
        self.index.take_control_traffic()
    }

    /// Fraction of `e`'s task slots currently busy (0.0 for an unknown
    /// executor). Diagnostics only since the weighted-shares refactor:
    /// the live transfer plane now meters real bytes in flight
    /// ([`crate::transfer::live::EgressLedger`]) instead of this proxy.
    pub fn busy_fraction(&self, e: ExecutorId) -> f64 {
        self.slots
            .get(&e)
            .map(|s| s.busy as f64 / s.capacity.max(1) as f64)
            .unwrap_or(0.0)
    }

    /// Turn on demand-driven replication (no-op if `cfg.enabled` is
    /// false). Executors already registered are treated as warm members,
    /// not joiners — only later joins get pre-staged.
    pub fn enable_replication(&mut self, cfg: &ReplicationConfig) {
        if cfg.enabled {
            self.repl = Some(ReplicationManager::new(cfg.clone()));
        }
    }

    /// Whether a replication manager is active.
    pub fn replication_enabled(&self) -> bool {
        self.repl.is_some()
    }

    /// Replica location entries: cached copies beyond each object's
    /// first (0 when nothing is replicated).
    pub fn replica_location_entries(&self) -> usize {
        self.index.entries().saturating_sub(self.index.len())
    }

    /// One replication evaluation round: returns the staging directives
    /// the driver must carry out (copy object from src's cache to dst's).
    /// Empty when replication is disabled.
    pub fn poll_replication(&mut self) -> Vec<ReplicaDirective> {
        match self.repl.as_mut() {
            Some(r) => r.evaluate(self.index.as_ref(), &self.all),
            None => Vec::new(),
        }
    }

    /// Driver notification: executor `dst` fetched `obj` from a peer
    /// cache (a demand signal for the replication manager).
    pub fn note_peer_fetch(&mut self, obj: ObjectId, dst: ExecutorId) {
        if let Some(r) = self.repl.as_mut() {
            r.note_peer_fetch(obj, dst);
        }
    }

    /// Driver notification: the staging transfer behind a directive
    /// finished (or was abandoned — dst released, source evicted, copy
    /// already present). Frees the in-flight slot; the index itself is
    /// updated through [`FalkonCore::apply_cache_events`] like any other
    /// cache change, preserving the index/cache coherence contract.
    pub fn replication_staged(&mut self, obj: ObjectId, dst: ExecutorId) {
        if let Some(r) = self.repl.as_mut() {
            r.on_staged(obj, dst);
        }
    }

    /// Driver notification: a [`ReplicaDirective::Drop`] was executed (or
    /// abandoned — victim released, copy already gone). The cache/index
    /// change itself flows through [`FalkonCore::apply_cache_events`]
    /// like any other eviction.
    pub fn replication_dropped(&mut self, obj: ObjectId, victim: ExecutorId) {
        if let Some(r) = self.repl.as_mut() {
            r.on_drop_done(obj, victim);
        }
    }

    /// Register a newly provisioned executor with one task slot.
    pub fn register_executor(&mut self, e: ExecutorId) {
        self.register_executor_with(e, 1);
    }

    /// Register an executor that can run `capacity` tasks concurrently
    /// (e.g. a dual-CPU node with capacity 2).
    pub fn register_executor_with(&mut self, e: ExecutorId, capacity: usize) {
        debug_assert!(capacity >= 1);
        if self
            .slots
            .insert(e, Slots { capacity, busy: 0 })
            .is_none()
        {
            if let Err(pos) = self.all.binary_search(&e) {
                self.all.insert(pos, e);
            }
            if let Err(pos) = self.idle.binary_search(&e) {
                self.idle.insert(pos, e);
            }
            self.index.executor_joined(e);
            if let Some(r) = self.repl.as_mut() {
                r.executor_joined(e);
            }
        }
    }

    /// Deregister an executor (released by the provisioner). Its parked
    /// tasks re-enter the queue; its index entries are dropped. Returns
    /// the objects whose last cached copy vanished with it.
    pub fn deregister_executor(&mut self, e: ExecutorId) -> Vec<crate::storage::object::ObjectId> {
        self.slots.remove(&e);
        if let Ok(pos) = self.all.binary_search(&e) {
            self.all.remove(pos);
        }
        if let Ok(pos) = self.idle.binary_search(&e) {
            self.idle.remove(pos);
        }
        self.queue.release(e); // parked tasks go back to the queue front
        if let Some(r) = self.repl.as_mut() {
            r.executor_dropped(e);
        }
        self.index.drop_executor(e)
    }

    /// Submit one task to the wait queue.
    pub fn submit(&mut self, task: Task) {
        self.submitted += 1;
        self.queue.push(task);
    }

    /// Current wait-queue length (FIFO + parked).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Wait-queue high-water mark since the run started.
    pub fn queue_peak(&self) -> usize {
        self.queue.peak()
    }

    /// Wait-queue high-water mark since the last call, resetting the
    /// mark — the provisioner's per-interval demand signal.
    pub fn take_queue_peak(&mut self) -> usize {
        self.queue.take_peak()
    }

    /// Number of idle executors.
    pub fn idle_count(&self) -> usize {
        self.idle.len()
    }

    /// All registered executors, ascending.
    pub fn executors(&self) -> &[ExecutorId] {
        &self.all
    }

    /// Executors running nothing at all (every slot free), ascending —
    /// the provisioner's release candidates. Distinct from `idle`, which
    /// contains any executor with *a* free slot.
    pub fn quiescent_executors(&self) -> Vec<ExecutorId> {
        self.all
            .iter()
            .copied()
            .filter(|e| self.slots.get(e).map(|s| s.busy == 0).unwrap_or(false))
            .collect()
    }

    /// Number of registered executors.
    pub fn executor_count(&self) -> usize {
        self.all.len()
    }

    /// (submitted, dispatched, completed) lifetime counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.submitted, self.dispatched, self.completed)
    }

    /// Attempt to dispatch as many queued tasks as the policy allows.
    /// Returns the orders the driver must execute. Convenience wrapper
    /// over [`FalkonCore::dispatch_into`] that allocates the result.
    pub fn try_dispatch(&mut self) -> Vec<DispatchOrder> {
        let mut orders = Vec::new();
        self.dispatch_into(&mut orders);
        orders
    }

    /// Batched dispatch into a caller-owned buffer: drains the ready
    /// queue once per wake-up, scoring the whole batch against the idle
    /// set through one reused [`BatchScratch`], and appends the resulting
    /// orders to `orders` (which is *not* cleared — callers reuse one
    /// buffer across wake-ups and drain it after each call). Decisions
    /// are identical to deciding each task individually: batching changes
    /// where allocations live, never what the policy sees.
    pub fn dispatch_into(&mut self, orders: &mut Vec<DispatchOrder>) {
        if self.policy == DispatchPolicy::MaxComputeUtil {
            return self.dispatch_matching_into(orders);
        }
        // Keep pulling tasks while we can place them. A task that parks
        // (Delay) does not block later tasks; a task that finds no
        // executor goes back to the front and stops the loop (FIFO).
        loop {
            let Some(task) = self.queue.pop() else { break };
            let view = SchedView {
                idle: &self.idle,
                all: &self.all,
                index: self.index.as_ref(),
                catalog: &self.catalog,
            };
            match self.policy.decide_with(&task, &view, &mut self.scratch) {
                Decision::Dispatch { executor, hints } => {
                    let cost = self.hint_lookup_cost(&task);
                    self.note_dispatch_demand(&task, executor);
                    self.mark_busy(executor);
                    self.dispatched += 1;
                    orders.push(DispatchOrder {
                        task,
                        executor,
                        hints,
                        cost,
                    });
                }
                Decision::Delay { executor } => {
                    self.queue.park(executor, task);
                }
                Decision::NoExecutor => {
                    self.queue.push_front(task);
                    break;
                }
            }
        }
    }

    /// max-compute-util dispatch with wait-queue matching.
    ///
    /// The policy "always sends a task to an available executor", and the
    /// scheduler exploits locality by *choosing which queued task* an
    /// available executor gets: up to `window` ready tasks are scanned
    /// for the (task, idle executor) pair with the most cached bytes
    /// (§3.2.3's 2.1 ms decision budget comfortably covers the scan —
    /// see `benches/dispatch_throughput.rs`). With no cached candidate it
    /// degrades to plain FIFO, so CPUs never idle while work waits.
    fn dispatch_matching_into(&mut self, orders: &mut Vec<DispatchOrder>) {
        while !self.idle.is_empty() {
            let w = self.window.min(self.queue.ready_len());
            if w == 0 {
                break;
            }
            // Best (score, position, executor), preferring higher score,
            // then earlier task; executors tied on score for one task
            // (replicas of its inputs) rotate by the task id, the same
            // spread rule as `SchedView::best_holder`. Scores come from
            // index.locations() so the scan cost is O(window × replicas),
            // independent of cluster size.
            let mut best: Option<(u64, usize, ExecutorId)> = None;
            if !self.index.is_empty() {
                // Reused scoring accumulator: the window scan shares the
                // decision scratch, so a whole drain allocates nothing.
                let per_exec = &mut self.scratch.per_exec;
                'scan: for (pos, task) in self.queue.iter_ready().take(w).enumerate() {
                    per_exec.clear();
                    let mut task_total = 0u64;
                    for &obj in &task.inputs {
                        let size = self.catalog.size(obj).unwrap_or(1);
                        task_total += size;
                        for &e in self.index.locations(obj) {
                            if self.idle.binary_search(&e).is_err() {
                                continue;
                            }
                            match per_exec.iter_mut().find(|(pe, _)| *pe == e) {
                                Some((_, s)) => *s += size,
                                None => per_exec.push((e, size)),
                            }
                        }
                    }
                    if let Some((e, s)) = SchedView::rotate_tied(per_exec, task) {
                        // Earlier positions win score ties automatically:
                        // we only replace on a strictly better score.
                        if best.map(|(bs, _, _)| s > bs).unwrap_or(true) {
                            best = Some((s, pos, e));
                        }
                    }
                    // Early exit: this task is *fully* cached on an idle
                    // executor. Scanning further can only find a task with
                    // strictly larger total input size; with the paper's
                    // uniform file sizes that does not exist, and the
                    // earliest fully-local task is the fair FIFO choice.
                    if let Some((bs, bp, _)) = best {
                        if bp == pos && bs == task_total && task_total > 0 {
                            break 'scan;
                        }
                    }
                }
            }
            let (task, executor) = match best {
                Some((_, pos, e)) => (
                    self.queue.remove_ready_at(pos).expect("scanned position"),
                    e,
                ),
                // Nothing cached anywhere useful: plain FIFO to the first
                // idle executor.
                None => (self.queue.pop().expect("ready_len > 0"), self.idle[0]),
            };
            let view = SchedView {
                idle: &self.idle,
                all: &self.all,
                index: self.index.as_ref(),
                catalog: &self.catalog,
            };
            let hints = view.hints_for(&task);
            let cost = self.hint_lookup_cost(&task);
            self.note_dispatch_demand(&task, executor);
            self.mark_busy(executor);
            self.dispatched += 1;
            orders.push(DispatchOrder {
                task,
                executor,
                hints,
                cost,
            });
        }
    }

    /// Steal up to `max` *ready* tasks from the back of this core's wait
    /// queue (youngest first to go, original order preserved — see
    /// [`WaitQueue::steal_back`]). Parked tasks never move: they wait on
    /// a specific busy executor only this core tracks. The `submitted`
    /// counter is untouched — the victim keeps the submit credit and the
    /// thief absorbs without counting, so counters summed across shards
    /// stay exact.
    pub fn steal_ready(&mut self, max: usize) -> Vec<Task> {
        self.queue.steal_back(max)
    }

    /// Accept a task stolen from another core: enqueue it *without*
    /// counting a submission (the victim already did).
    pub fn absorb(&mut self, task: Task) {
        self.queue.push(task);
    }

    /// Tasks immediately dispatchable (ready, not parked) — the steal
    /// balancer's queue-length signal.
    pub fn ready_len(&self) -> usize {
        self.queue.ready_len()
    }

    /// Index cost charged for dispatching `task`: one location lookup per
    /// input for data-aware policies (the hints shipped with the order).
    /// The window scan's candidate scoring reuses those same per-input
    /// resolutions, so it is not double-charged — consistent with the
    /// §3.2.3 budget analysis, which counts lookups per *task*.
    fn hint_lookup_cost(&self, task: &Task) -> LookupCost {
        if !self.policy.is_data_aware() {
            return LookupCost::ZERO;
        }
        let mut cost = LookupCost::ZERO;
        for &obj in &task.inputs {
            cost.accumulate(self.index.lookup_cost(obj));
        }
        cost
    }

    /// Feed the replication manager the demand behind one dispatch: every
    /// input's location lookup, plus unmet demand when the chosen
    /// executor does not hold an input (it will read remotely).
    fn note_dispatch_demand(&mut self, task: &Task, executor: ExecutorId) {
        if !self.policy.is_data_aware() {
            return;
        }
        let Some(repl) = self.repl.as_mut() else {
            return;
        };
        for &obj in &task.inputs {
            repl.note_lookup(obj);
            if !self.index.holds(executor, obj) {
                repl.note_remote_placement(obj, executor);
            }
        }
    }

    /// Executor reports a completed task along with the cache changes it
    /// made while running it. Frees the slot, applies index updates, and
    /// releases any tasks parked on this executor.
    pub fn on_task_complete(
        &mut self,
        e: ExecutorId,
        _task: TaskId,
        cache_events: &[CacheEvent],
    ) {
        self.completed += 1;
        self.apply_cache_events(e, cache_events);
        self.queue.release(e);
        self.mark_idle(e);
    }

    /// Apply cache-change notifications from an executor (the "loosely
    /// coherent" index maintenance of §3.2.1 — also called periodically
    /// in live mode, not only at completion).
    pub fn apply_cache_events(&mut self, e: ExecutorId, events: &[CacheEvent]) {
        for ev in events {
            match ev {
                CacheEvent::Inserted(obj) => self.index.insert(*obj, e),
                CacheEvent::Evicted(obj) => self.index.remove(*obj, e),
            }
        }
    }

    fn mark_busy(&mut self, e: ExecutorId) {
        if let Some(s) = self.slots.get_mut(&e) {
            s.busy += 1;
            debug_assert!(s.busy <= s.capacity, "dispatched to a full executor");
            if s.busy == s.capacity {
                if let Ok(pos) = self.idle.binary_search(&e) {
                    self.idle.remove(pos);
                }
            }
        }
    }

    fn mark_idle(&mut self, e: ExecutorId) {
        // Executor may have been deregistered while running.
        if let Some(s) = self.slots.get_mut(&e) {
            s.busy = s.busy.saturating_sub(1);
            if let Err(pos) = self.idle.binary_search(&e) {
                self.idle.insert(pos, e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;
    use crate::coordinator::task::TaskId;
    use crate::storage::object::ObjectId;

    fn core(policy: DispatchPolicy) -> FalkonCore {
        let mut catalog = Catalog::new();
        for i in 0..10 {
            catalog.insert(ObjectId(i), 100);
        }
        let cfg = SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        };
        FalkonCore::new(&cfg, catalog)
    }

    #[test]
    fn dispatch_cycle_first_available() {
        let mut c = core(DispatchPolicy::FirstAvailable);
        c.register_executor(0);
        c.register_executor(1);
        for i in 0..3 {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i)]));
        }
        let orders = c.try_dispatch();
        assert_eq!(orders.len(), 2, "two idle executors, two dispatches");
        assert_eq!(c.queue_len(), 1);
        assert_eq!(c.idle_count(), 0);

        c.on_task_complete(orders[0].executor, orders[0].task.id, &[]);
        let orders2 = c.try_dispatch();
        assert_eq!(orders2.len(), 1);
        let (sub, disp, comp) = c.counters();
        assert_eq!((sub, disp, comp), (3, 3, 1));
    }

    #[test]
    fn cache_events_feed_index_and_scheduling() {
        let mut c = core(DispatchPolicy::MaxComputeUtil);
        c.register_executor(0);
        c.register_executor(1);
        // Task 0 runs on exec 0 and caches object 5.
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(5)]));
        let o = c.try_dispatch();
        assert_eq!(o[0].executor, 0);
        c.on_task_complete(0, TaskId(0), &[CacheEvent::Inserted(ObjectId(5))]);
        assert_eq!(c.index().locations(ObjectId(5)), &[0]);
        // Next task needing object 5 must be routed to exec 0.
        c.submit(Task::with_inputs(TaskId(1), vec![ObjectId(5)]));
        let o = c.try_dispatch();
        assert_eq!(o[0].executor, 0);
        assert_eq!(o[0].hints.get(&ObjectId(5)), Some(&vec![0]));
    }

    #[test]
    fn max_cache_hit_parks_and_releases() {
        let mut c = core(DispatchPolicy::MaxCacheHit);
        c.register_executor(0);
        c.register_executor(1);
        // Prime: object 5 cached on executor 0; executor 0 made busy.
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(5)]));
        let o = c.try_dispatch();
        assert_eq!(o.len(), 1);
        c.apply_cache_events(0, &[CacheEvent::Inserted(ObjectId(5))]);
        // While exec 0 is busy, a task needing obj 5 parks on it.
        c.submit(Task::with_inputs(TaskId(1), vec![ObjectId(5)]));
        let o = c.try_dispatch();
        assert!(o.is_empty(), "task should be parked");
        assert_eq!(c.queue_len(), 1);
        // Completion releases the parked task to executor 0.
        c.on_task_complete(0, TaskId(0), &[]);
        let o = c.try_dispatch();
        assert_eq!(o.len(), 1);
        assert_eq!(o[0].executor, 0);
        assert_eq!(o[0].task.id, TaskId(1));
    }

    #[test]
    fn no_executor_preserves_fifo() {
        let mut c = core(DispatchPolicy::FirstAvailable);
        c.submit(Task::with_inputs(TaskId(0), vec![]));
        c.submit(Task::with_inputs(TaskId(1), vec![]));
        assert!(c.try_dispatch().is_empty());
        c.register_executor(0);
        let o = c.try_dispatch();
        assert_eq!(o[0].task.id, TaskId(0), "FIFO order preserved");
    }

    #[test]
    fn multi_slot_executor_takes_capacity_tasks() {
        let mut c = core(DispatchPolicy::FirstAvailable);
        c.register_executor_with(0, 2); // dual-CPU node
        for i in 0..3 {
            c.submit(Task::with_inputs(TaskId(i), vec![]));
        }
        let o = c.try_dispatch();
        assert_eq!(o.len(), 2, "both CPU slots fill");
        assert_eq!(c.idle_count(), 0);
        c.on_task_complete(0, TaskId(0), &[]);
        assert_eq!(c.idle_count(), 1);
        let o = c.try_dispatch();
        assert_eq!(o.len(), 1);
    }

    #[test]
    fn orders_carry_index_cost_per_backend() {
        use crate::config::IndexConfig;
        use crate::index::IndexBackend;

        // Data-unaware policy: free regardless of backend.
        let mut c = core(DispatchPolicy::FirstAvailable);
        c.register_executor(0);
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(1)]));
        let o = c.try_dispatch();
        assert_eq!(o[0].cost, crate::index::LookupCost::ZERO);

        // Chord backend: every data-aware dispatch charges routed lookups.
        let mut catalog = Catalog::new();
        for i in 0..10 {
            catalog.insert(ObjectId(i), 100);
        }
        let cfg = SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            ..SchedulerConfig::default()
        };
        let chord_cfg = IndexConfig {
            backend: IndexBackend::Chord,
            ..IndexConfig::default()
        };
        let mut c = FalkonCore::with_index(&cfg, catalog, crate::index::build(&chord_cfg, 7));
        for e in 0..32 {
            c.register_executor(e);
        }
        assert_eq!(c.index().backend(), "chord");
        let mut total_lookups = 0u32;
        let mut any_hops = false;
        for i in 0..16 {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i % 10)]));
        }
        for o in c.try_dispatch() {
            total_lookups += o.cost.lookups;
            any_hops |= o.cost.hops > 0;
            let per_hop = chord_cfg.hop_latency_s + chord_cfg.hop_proc_s;
            assert!((o.cost.latency_s - o.cost.hops as f64 * per_hop).abs() < 1e-12);
        }
        assert_eq!(total_lookups, 16, "one lookup per single-input task");
        assert!(any_hops, "32-node overlay should route at least once");
    }

    #[test]
    fn replication_directives_flow_from_dispatch_demand() {
        use crate::config::ReplicationConfig;

        let mut c = core(DispatchPolicy::MaxComputeUtil);
        for e in 0..4 {
            c.register_executor(e);
        }
        // Enabled after the initial pool registered: the pool is warm
        // membership, not a join wave to pre-stage.
        c.enable_replication(&ReplicationConfig {
            enabled: true,
            max_replicas: 3,
            demand_threshold: 1.0,
            ewma_alpha: 1.0, // no smoothing: directives after one round
            ..ReplicationConfig::default()
        });
        assert!(c.replication_enabled());
        // Seed one copy of object 5 on executor 0 and drive demand at it.
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(5)]));
        let o = c.try_dispatch();
        c.on_task_complete(o[0].executor, TaskId(0), &[CacheEvent::Inserted(ObjectId(5))]);
        for i in 1..5 {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(5)]));
            for o in c.try_dispatch() {
                c.on_task_complete(o.executor, o.task.id, &[]);
            }
        }
        let dirs = c.poll_replication();
        assert_eq!(dirs.len(), 1, "hot object earns one copy per round");
        let crate::replication::ReplicaDirective::Stage {
            obj,
            src,
            dst,
            prestage,
        } = dirs[0]
        else {
            panic!("expected Stage, got {:?}", dirs[0]);
        };
        assert_eq!(obj, ObjectId(5));
        assert_eq!(src, 0, "only holder is the source");
        assert_ne!(dst, 0);
        assert!(!prestage, "demand growth, not a join warm-up");
        // Driver stages it: cache event + completion notification.
        c.apply_cache_events(dst, &[CacheEvent::Inserted(obj)]);
        c.replication_staged(obj, dst);
        assert_eq!(c.index().locations(ObjectId(5)).len(), 2);
        assert_eq!(c.replica_location_entries(), 1);
    }

    #[test]
    fn drop_directives_flow_through_the_core_on_decay() {
        use crate::config::ReplicationConfig;

        let mut c = core(DispatchPolicy::MaxComputeUtil);
        for e in 0..3 {
            c.register_executor(e);
        }
        c.enable_replication(&ReplicationConfig {
            enabled: true,
            // Cap = current copies: growth is impossible, so the decayed
            // object goes straight to teardown.
            max_replicas: 2,
            demand_threshold: 1.0,
            release_threshold: 0.5,
            ewma_alpha: 1.0,
            ..ReplicationConfig::default()
        });
        // Two copies of object 5 exist; demand never materializes, so the
        // manager tears the second copy down.
        c.apply_cache_events(0, &[CacheEvent::Inserted(ObjectId(5))]);
        c.apply_cache_events(2, &[CacheEvent::Inserted(ObjectId(5))]);
        // One lookup puts the object on the manager's radar (ewma 1.0 with
        // alpha 1.0), then silence decays it to 0 next round.
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(5)]));
        for o in c.try_dispatch() {
            c.on_task_complete(o.executor, o.task.id, &[]);
        }
        let _ = c.poll_replication(); // ewma 1.0: neither hot (cap) nor cold
        let dirs = c.poll_replication(); // ewma 0.0 < 0.5: teardown
        assert_eq!(
            dirs,
            vec![crate::replication::ReplicaDirective::Drop {
                obj: ObjectId(5),
                victim: 2
            }]
        );
        // Driver honors it: eviction event + confirmation.
        c.apply_cache_events(2, &[CacheEvent::Evicted(ObjectId(5))]);
        c.replication_dropped(ObjectId(5), 2);
        assert_eq!(c.index().locations(ObjectId(5)), &[0]);
        assert_eq!(c.replica_location_entries(), 0);
    }

    #[test]
    fn dispatch_into_appends_to_a_reused_buffer() {
        let mut c = core(DispatchPolicy::MaxComputeUtil);
        c.register_executor(0);
        c.register_executor(1);
        let mut buf = Vec::new();
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(1)]));
        c.dispatch_into(&mut buf);
        assert_eq!(buf.len(), 1);
        // Not cleared by the core: the caller owns the drain cadence.
        c.submit(Task::with_inputs(TaskId(1), vec![ObjectId(2)]));
        c.dispatch_into(&mut buf);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[0].task.id, TaskId(0));
        assert_eq!(buf[1].task.id, TaskId(1));
    }

    #[test]
    fn steal_and_absorb_keep_counters_exact() {
        let mut victim = core(DispatchPolicy::FirstAvailable);
        let mut thief = core(DispatchPolicy::FirstAvailable);
        for i in 0..4 {
            victim.submit(Task::with_inputs(TaskId(i), vec![]));
        }
        assert_eq!(victim.ready_len(), 4);
        let stolen = victim.steal_ready(2);
        assert_eq!(stolen.len(), 2);
        assert_eq!(victim.ready_len(), 2);
        for t in stolen {
            thief.absorb(t);
        }
        // Submit credit stays with the victim; the thief counted nothing.
        assert_eq!(victim.counters().0, 4);
        assert_eq!(thief.counters().0, 0);
        thief.register_executor(0);
        let o = thief.try_dispatch();
        assert_eq!(o.len(), 1, "stolen work actually dispatches");
        assert_eq!(o[0].task.id, TaskId(2), "youngest tasks moved, in order");
    }

    #[test]
    fn busy_fraction_tracks_slots() {
        let mut c = core(DispatchPolicy::FirstAvailable);
        c.register_executor_with(0, 2);
        assert_eq!(c.busy_fraction(0), 0.0);
        assert_eq!(c.busy_fraction(9), 0.0, "unknown executor reads idle");
        c.submit(Task::with_inputs(TaskId(0), vec![]));
        let o = c.try_dispatch();
        assert_eq!(o.len(), 1);
        assert!((c.busy_fraction(0) - 0.5).abs() < 1e-12);
        c.on_task_complete(0, TaskId(0), &[]);
        assert_eq!(c.busy_fraction(0), 0.0);
    }

    #[test]
    fn deregister_returns_orphans_and_requeues_parked() {
        let mut c = core(DispatchPolicy::MaxCacheHit);
        c.register_executor(0);
        c.submit(Task::with_inputs(TaskId(0), vec![ObjectId(1)]));
        let _ = c.try_dispatch();
        c.apply_cache_events(0, &[CacheEvent::Inserted(ObjectId(1))]);
        // Park a follow-up task on busy exec 0.
        c.submit(Task::with_inputs(TaskId(1), vec![ObjectId(1)]));
        assert!(c.try_dispatch().is_empty());
        // Executor dies.
        let orphans = c.deregister_executor(0);
        assert_eq!(orphans, vec![ObjectId(1)]);
        assert_eq!(c.executor_count(), 0);
        // Parked task survived, waiting for capacity.
        assert_eq!(c.queue_len(), 1);
        c.register_executor(7);
        let o = c.try_dispatch();
        assert_eq!(o.len(), 1);
        assert_eq!(o[0].executor, 7);
    }
}
