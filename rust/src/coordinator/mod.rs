//! The Falkon coordinator extended with data diffusion.
//!
//! * [`task`] — the task model (micro-benchmark and stacking tasks).
//! * [`core`] — the dispatcher core: wait queue, executor slots, central
//!   index, the data-aware dispatch loop, and the demand-driven
//!   [`crate::replication::ReplicationManager`] it feeds. Pure
//!   synchronous state shared by both execution drivers.
//! * [`sharded`] — N dispatcher shards behind one facade
//!   ([`ShardedCore`]), lifting the single-loop dispatch-rate ceiling.
//! * [`metrics`] — experiment counters (bytes by source, hit ratios,
//!   latencies) that the figures read out.
//!
//! ## The shard layer
//!
//! [`ShardedCore`] owns N independent [`FalkonCore`]s and adds three
//! mechanisms on top of them:
//!
//! * **Partitioning rule.** Executors split round-robin (`e % shards`):
//!   disjoint slices, so two shards can never race for one slot. Tasks
//!   route by the Chord owner of their *dominant input* — the input
//!   with the largest catalog size (first wins ties; inputless tasks
//!   hash by task id) — over a small ring keyed by the shard count.
//!   Tasks touching the same hot object therefore land on the same
//!   shard, and each shard's [`crate::index::DataIndex`] slice stays
//!   mostly local to the objects it schedules around.
//! * **Batching contract.** A wake-up drains the shard's ready queue
//!   *once* ([`FalkonCore::dispatch_into`]): the whole batch is scored
//!   against the idle set through one reused
//!   [`crate::scheduler::decision::BatchScratch`] and emitted as a
//!   `Vec<DispatchOrder>`. Batching moves allocations out of the hot
//!   path but never changes what a policy sees — at `shards = 1` the
//!   emitted orders are bit-for-bit those of the per-task dispatcher,
//!   for all four policies on both index backends (property-tested by
//!   `prop_sharded_equivalence`).
//! * **Steal protocol.** A shard with idle executors and an empty
//!   ready queue steals from the shard with the longest ready queue:
//!   at most half the victim's ready tasks, capped by the thief's idle
//!   slots and an adaptive [`sharded::StealSizer`] batch cap — an EWMA
//!   of the victim's post-steal residual backlog, clamped to `[1, 64]`
//!   and seeded at [`sharded::MAX_STEAL_BATCH`] — taken from the *back*
//!   of the victim's FIFO (youngest first) with relative order
//!   preserved. Parked tasks never move — they wait on a specific busy
//!   executor only the owning shard tracks. Submit credit stays with
//!   the victim so counters summed across shards remain exact.
//!
//! ## Two concurrency shapes
//!
//! The shard layer is used two ways, by channel topology:
//!
//! * **Single-owner facade** ([`ShardedCore`]) — one loop drives all
//!   shards; concurrency exists only *inside* a call (scoped threads in
//!   `try_dispatch`/`drain_all`). The simulator and the live driver at
//!   `--shards 1` use this shape: every executor report funnels into
//!   one channel owned by one coordinator loop.
//! * **Per-shard dispatcher threads** ([`sharded::ShardPlane`], from
//!   [`ShardedCore::into_plane`]) — each shard is a `Mutex<FalkonCore>`
//!   driven by its own long-lived loop with its *own* report channel:
//!   executors send completions to their owning shard's channel, so
//!   dispatch decisions, cache-event application, and index updates for
//!   shard *s* run concurrently with shard *t*. Cross-thread steals go
//!   through `ShardPlane::steal_into` — victim picked from lock-free
//!   published ready-length hints, victim lock only ever `try_lock`ed
//!   (back off on contention), so no thread blocks on a second shard
//!   lock and no deadlock cycle can form. A thin coordinator thread
//!   handles membership churn (register/release handoff messages to the
//!   owning shard loop), QoS harvest, and the final metrics merge. The
//!   live driver at `--shards >= 2` uses this shape — see
//!   [`crate::driver::live`] for the channel ownership map.
//!
//! The shard count comes from `coordinator.shards` in config (or
//! `--shards` on the CLI): 1 by default, N for a fixed count, and 0 for
//! auto — resolved at config-load time to one shard per available core
//! (`std::thread::available_parallelism`), so everything below this
//! layer always sees a concrete count ≥ 1.
//!
//! Execution drivers live in [`crate::driver`]: `sim` replays workloads
//! over the discrete-event testbed (per-shard dispatch wake-ups); `live`
//! runs real executor threads with real files and PJRT compute.

pub mod core;
pub mod metrics;
pub mod sharded;
pub mod task;

pub use self::core::{DispatchOrder, FalkonCore};
pub use metrics::{ByteSource, Metrics};
pub use sharded::{ShardPlane, ShardStats, ShardedCore, StealSizer};
pub use task::{Task, TaskId, TaskKind};
