//! The Falkon coordinator extended with data diffusion.
//!
//! * [`task`] — the task model (micro-benchmark and stacking tasks).
//! * [`core`] — the dispatcher core: wait queue, executor slots, central
//!   index, the data-aware dispatch loop, and the demand-driven
//!   [`crate::replication::ReplicationManager`] it feeds. Pure
//!   synchronous state shared by both execution drivers.
//! * [`metrics`] — experiment counters (bytes by source, hit ratios,
//!   latencies) that the figures read out.
//!
//! Execution drivers live in [`crate::driver`]: `sim` replays workloads
//! over the discrete-event testbed; `live` runs real executor threads
//! with real files and PJRT compute.

pub mod core;
pub mod metrics;
pub mod task;

pub use self::core::{DispatchOrder, FalkonCore};
pub use metrics::{ByteSource, Metrics};
pub use task::{Task, TaskId, TaskKind};
