//! Experiment metrics: byte accounting by source, cache statistics,
//! task latencies, and aggregate throughput.
//!
//! Figures 10–13 are direct readouts of this structure: cache-hit ratio
//! (Fig 10), time per stack (Fig 8/9/11), aggregate I/O throughput split
//! into local / cache-to-cache / GPFS (Fig 12), and per-task data
//! movement by source (Fig 13).

use crate::index::{ControlTraffic, LookupCost};
use crate::transfer::TransferClass;
use crate::util::stats::{Percentiles, Summary};

/// Where bytes came from (the three arrows in the architecture figure).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteSource {
    /// Node-local cache (disk read on the executor itself).
    Local,
    /// Peer executor cache (GridFTP-style cache-to-cache transfer).
    CacheToCache,
    /// Persistent storage (GPFS) read.
    Gpfs,
    /// Persistent storage (GPFS) write (task outputs).
    GpfsWrite,
}

/// One sample of the elastic executor pool, taken at every provisioner
/// evaluation — the allocated-vs-demand timeline behind the DRP figure.
/// Hit/miss counters are cumulative at sample time, so windowed hit
/// ratios (cache recovery after churn) fall out of consecutive samples.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PoolSample {
    /// Sample time (sim seconds / live seconds since start).
    pub t: f64,
    /// Executors registered and live.
    pub allocated: usize,
    /// Executors requested but not yet granted (allocation latency).
    pub pending: usize,
    /// Wait-queue length at sample time (the demand).
    pub queued: usize,
    /// Cumulative local cache hits.
    pub cache_hits: u64,
    /// Cumulative peer-cache hits.
    pub peer_hits: u64,
    /// Cumulative persistent-storage misses.
    pub gpfs_misses: u64,
    /// Replica location entries at sample time: cached copies beyond each
    /// object's first (index entries − distinct objects), so the timeline
    /// shows replication growing during bursts and decaying with
    /// eviction.
    pub replicas: usize,
    /// Cumulative staging transfers deferred by admission control at
    /// sample time — the timeline shows when background replication was
    /// held back to protect foreground bandwidth.
    pub staging_deferred: u64,
}

impl PoolSample {
    /// Local hit ratio of the accesses that happened between `prev` and
    /// this sample (NaN-free: 0.0 for an empty window).
    pub fn window_hit_ratio(&self, prev: &PoolSample) -> f64 {
        let hits = self.cache_hits.saturating_sub(prev.cache_hits);
        let total = hits
            + self.peer_hits.saturating_sub(prev.peer_hits)
            + self.gpfs_misses.saturating_sub(prev.gpfs_misses);
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// Mutable experiment counters.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Bytes read from the executor's own cache.
    pub local_bytes: u64,
    /// Bytes fetched from peer caches.
    pub c2c_bytes: u64,
    /// Bytes read from persistent storage.
    pub gpfs_bytes: u64,
    /// Bytes written to persistent storage.
    pub gpfs_write_bytes: u64,
    /// Cache hits (input resolved from own cache).
    pub cache_hits: u64,
    /// Cache misses served by a peer executor.
    pub peer_hits: u64,
    /// Cache misses served by persistent storage.
    pub gpfs_misses: u64,
    /// Tasks completed.
    pub tasks_done: u64,
    /// Tasks dispatched (should equal tasks_done at quiesce).
    pub tasks_dispatched: u64,
    /// Cache-location index lookups charged at dispatch time, plus
    /// executor-side re-resolutions of stale hints (§3.2.2).
    pub index_lookups: u64,
    /// Overlay routing hops behind those lookups (0 on the centralized
    /// backend).
    pub index_hops: u64,
    /// Total simulated index latency charged, seconds.
    pub index_cost_s: f64,
    /// Per-task end-to-end latency (submit → complete), seconds.
    pub task_latency: Summary,
    /// Stored task-latency sample for tail percentiles (the QoS figure's
    /// p99); fed together with `task_latency` by
    /// [`Metrics::note_task_latency`].
    pub task_latency_pcts: Percentiles,
    /// Per-task execution span (dispatch → complete), seconds.
    pub exec_latency: Summary,
    /// Time the first task was dispatched (experiment start).
    pub t_start: f64,
    /// Time the last task completed (experiment end).
    pub t_end: f64,
    /// Allocated-vs-demand samples, one per provisioner evaluation
    /// (empty when the pool is static).
    pub pool_timeline: Vec<PoolSample>,
    /// Allocation requests sent to the cluster provider.
    pub alloc_requests: u64,
    /// Executors that came up mid-run.
    pub executors_joined: u64,
    /// Executors released mid-run.
    pub executors_released: u64,
    /// Largest pool observed (static runs: the configured node count).
    pub peak_executors: usize,
    /// Executor-seconds spent fully idle while allocated (the cost of
    /// over-provisioning the idle-release timeout defends against).
    pub idle_exec_s: f64,
    /// Executor-seconds spent waiting on the cluster's allocation
    /// latency (requested but not yet usable — the DRP overhead).
    pub alloc_wait_s: f64,
    /// Replicas created by the replication manager (staged copies that
    /// actually entered a cache; organic peer-fetch copies not counted).
    pub replicas_created: u64,
    /// Bytes shipped by replication staging transfers (also accounted in
    /// `c2c_bytes` — staging rides the cache-to-cache path).
    pub replica_bytes_staged: u64,
    /// Local cache hits served by a manager-staged replica (demand the
    /// replication subsystem converted from peer/GPFS traffic).
    pub replica_hits: u64,
    /// Replica copies actively released on demand decay
    /// ([`crate::replication::ReplicaDirective::Drop`] honored by a
    /// driver; pressure evictions not counted).
    pub replicas_dropped: u64,
    /// Background staging transfers deferred by the transfer plane's
    /// admission controller (initial deferrals; re-deferral rounds while
    /// queued are not re-counted).
    pub staging_deferred: u64,
    /// Index control-plane stabilization messages (Chord membership
    /// maintenance; zero on the centralized backend).
    pub stabilization_msgs: u64,
    /// Lookups that misrouted through a stale finger between a
    /// membership change and the next repair round (their extra hop and
    /// latency are already inside `index_hops`/`index_cost_s`; this
    /// counts how many lookups paid it).
    pub index_misroutes: u64,
    /// Index-update control messages: routed insert/evict records plus
    /// the per-owner partition handoff a Chord membership change implies
    /// (zero on the centralized backend — updates mutate one in-process
    /// table).
    pub index_update_msgs: u64,
    /// Cross-shard work-steal operations performed by the sharded
    /// dispatcher (always 0 at `shards = 1`).
    pub dispatch_steals: u64,
    /// Tasks moved across shards by those steals.
    pub dispatch_stolen_tasks: u64,
    /// Non-empty dispatch batches emitted across all shards (one per
    /// wake-up that produced orders).
    pub dispatch_batches: u64,
    /// Dispatch batch-size histogram, buckets 1, 2–3, 4–7, 8–15,
    /// 16–31, 32+.
    pub dispatch_batch_hist: [u64; 6],
    /// Per-shard ready-queue depth at harvest time (one entry per
    /// dispatcher shard; a single entry at `shards = 1`).
    pub shard_queue_depths: Vec<usize>,
    /// Wall-clock seconds dispatcher loops spent doing work (applying
    /// reports, stealing, deciding, sending) rather than blocked on
    /// their report channel — summed across per-shard loops, so it can
    /// exceed the run's span. Live driver only; 0 in the simulator.
    /// Wall-clock derived, so excluded from [`Metrics::checksum`].
    pub dispatch_loop_busy_s: f64,
    /// Largest report burst (completion/staging/drop messages drained
    /// in one wake-up) per live dispatcher loop — one entry per shard
    /// loop at `--shards >= 2`, empty elsewhere. A proxy for report
    /// queue depth: deep bursts mean the loop was the bottleneck.
    pub report_queue_peaks: Vec<u64>,
    /// Bytes moved by transfer-plane data movements, per
    /// [`TransferClass`] (indexed by [`TransferClass::index`]:
    /// foreground, staging, prestage).
    pub class_bytes: [u64; 3],
    /// Cumulative transfer time per class, seconds (each movement's
    /// start→finish span summed; movements overlap, so this is transfer
    /// work, not wall time). `class_bytes / class_xfer_s` is the class's
    /// mean achieved rate — the readout that shows weighted shares
    /// actually throttling background movement.
    pub class_xfer_s: [f64; 3],
    /// Bytes that crossed a WAN link between federation sites (also
    /// accounted in their per-source counters; 0 without `[[site]]`
    /// tables). The `fig_federation` cost axis.
    pub wan_bytes: u64,
    /// Tasks the federation scheduler placed at a site other than their
    /// origin (ship-task decisions; 0 without federation).
    pub cross_site_tasks: u64,
    /// Per-site allocated-pool samples (one inner timeline per site,
    /// sampled alongside `pool_timeline`; empty without federation or
    /// with a static pool).
    pub site_pool_timeline: Vec<Vec<PoolSample>>,
}

impl Metrics {
    /// Fresh counters.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record bytes moved from a source.
    pub fn add_bytes(&mut self, source: ByteSource, bytes: u64) {
        match source {
            ByteSource::Local => self.local_bytes += bytes,
            ByteSource::CacheToCache => self.c2c_bytes += bytes,
            ByteSource::Gpfs => self.gpfs_bytes += bytes,
            ByteSource::GpfsWrite => self.gpfs_write_bytes += bytes,
        }
    }

    /// Record the index cost charged for one dispatch order.
    pub fn add_index_cost(&mut self, cost: LookupCost) {
        self.index_lookups += cost.lookups as u64;
        self.index_hops += cost.hops as u64;
        self.index_cost_s += cost.latency_s;
    }

    /// Fold harvested index control-plane traffic into the run totals:
    /// stabilization messages and misroute counts, and the stabilization
    /// latency lands in `index_cost_s` (misroute latency already arrived
    /// through the affected lookups' own costs, so nothing is
    /// double-charged).
    pub fn add_control_traffic(&mut self, t: ControlTraffic) {
        self.stabilization_msgs += t.stabilization_msgs;
        self.index_misroutes += t.misroutes;
        self.index_update_msgs += t.update_msgs;
        self.index_cost_s += t.latency_s;
    }

    /// Fold the sharded dispatcher's counters into the run totals
    /// (drivers call this once at run end with
    /// [`crate::coordinator::ShardStats`]).
    pub fn harvest_shard_stats(&mut self, stats: &crate::coordinator::ShardStats) {
        self.dispatch_steals += stats.steals;
        self.dispatch_stolen_tasks += stats.stolen_tasks;
        self.dispatch_batches += stats.batches;
        for (dst, src) in self.dispatch_batch_hist.iter_mut().zip(stats.batch_hist) {
            *dst += src;
        }
        self.shard_queue_depths = stats.queue_depths.clone();
    }

    /// Record one transfer-plane data movement: `bytes` of `class` that
    /// took `secs` from start to finish.
    pub fn note_class_transfer(&mut self, class: TransferClass, bytes: u64, secs: f64) {
        let i = class.index();
        self.class_bytes[i] += bytes;
        self.class_xfer_s[i] += secs.max(0.0);
    }

    /// Mean achieved rate of one transfer class, bits/sec (0 before any
    /// transfer of that class finished).
    pub fn class_mean_rate_bps(&self, class: TransferClass) -> f64 {
        let i = class.index();
        if self.class_xfer_s[i] <= 0.0 {
            0.0
        } else {
            self.class_bytes[i] as f64 * 8.0 / self.class_xfer_s[i]
        }
    }

    /// Record one task's end-to-end latency (Summary + stored sample for
    /// tail percentiles).
    pub fn note_task_latency(&mut self, secs: f64) {
        self.task_latency.add(secs);
        self.task_latency_pcts.add(secs);
    }

    /// p50 (median) of per-task end-to-end latency (NaN before the
    /// first task).
    pub fn task_latency_p50(&mut self) -> f64 {
        self.task_latency_pcts.quantile(0.50)
    }

    /// p90 of per-task end-to-end latency (NaN before the first task).
    pub fn task_latency_p90(&mut self) -> f64 {
        self.task_latency_pcts.quantile(0.90)
    }

    /// p99 of per-task end-to-end latency (NaN before the first task).
    pub fn task_latency_p99(&mut self) -> f64 {
        self.task_latency_pcts.quantile(0.99)
    }

    /// Record one elastic-pool sample (hit counters are captured from
    /// the current totals) and keep the pool peak up to date. `replicas`
    /// is the index's current count of extra copies (entries − objects).
    pub fn sample_pool(
        &mut self,
        t: f64,
        allocated: usize,
        pending: usize,
        queued: usize,
        replicas: usize,
    ) {
        self.peak_executors = self.peak_executors.max(allocated);
        self.pool_timeline.push(PoolSample {
            t,
            allocated,
            pending,
            queued,
            cache_hits: self.cache_hits,
            peer_hits: self.peer_hits,
            gpfs_misses: self.gpfs_misses,
            replicas,
            staging_deferred: self.staging_deferred,
        });
    }

    /// Record one elastic-pool sample for a single federation site
    /// (pool shape only; cumulative hit counters are run-global and the
    /// demand split lives in the combined `pool_timeline`).
    pub fn sample_site_pool(&mut self, site: usize, t: f64, allocated: usize, pending: usize, queued: usize) {
        if self.site_pool_timeline.len() <= site {
            self.site_pool_timeline.resize_with(site + 1, Vec::new);
        }
        self.site_pool_timeline[site].push(PoolSample {
            t,
            allocated,
            pending,
            queued,
            cache_hits: self.cache_hits,
            peer_hits: self.peer_hits,
            gpfs_misses: self.gpfs_misses,
            replicas: 0,
            staging_deferred: self.staging_deferred,
        });
    }

    /// Record how one input was resolved.
    pub fn add_resolution(&mut self, source: ByteSource) {
        match source {
            ByteSource::Local => self.cache_hits += 1,
            ByteSource::CacheToCache => self.peer_hits += 1,
            ByteSource::Gpfs => self.gpfs_misses += 1,
            ByteSource::GpfsWrite => {}
        }
    }

    /// Cache-hit ratio counting only *local* hits (the paper's Fig 10
    /// metric: fraction of accesses served by the executor's own cache).
    pub fn local_hit_ratio(&self) -> f64 {
        let total = self.cache_hits + self.peer_hits + self.gpfs_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Hit ratio counting local + cache-to-cache (any cached copy).
    pub fn any_hit_ratio(&self) -> f64 {
        let total = self.cache_hits + self.peer_hits + self.gpfs_misses;
        if total == 0 {
            0.0
        } else {
            (self.cache_hits + self.peer_hits) as f64 / total as f64
        }
    }

    /// Experiment wall-clock span, seconds.
    pub fn span_secs(&self) -> f64 {
        (self.t_end - self.t_start).max(0.0)
    }

    /// Total bytes read from any source.
    pub fn total_read_bytes(&self) -> u64 {
        self.local_bytes + self.c2c_bytes + self.gpfs_bytes
    }

    /// Aggregate read throughput over the experiment span, bits/sec.
    pub fn read_throughput_bps(&self) -> f64 {
        crate::util::units::throughput_bps(self.total_read_bytes(), self.span_secs())
    }

    /// Aggregate read+write throughput over the span, bits/sec.
    pub fn rw_throughput_bps(&self) -> f64 {
        crate::util::units::throughput_bps(
            self.total_read_bytes() + self.gpfs_write_bytes,
            self.span_secs(),
        )
    }

    /// Tasks per second over the experiment span.
    pub fn task_rate(&self) -> f64 {
        let span = self.span_secs();
        if span <= 0.0 {
            0.0
        } else {
            self.tasks_done as f64 / span
        }
    }

    /// Fold another site's metrics into this one (the federated driver
    /// merges per-site metrics in fixed site order, so the result is
    /// deterministic and thread-count independent).
    ///
    /// Counters and byte totals sum; latency estimators merge; the
    /// experiment span is the earliest dispatch to the latest
    /// completion across sites that ran tasks; `pool_timeline`s merge
    /// by carrying each side forward to the union of sample times and
    /// summing; `site_pool_timeline` slots concatenate by index (each
    /// site only ever writes its own); `peak_executors` sums, an upper
    /// bound (site peaks need not coincide).
    pub fn merge(&mut self, other: &Metrics) {
        if other.tasks_dispatched > 0 {
            self.t_start = if self.tasks_dispatched > 0 {
                self.t_start.min(other.t_start)
            } else {
                other.t_start
            };
        }
        self.t_end = self.t_end.max(other.t_end);
        self.local_bytes += other.local_bytes;
        self.c2c_bytes += other.c2c_bytes;
        self.gpfs_bytes += other.gpfs_bytes;
        self.gpfs_write_bytes += other.gpfs_write_bytes;
        self.cache_hits += other.cache_hits;
        self.peer_hits += other.peer_hits;
        self.gpfs_misses += other.gpfs_misses;
        self.tasks_done += other.tasks_done;
        self.tasks_dispatched += other.tasks_dispatched;
        self.index_lookups += other.index_lookups;
        self.index_hops += other.index_hops;
        self.index_cost_s += other.index_cost_s;
        self.task_latency.merge(&other.task_latency);
        self.task_latency_pcts.merge(&other.task_latency_pcts);
        self.exec_latency.merge(&other.exec_latency);
        self.pool_timeline = merge_timelines(&self.pool_timeline, &other.pool_timeline);
        self.alloc_requests += other.alloc_requests;
        self.executors_joined += other.executors_joined;
        self.executors_released += other.executors_released;
        self.peak_executors += other.peak_executors;
        self.idle_exec_s += other.idle_exec_s;
        self.alloc_wait_s += other.alloc_wait_s;
        self.replicas_created += other.replicas_created;
        self.replica_bytes_staged += other.replica_bytes_staged;
        self.replica_hits += other.replica_hits;
        self.replicas_dropped += other.replicas_dropped;
        self.staging_deferred += other.staging_deferred;
        self.stabilization_msgs += other.stabilization_msgs;
        self.index_misroutes += other.index_misroutes;
        self.index_update_msgs += other.index_update_msgs;
        self.dispatch_steals += other.dispatch_steals;
        self.dispatch_stolen_tasks += other.dispatch_stolen_tasks;
        self.dispatch_batches += other.dispatch_batches;
        for (dst, src) in self.dispatch_batch_hist.iter_mut().zip(other.dispatch_batch_hist) {
            *dst += src;
        }
        self.shard_queue_depths.extend_from_slice(&other.shard_queue_depths);
        self.dispatch_loop_busy_s += other.dispatch_loop_busy_s;
        self.report_queue_peaks.extend_from_slice(&other.report_queue_peaks);
        for i in 0..3 {
            self.class_bytes[i] += other.class_bytes[i];
            self.class_xfer_s[i] += other.class_xfer_s[i];
        }
        self.wan_bytes += other.wan_bytes;
        self.cross_site_tasks += other.cross_site_tasks;
        for (site, tl) in other.site_pool_timeline.iter().enumerate() {
            if tl.is_empty() {
                continue;
            }
            if self.site_pool_timeline.len() <= site {
                self.site_pool_timeline.resize_with(site + 1, Vec::new);
            }
            self.site_pool_timeline[site].extend_from_slice(tl);
        }
    }

    /// Order-sensitive digest of the run's outcome counters (FNV-1a
    /// over every counter and f64 bit pattern that is a function of
    /// simulated — not wall-clock — time). Serial-vs-parallel
    /// equivalence tests compare these: identical checksums mean
    /// identical byte accounting, hit profiles, latency sums, spans,
    /// and timeline shapes.
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut fold = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        for x in [
            self.local_bytes,
            self.c2c_bytes,
            self.gpfs_bytes,
            self.gpfs_write_bytes,
            self.cache_hits,
            self.peer_hits,
            self.gpfs_misses,
            self.tasks_done,
            self.tasks_dispatched,
            self.index_lookups,
            self.index_hops,
            self.index_cost_s.to_bits(),
            self.task_latency.count(),
            self.task_latency.sum().to_bits(),
            self.exec_latency.count(),
            self.exec_latency.sum().to_bits(),
            self.t_start.to_bits(),
            self.t_end.to_bits(),
            self.pool_timeline.len() as u64,
            self.alloc_requests,
            self.executors_joined,
            self.executors_released,
            self.peak_executors as u64,
            self.idle_exec_s.to_bits(),
            self.alloc_wait_s.to_bits(),
            self.replicas_created,
            self.replica_bytes_staged,
            self.replica_hits,
            self.replicas_dropped,
            self.staging_deferred,
            self.stabilization_msgs,
            self.index_misroutes,
            self.index_update_msgs,
            self.dispatch_steals,
            self.dispatch_stolen_tasks,
            self.dispatch_batches,
            self.wan_bytes,
            self.cross_site_tasks,
        ] {
            fold(x);
        }
        for b in self.dispatch_batch_hist {
            fold(b);
        }
        for i in 0..3 {
            fold(self.class_bytes[i]);
            fold(self.class_xfer_s[i].to_bits());
        }
        for s in &self.pool_timeline {
            fold(s.t.to_bits());
            fold(s.allocated as u64);
            fold(s.queued as u64);
        }
        h
    }
}

/// Union-merge two pool timelines: at each distinct sample time, carry
/// each side forward to that time (zero before its first sample) and
/// sum the pool shapes and cumulative counters. Associative, so
/// pairwise merging across N sites equals the N-way merge.
fn merge_timelines(a: &[PoolSample], b: &[PoolSample]) -> Vec<PoolSample> {
    if a.is_empty() {
        return b.to_vec();
    }
    if b.is_empty() {
        return a.to_vec();
    }
    let zero = PoolSample::default();
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    let (mut last_a, mut last_b) = (zero, zero);
    while i < a.len() || j < b.len() {
        let ta = a.get(i).map_or(f64::INFINITY, |s| s.t);
        let tb = b.get(j).map_or(f64::INFINITY, |s| s.t);
        let t = ta.min(tb);
        if ta <= t {
            last_a = a[i];
            i += 1;
        }
        if tb <= t {
            last_b = b[j];
            j += 1;
        }
        out.push(PoolSample {
            t,
            allocated: last_a.allocated + last_b.allocated,
            pending: last_a.pending + last_b.pending,
            queued: last_a.queued + last_b.queued,
            cache_hits: last_a.cache_hits + last_b.cache_hits,
            peer_hits: last_a.peer_hits + last_b.peer_hits,
            gpfs_misses: last_a.gpfs_misses + last_b.gpfs_misses,
            replicas: last_a.replicas + last_b.replicas,
            staging_deferred: last_a.staging_deferred + last_b.staging_deferred,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting_by_source() {
        let mut m = Metrics::new();
        m.add_bytes(ByteSource::Local, 100);
        m.add_bytes(ByteSource::CacheToCache, 50);
        m.add_bytes(ByteSource::Gpfs, 25);
        m.add_bytes(ByteSource::GpfsWrite, 10);
        assert_eq!(m.total_read_bytes(), 175);
        assert_eq!(m.gpfs_write_bytes, 10);
    }

    #[test]
    fn hit_ratios() {
        let mut m = Metrics::new();
        for _ in 0..6 {
            m.add_resolution(ByteSource::Local);
        }
        for _ in 0..2 {
            m.add_resolution(ByteSource::CacheToCache);
        }
        for _ in 0..2 {
            m.add_resolution(ByteSource::Gpfs);
        }
        assert!((m.local_hit_ratio() - 0.6).abs() < 1e-12);
        assert!((m.any_hit_ratio() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn throughput_over_span() {
        let mut m = Metrics::new();
        m.t_start = 10.0;
        m.t_end = 18.0;
        m.add_bytes(ByteSource::Gpfs, 1_000_000_000);
        // 1 GB in 8 s = 1 Gb/s.
        assert!((m.read_throughput_bps() - 1e9).abs() < 1.0);
    }

    #[test]
    fn empty_ratios_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.local_hit_ratio(), 0.0);
        assert_eq!(m.task_rate(), 0.0);
    }

    #[test]
    fn control_traffic_and_tail_latency_account() {
        let mut m = Metrics::new();
        m.add_control_traffic(ControlTraffic {
            stabilization_msgs: 16,
            misroutes: 3,
            update_msgs: 5,
            latency_s: 0.004,
        });
        m.add_control_traffic(ControlTraffic::default());
        assert_eq!(m.stabilization_msgs, 16);
        assert_eq!(m.index_misroutes, 3);
        assert_eq!(m.index_update_msgs, 5);
        assert!((m.index_cost_s - 0.004).abs() < 1e-15);
        for i in 1..=100 {
            m.note_task_latency(i as f64);
        }
        assert_eq!(m.task_latency.count(), 100);
        assert!((m.task_latency_p50() - 50.5).abs() < 1e-9);
        assert!((m.task_latency_p90() - 90.1).abs() < 1e-9);
        assert!((m.task_latency_p99() - 99.01).abs() < 1e-9);
    }

    #[test]
    fn shard_stats_fold_into_run_totals() {
        let mut m = Metrics::new();
        let stats = crate::coordinator::ShardStats {
            steals: 3,
            stolen_tasks: 7,
            batches: 12,
            batch_hist: [4, 3, 2, 1, 1, 1],
            queue_depths: vec![5, 0],
        };
        m.harvest_shard_stats(&stats);
        m.harvest_shard_stats(&stats);
        assert_eq!(m.dispatch_steals, 6);
        assert_eq!(m.dispatch_stolen_tasks, 14);
        assert_eq!(m.dispatch_batches, 24);
        assert_eq!(m.dispatch_batch_hist, [8, 6, 4, 2, 2, 2]);
        // Depths are a snapshot, not a sum: the last harvest wins.
        assert_eq!(m.shard_queue_depths, vec![5, 0]);
    }

    #[test]
    fn per_class_transfer_accounting_and_mean_rate() {
        let mut m = Metrics::new();
        // 1 MB of foreground in 1 s = 8 Mb/s; 1 MB of staging in 4 s =
        // 2 Mb/s (a throttled class reads out slower, same bytes).
        m.note_class_transfer(TransferClass::Foreground, 1_000_000, 1.0);
        m.note_class_transfer(TransferClass::Staging, 500_000, 2.0);
        m.note_class_transfer(TransferClass::Staging, 500_000, 2.0);
        assert_eq!(m.class_bytes[TransferClass::Foreground.index()], 1_000_000);
        assert_eq!(m.class_bytes[TransferClass::Staging.index()], 1_000_000);
        assert!((m.class_mean_rate_bps(TransferClass::Foreground) - 8e6).abs() < 1.0);
        assert!((m.class_mean_rate_bps(TransferClass::Staging) - 2e6).abs() < 1.0);
        assert_eq!(m.class_mean_rate_bps(TransferClass::Prestage), 0.0);
    }

    #[test]
    fn site_pool_timelines_grow_independently() {
        let mut m = Metrics::new();
        m.sample_site_pool(1, 0.0, 4, 0, 2);
        m.sample_site_pool(0, 0.0, 8, 1, 0);
        m.sample_site_pool(1, 5.0, 3, 0, 0);
        assert_eq!(m.site_pool_timeline.len(), 2);
        assert_eq!(m.site_pool_timeline[0].len(), 1);
        assert_eq!(m.site_pool_timeline[1].len(), 2);
        assert_eq!(m.site_pool_timeline[1][1].allocated, 3);
        // Site samples don't disturb the combined peak.
        assert_eq!(m.peak_executors, 0);
    }

    #[test]
    fn metrics_merge_sums_counters_and_unions_timelines() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.tasks_dispatched = 2;
        a.tasks_done = 2;
        a.t_start = 1.0;
        a.t_end = 9.0;
        b.tasks_dispatched = 3;
        b.tasks_done = 3;
        b.t_start = 0.5;
        b.t_end = 7.0;
        a.add_bytes(ByteSource::Local, 10);
        b.add_bytes(ByteSource::Gpfs, 4);
        a.note_task_latency(1.0);
        b.note_task_latency(3.0);
        a.sample_pool(0.0, 2, 0, 1, 0);
        b.sample_pool(0.0, 3, 0, 0, 0);
        b.sample_pool(5.0, 4, 0, 2, 0);
        b.sample_site_pool(1, 5.0, 4, 0, 2);
        let before = a.checksum();
        a.merge(&b);
        assert_ne!(a.checksum(), before);
        assert_eq!(a.tasks_done, 5);
        assert_eq!(a.local_bytes, 10);
        assert_eq!(a.gpfs_bytes, 4);
        assert!((a.t_start - 0.5).abs() < 1e-12, "earliest dispatch wins");
        assert!((a.t_end - 9.0).abs() < 1e-12, "latest completion wins");
        assert_eq!(a.task_latency.count(), 2);
        // Timeline union at times {0.0, 5.0}; at 5.0 side A carries its
        // t=0 sample forward.
        assert_eq!(a.pool_timeline.len(), 2);
        assert_eq!(a.pool_timeline[0].allocated, 5);
        assert_eq!(a.pool_timeline[1].allocated, 6);
        assert_eq!(a.site_pool_timeline[1].len(), 1);
    }

    #[test]
    fn merge_skips_t_start_of_idle_sites() {
        // A site that never dispatched keeps its default t_start = 0.0,
        // which must not drag the merged experiment start to zero.
        let mut a = Metrics::new();
        a.tasks_dispatched = 1;
        a.tasks_done = 1;
        a.t_start = 4.0;
        a.t_end = 6.0;
        let idle = Metrics::new();
        a.merge(&idle);
        assert!((a.t_start - 4.0).abs() < 1e-12);
        let mut fresh = Metrics::new();
        fresh.merge(&a);
        assert!((fresh.t_start - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pool_samples_track_peak_and_windowed_hits() {
        let mut m = Metrics::new();
        m.sample_pool(0.0, 2, 1, 10, 0);
        for _ in 0..3 {
            m.add_resolution(ByteSource::Gpfs);
        }
        m.sample_pool(5.0, 6, 0, 4, 2);
        for _ in 0..4 {
            m.add_resolution(ByteSource::Local);
        }
        m.add_resolution(ByteSource::Gpfs);
        m.sample_pool(10.0, 6, 0, 0, 5);
        assert_eq!(m.peak_executors, 6);
        assert_eq!(m.pool_timeline.len(), 3);
        assert_eq!(m.pool_timeline[2].replicas, 5);
        assert_eq!(m.pool_timeline[2].staging_deferred, 0);
        let w1 = m.pool_timeline[1].window_hit_ratio(&m.pool_timeline[0]);
        let w2 = m.pool_timeline[2].window_hit_ratio(&m.pool_timeline[1]);
        assert_eq!(w1, 0.0, "first window: all misses");
        assert!((w2 - 0.8).abs() < 1e-12, "second window: 4/5 local");
    }
}
