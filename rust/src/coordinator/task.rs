//! Task model.
//!
//! A task names the data objects it needs, the work it performs, and how
//! many bytes it writes back. The two kinds mirror the paper's two
//! evaluation campaigns: synthetic read/read+write micro-benchmark tasks
//! (§4.3) and image-stacking tasks (§5).

use crate::storage::object::ObjectId;

/// Globally unique task identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "task{}", self.0)
    }
}

/// What a task computes once its data is local.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskKind {
    /// Micro-benchmark task: `cpu_s` seconds of compute (usually ~0).
    Synthetic {
        /// Pure CPU time, seconds.
        cpu_s: f64,
    },
    /// Image stacking: extract an ROI from the input file and coadd.
    /// `stack_depth` is the number of cutouts the logical stacking
    /// combines (= workload locality; affects only the PJRT variant
    /// chosen in live mode — sim mode charges the calibrated constant).
    Stack {
        /// Cutouts per stacking operation.
        stack_depth: u32,
    },
}

/// A unit of dispatchable work.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Unique id (submission order).
    pub id: TaskId,
    /// Data objects (files) the task reads.
    pub inputs: Vec<ObjectId>,
    /// Bytes written back to persistent storage (0 = nothing).
    pub output_bytes: u64,
    /// The compute performed.
    pub kind: TaskKind,
}

impl Task {
    /// A data-only task (no compute, no output) over the given inputs —
    /// the §4.3 "read" micro-benchmark shape.
    pub fn with_inputs(id: TaskId, inputs: Vec<ObjectId>) -> Task {
        Task {
            id,
            inputs,
            output_bytes: 0,
            kind: TaskKind::Synthetic { cpu_s: 0.0 },
        }
    }

    /// A read+write micro-benchmark task.
    pub fn read_write(id: TaskId, input: ObjectId, output_bytes: u64) -> Task {
        Task {
            id,
            inputs: vec![input],
            output_bytes,
            kind: TaskKind::Synthetic { cpu_s: 0.0 },
        }
    }

    /// An image-stacking task over one file.
    pub fn stacking(id: TaskId, file: ObjectId, stack_depth: u32, output_bytes: u64) -> Task {
        Task {
            id,
            inputs: vec![file],
            output_bytes,
            kind: TaskKind::Stack { stack_depth },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let t = Task::with_inputs(TaskId(1), vec![ObjectId(5)]);
        assert_eq!(t.output_bytes, 0);
        let t = Task::read_write(TaskId(2), ObjectId(5), 100);
        assert_eq!(t.output_bytes, 100);
        let t = Task::stacking(TaskId(3), ObjectId(5), 30, 40_000);
        assert!(matches!(t.kind, TaskKind::Stack { stack_depth: 30 }));
    }

    #[test]
    fn display() {
        assert_eq!(TaskId(7).to_string(), "task7");
    }
}
