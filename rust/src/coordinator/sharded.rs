//! Sharded dispatch: N [`FalkonCore`] shards behind one facade, with
//! cross-shard work stealing.
//!
//! One dispatcher loop is the ceiling on dispatch throughput once
//! data-aware scheduling makes every decision index-dependent (the
//! paper's companion work measures Falkon's dispatch rate — not the
//! network — as the bottleneck). [`ShardedCore`] removes that ceiling
//! while keeping the per-shard logic byte-identical to the single-core
//! dispatcher:
//!
//! * **Partitioning** — executors split round-robin (`e % shards`), so
//!   each shard owns a disjoint slice of the pool and two shards can
//!   never race for the same slot. Tasks route by the *Chord owner of
//!   their dominant input* (largest catalog size, first on ties;
//!   inputless tasks hash by task id): a small [`ChordRing`] over the
//!   shard count, so the objects a shard schedules around — and hence
//!   its [`DataIndex`] slice — stay mostly local to it.
//! * **Batching** — every wake-up drains the shard's ready queue once
//!   through [`FalkonCore::dispatch_into`], scoring the whole batch
//!   against the idle set with one reused scratch and emitting a
//!   `Vec<DispatchOrder>`, instead of deciding task-by-task with fresh
//!   allocations.
//! * **Stealing** — a shard with idle executors and an empty ready
//!   queue steals a bounded batch (at most half the victim's ready
//!   queue, capped by an adaptive [`StealSizer`] that starts at
//!   [`MAX_STEAL_BATCH`]) from the shard with the longest ready queue.
//!   Only *ready* tasks move; parked (policy-delayed) tasks wait on a
//!   specific busy executor that only the owning shard tracks.
//!
//! At `shards = 1` everything degrades to exactly the single-core
//! dispatcher: one shard owns all executors, every task routes to it,
//! stealing is impossible, and the emitted orders are bit-for-bit the
//! ones [`FalkonCore::try_dispatch`] would produce (property-tested in
//! `tests/proptest_invariants.rs::prop_sharded_equivalence`).
//!
//! ## Cross-thread use: [`ShardPlane`]
//!
//! [`ShardedCore`] is a single-owner facade: one loop calls into it and
//! the shards only run concurrently inside scoped calls like
//! [`ShardedCore::try_dispatch`]. The live driver's per-shard dispatcher
//! threads need the opposite shape — each shard driven by its *own*
//! long-lived thread — so [`ShardedCore::into_plane`] decomposes the
//! core into a [`ShardPlane`]: one `Mutex<FalkonCore>` per shard plus
//! lock-free published hints (ready-queue length, executor count) that
//! let a starved shard pick a steal victim without touching the
//! victim's lock. The steal protocol is deadlock-free by construction:
//! a thief holds its own core and only ever `try_lock`s the victim —
//! no thread blocks on a second shard lock, so no lock cycle can form.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::cache::store::CacheEvent;
use crate::config::{ReplicationConfig, SchedulerConfig};
use crate::coordinator::core::{DispatchOrder, FalkonCore};
use crate::coordinator::task::{Task, TaskId};
use crate::index::central::{CentralIndex, ExecutorId};
use crate::index::dht::ChordRing;
use crate::index::{ControlTraffic, DataIndex, LookupCost};
use crate::replication::ReplicaDirective;
use crate::scheduler::DispatchPolicy;
use crate::storage::object::{Catalog, ObjectId};

/// Initial cap on tasks moved per steal: enough to refill a starved
/// shard's idle slots without oscillating work between shards. The
/// effective cap adapts from there — see [`StealSizer`].
pub const MAX_STEAL_BATCH: usize = 8;

/// Hard ceiling of the adaptive steal-batch cap.
const STEAL_BATCH_CEIL: usize = 64;

/// EWMA smoothing factor for the post-steal residual signal.
const STEAL_EWMA_ALPHA: f64 = 0.25;

/// Adaptive steal-batch sizing from measured queue imbalance.
///
/// After each steal the victim's *residual* ready-queue length (what
/// the bounded batch left behind) is the post-steal imbalance between
/// victim and thief: the thief drains its batch immediately, so any
/// leftover backlog means the batch was too small to rebalance. An
/// EWMA of that residual drives the next steal's cap — deep persistent
/// backlogs grow batches toward [`STEAL_BATCH_CEIL`] (64), clean
/// steals shrink them toward 1 — clamped to `[1, 64]`, starting at
/// [`MAX_STEAL_BATCH`].
#[derive(Debug, Clone)]
pub struct StealSizer {
    /// EWMA of the victim's post-steal residual ready-queue length.
    ewma: f64,
    cap: usize,
}

impl Default for StealSizer {
    fn default() -> Self {
        StealSizer::new()
    }
}

impl StealSizer {
    /// Fresh sizer: the cap starts at [`MAX_STEAL_BATCH`].
    pub fn new() -> StealSizer {
        StealSizer {
            ewma: MAX_STEAL_BATCH as f64,
            cap: MAX_STEAL_BATCH,
        }
    }

    /// Current steal-batch cap, in `[1, 64]`.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Record one steal: the victim had `victim_ready` ready tasks and
    /// `stolen` of them moved.
    pub fn record(&mut self, victim_ready: usize, stolen: usize) {
        let residual = victim_ready.saturating_sub(stolen) as f64;
        self.ewma = STEAL_EWMA_ALPHA * residual + (1.0 - STEAL_EWMA_ALPHA) * self.ewma;
        self.cap = (self.ewma.ceil() as usize).clamp(1, STEAL_BATCH_CEIL);
    }
}

/// Ready-task backlog at which [`ShardedCore::try_dispatch`] dispatches
/// shards on scoped threads instead of sequentially: below this the
/// spawn overhead costs more than the parallelism buys.
const PARALLEL_READY_MIN: usize = 32;

/// Fixed seed for the task-partitioning ring: the task → shard mapping
/// is part of the dispatcher's deterministic replay surface, so it must
/// not vary with the run seed.
const PARTITION_SEED: u64 = 0x5EED_D1FF;

/// Steal/batch counters a driver harvests into
/// [`crate::coordinator::metrics::Metrics`] at run end.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Steal operations performed (one per victim→thief batch).
    pub steals: u64,
    /// Tasks moved across shards by stealing.
    pub stolen_tasks: u64,
    /// Non-empty dispatch batches emitted.
    pub batches: u64,
    /// Batch-size histogram over non-empty batches:
    /// [1, 2–3, 4–7, 8–15, 16–31, 32+].
    pub batch_hist: [u64; 6],
    /// Final wait-queue depth per shard (FIFO + parked).
    pub queue_depths: Vec<usize>,
}

/// N dispatcher shards behind the [`FalkonCore`] driver surface.
pub struct ShardedCore {
    shards: Vec<FalkonCore>,
    /// Per-shard order buffers reused across wake-ups (batching keeps
    /// allocations out of the dispatch hot path).
    bufs: Vec<Vec<DispatchOrder>>,
    /// Task-partitioning ring over the *shard count* (not the executor
    /// pool): `ring.owner(obj)` is the shard id owning `obj`'s tasks.
    ring: ChordRing,
    /// Shared object catalog (dominant-input sizing).
    catalog: Catalog,
    /// All registered executors across shards, ascending.
    all: Vec<ExecutorId>,
    /// Adaptive steal-batch cap shared by every thief shard.
    sizer: StealSizer,
    steals: u64,
    stolen_tasks: u64,
    batches: u64,
    batch_hist: [u64; 6],
}

impl ShardedCore {
    /// New sharded core over zero-cost [`CentralIndex`] backends, one
    /// per shard.
    pub fn new(cfg: &SchedulerConfig, catalog: Catalog, shards: usize) -> Self {
        let n = shards.max(1);
        let indexes = (0..n)
            .map(|_| Box::new(CentralIndex::new()) as Box<dyn DataIndex>)
            .collect();
        ShardedCore::with_indexes(cfg, catalog, indexes)
    }

    /// New sharded core over explicit index backends (one per shard;
    /// the shard count is `indexes.len()`). Each shard's index tracks
    /// only that shard's executors — the partition-by-owner routing is
    /// what keeps a shard's lookups local to its slice.
    pub fn with_indexes(
        cfg: &SchedulerConfig,
        catalog: Catalog,
        indexes: Vec<Box<dyn DataIndex>>,
    ) -> Self {
        assert!(!indexes.is_empty(), "at least one shard required");
        let n = indexes.len();
        let shards: Vec<FalkonCore> = indexes
            .into_iter()
            .map(|idx| FalkonCore::with_index(cfg, catalog.clone(), idx))
            .collect();
        ShardedCore {
            bufs: (0..n).map(|_| Vec::new()).collect(),
            ring: ChordRing::new(n, PARTITION_SEED),
            catalog,
            all: Vec::new(),
            shards,
            sizer: StealSizer::new(),
            steals: 0,
            stolen_tasks: 0,
            batches: 0,
            batch_hist: [0; 6],
        }
    }

    /// Number of dispatcher shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The dispatch policy in force (identical across shards).
    pub fn policy(&self) -> DispatchPolicy {
        self.shards[0].policy()
    }

    /// The shared object catalog.
    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Index backend label (identical across shards).
    pub fn backend(&self) -> &'static str {
        self.shards[0].index().backend()
    }

    /// Read access to one shard (tests, figures).
    pub fn shard(&self, s: usize) -> &FalkonCore {
        &self.shards[s]
    }

    /// The shard owning executor `e`: round-robin, so shards hold
    /// disjoint, evenly sized slices of a dense executor id space and
    /// can never dispatch to each other's slots.
    pub fn shard_of_executor(&self, e: ExecutorId) -> usize {
        e % self.shards.len()
    }

    /// The shard owning tasks dominated by `obj` (its Chord owner on
    /// the shard ring).
    pub fn shard_of_object(&self, obj: ObjectId) -> usize {
        self.ring.owner(obj)
    }

    /// The shard `task` routes to: the Chord owner of its dominant
    /// input (largest catalog size; ties keep the first input, so the
    /// choice is order-stable), or a task-id hash when it has no
    /// inputs.
    pub fn shard_of_task(&self, task: &Task) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let mut dom: Option<(u64, ObjectId)> = None;
        for &obj in &task.inputs {
            let size = self.catalog.size(obj).unwrap_or(1);
            if dom.map(|(best, _)| size > best).unwrap_or(true) {
                dom = Some((size, obj));
            }
        }
        match dom {
            Some((_, obj)) => self.ring.owner(obj),
            None => (task.id.0 % self.shards.len() as u64) as usize,
        }
    }

    /// Submit one task to its owning shard's wait queue.
    pub fn submit(&mut self, task: Task) {
        let s = self.shard_of_task(&task);
        self.shards[s].submit(task);
    }

    /// Register a newly provisioned executor with one task slot.
    pub fn register_executor(&mut self, e: ExecutorId) {
        self.register_executor_with(e, 1);
    }

    /// Register an executor that can run `capacity` tasks concurrently.
    pub fn register_executor_with(&mut self, e: ExecutorId, capacity: usize) {
        let s = self.shard_of_executor(e);
        self.shards[s].register_executor_with(e, capacity);
        if let Err(pos) = self.all.binary_search(&e) {
            self.all.insert(pos, e);
        }
    }

    /// Deregister an executor; returns the objects whose last cached
    /// copy vanished with it (from its shard's index slice).
    pub fn deregister_executor(&mut self, e: ExecutorId) -> Vec<ObjectId> {
        if let Ok(pos) = self.all.binary_search(&e) {
            self.all.remove(pos);
        }
        let s = self.shard_of_executor(e);
        self.shards[s].deregister_executor(e)
    }

    /// All registered executors across shards, ascending.
    pub fn executors(&self) -> &[ExecutorId] {
        &self.all
    }

    /// Number of registered executors.
    pub fn executor_count(&self) -> usize {
        self.all.len()
    }

    /// Idle executors across shards.
    pub fn idle_count(&self) -> usize {
        self.shards.iter().map(|s| s.idle_count()).sum()
    }

    /// Executors running nothing at all, ascending across shards.
    pub fn quiescent_executors(&self) -> Vec<ExecutorId> {
        let mut q: Vec<ExecutorId> = self
            .shards
            .iter()
            .flat_map(|s| s.quiescent_executors())
            .collect();
        q.sort_unstable();
        q
    }

    /// Total wait-queue length (FIFO + parked) across shards.
    pub fn queue_len(&self) -> usize {
        self.shards.iter().map(|s| s.queue_len()).sum()
    }

    /// Total ready (non-parked) tasks across shards.
    pub fn ready_len(&self) -> usize {
        self.shards.iter().map(|s| s.ready_len()).sum()
    }

    /// Sum of per-shard queue high-water marks since the last call —
    /// the provisioner's demand signal (exact at one shard; an additive
    /// upper bound across shards).
    pub fn take_queue_peak(&mut self) -> usize {
        self.shards.iter_mut().map(|s| s.take_queue_peak()).sum()
    }

    /// (submitted, dispatched, completed) summed across shards. Steals
    /// keep the submit credit on the victim shard, so sums stay exact.
    pub fn counters(&self) -> (u64, u64, u64) {
        self.shards.iter().fold((0, 0, 0), |acc, s| {
            let c = s.counters();
            (acc.0 + c.0, acc.1 + c.1, acc.2 + c.2)
        })
    }

    /// Fraction of `e`'s task slots currently busy.
    pub fn busy_fraction(&self, e: ExecutorId) -> f64 {
        self.shards[self.shard_of_executor(e)].busy_fraction(e)
    }

    /// Lookup cost of resolving `obj` from executor `e`'s shard — the
    /// index slice that shard's dispatcher consults. Drivers charge
    /// this for executor-side re-resolution of stale hints.
    pub fn lookup_cost_for(&self, e: ExecutorId, obj: ObjectId) -> LookupCost {
        self.shards[self.shard_of_executor(e)].index().lookup_cost(obj)
    }

    /// Locations of `obj` as recorded by executor `e`'s shard.
    pub fn locations_for(&self, e: ExecutorId, obj: ObjectId) -> &[ExecutorId] {
        self.shards[self.shard_of_executor(e)].index().locations(obj)
    }

    /// Executor reports a completed task with its cache changes; routed
    /// to the executor's shard.
    pub fn on_task_complete(&mut self, e: ExecutorId, task: TaskId, events: &[CacheEvent]) {
        let s = self.shard_of_executor(e);
        self.shards[s].on_task_complete(e, task, events);
    }

    /// Apply cache-change notifications from executor `e` to its
    /// shard's index slice.
    pub fn apply_cache_events(&mut self, e: ExecutorId, events: &[CacheEvent]) {
        let s = self.shard_of_executor(e);
        self.shards[s].apply_cache_events(e, events);
    }

    /// Drain control-plane traffic accumulated by every shard's index.
    pub fn take_index_control(&mut self) -> ControlTraffic {
        let mut total = ControlTraffic::default();
        for s in self.shards.iter_mut() {
            let c = s.take_index_control();
            total.stabilization_msgs += c.stabilization_msgs;
            total.misroutes += c.misroutes;
            total.update_msgs += c.update_msgs;
            total.latency_s += c.latency_s;
        }
        total
    }

    /// Turn on demand-driven replication in every shard (each manages
    /// replicas within its own executor slice).
    pub fn enable_replication(&mut self, cfg: &ReplicationConfig) {
        for s in self.shards.iter_mut() {
            s.enable_replication(cfg);
        }
    }

    /// Whether replication is active.
    pub fn replication_enabled(&self) -> bool {
        self.shards.iter().any(|s| s.replication_enabled())
    }

    /// Replica location entries across shards.
    pub fn replica_location_entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.replica_location_entries())
            .sum()
    }

    /// One replication evaluation round per shard, concatenated in
    /// shard order (deterministic).
    pub fn poll_replication(&mut self) -> Vec<ReplicaDirective> {
        let mut dirs = Vec::new();
        for s in self.shards.iter_mut() {
            dirs.extend(s.poll_replication());
        }
        dirs
    }

    /// Driver notification: `dst` fetched `obj` from a peer cache.
    pub fn note_peer_fetch(&mut self, obj: ObjectId, dst: ExecutorId) {
        let s = self.shard_of_executor(dst);
        self.shards[s].note_peer_fetch(obj, dst);
    }

    /// Driver notification: a staging transfer finished (or was
    /// abandoned).
    pub fn replication_staged(&mut self, obj: ObjectId, dst: ExecutorId) {
        let s = self.shard_of_executor(dst);
        self.shards[s].replication_staged(obj, dst);
    }

    /// Driver notification: a replica drop was executed (or abandoned).
    pub fn replication_dropped(&mut self, obj: ObjectId, victim: ExecutorId) {
        let s = self.shard_of_executor(victim);
        self.shards[s].replication_dropped(obj, victim);
    }

    /// Dispatch every shard once: rebalance (steal into starved
    /// shards), drain each shard's ready queue as one batch, and merge
    /// the orders in shard order. Shards own disjoint executor slices,
    /// so above a backlog threshold they dispatch concurrently on
    /// scoped threads; the merged order stream is identical either way.
    pub fn try_dispatch(&mut self) -> Vec<DispatchOrder> {
        self.rebalance();
        let total_ready: usize = self.shards.iter().map(|s| s.ready_len()).sum();
        if self.shards.len() == 1 || total_ready < PARALLEL_READY_MIN {
            for (shard, buf) in self.shards.iter_mut().zip(self.bufs.iter_mut()) {
                shard.dispatch_into(buf);
            }
        } else {
            std::thread::scope(|scope| {
                for (shard, buf) in self.shards.iter_mut().zip(self.bufs.iter_mut()) {
                    scope.spawn(move || shard.dispatch_into(buf));
                }
            });
        }
        let mut merged = Vec::with_capacity(self.bufs.iter().map(Vec::len).sum());
        for buf in self.bufs.iter_mut() {
            Self::record_batch(&mut self.batches, &mut self.batch_hist, buf.len());
            merged.append(buf);
        }
        merged
    }

    /// Dispatch a single shard (per-shard wake-ups in the sim driver):
    /// steal for it if starved, then drain its ready queue as one
    /// batch.
    pub fn try_dispatch_shard(&mut self, s: usize) -> Vec<DispatchOrder> {
        self.steal_for(s);
        let mut orders = Vec::new();
        self.shards[s].dispatch_into(&mut orders);
        Self::record_batch(&mut self.batches, &mut self.batch_hist, orders.len());
        orders
    }

    /// Dispatch-and-retire every queued task as fast as possible, one
    /// thread per shard — the dispatch-throughput measurement harness
    /// behind `benches/dispatch_throughput.rs` and the `fig_shard_scaling`
    /// sweep. Tasks complete instantly with no cache changes (the index
    /// is whatever the caller prewarmed), so the measured rate is pure
    /// decision + queue throughput. Returns tasks retired.
    pub fn drain_all(&mut self) -> u64 {
        let mut total = 0u64;
        loop {
            self.rebalance();
            let before = self.queue_len();
            if before == 0 {
                break;
            }
            let tally = if self.shards.len() == 1 {
                drain_shard(&mut self.shards[0], &mut self.bufs[0])
            } else {
                std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .shards
                        .iter_mut()
                        .zip(self.bufs.iter_mut())
                        .map(|(shard, buf)| scope.spawn(move || drain_shard(shard, buf)))
                        .collect();
                    let mut sum = DrainTally::default();
                    for h in handles {
                        sum.merge(h.join().expect("drain thread"));
                    }
                    sum
                })
            };
            total += tally.done;
            self.batches += tally.batches;
            for (h, o) in self.batch_hist.iter_mut().zip(tally.batch_hist) {
                *h += o;
            }
            // A shard with queued work but no executors makes no
            // progress on its own; if stealing could not move its work
            // either, stop rather than spin.
            if self.queue_len() == before {
                break;
            }
        }
        total
    }

    /// Steal/batch statistics plus per-shard queue depths, for the
    /// metrics harvest at run end.
    pub fn shard_stats(&self) -> ShardStats {
        ShardStats {
            steals: self.steals,
            stolen_tasks: self.stolen_tasks,
            batches: self.batches,
            batch_hist: self.batch_hist,
            queue_depths: self.shards.iter().map(|s| s.queue_len()).collect(),
        }
    }

    pub(crate) fn record_batch(batches: &mut u64, hist: &mut [u64; 6], n: usize) {
        if n == 0 {
            return;
        }
        *batches += 1;
        let bucket = match n {
            1 => 0,
            2..=3 => 1,
            4..=7 => 2,
            8..=15 => 3,
            16..=31 => 4,
            _ => 5,
        };
        hist[bucket] += 1;
    }

    /// Steal work into every starved shard (idle executors, empty ready
    /// queue) from the shard with the longest ready queue.
    fn rebalance(&mut self) {
        if self.shards.len() < 2 {
            return;
        }
        for s in 0..self.shards.len() {
            self.steal_for(s);
        }
    }

    /// Steal one bounded batch into shard `s` if it is starved: victim
    /// is the longest ready queue elsewhere (first such shard on ties),
    /// batch is at most half the victim's ready queue, capped by the
    /// thief's idle slots and the adaptive [`StealSizer`] cap (initially
    /// [`MAX_STEAL_BATCH`]).
    fn steal_for(&mut self, s: usize) {
        if self.shards.len() < 2 {
            return;
        }
        let thief_idle = self.shards[s].idle_count();
        if thief_idle == 0 || self.shards[s].ready_len() > 0 {
            return;
        }
        let mut victim: Option<(usize, usize)> = None; // (ready_len, shard)
        for (v, shard) in self.shards.iter().enumerate() {
            if v == s {
                continue;
            }
            let len = shard.ready_len();
            if len >= 2 && victim.map(|(best, _)| len > best).unwrap_or(true) {
                victim = Some((len, v));
            }
        }
        let Some((vlen, v)) = victim else { return };
        let batch = vlen.div_ceil(2).min(thief_idle).min(self.sizer.cap());
        let stolen = self.shards[v].steal_ready(batch);
        if stolen.is_empty() {
            return;
        }
        self.sizer.record(vlen, stolen.len());
        self.steals += 1;
        self.stolen_tasks += stolen.len() as u64;
        for t in stolen {
            self.shards[s].absorb(t);
        }
    }

    /// Decompose into a thread-safe [`ShardPlane`] for per-shard
    /// dispatcher threads (the live driver at `--shards >= 2`). Tasks
    /// and executors already submitted/registered stay on their shards;
    /// the facade's own steal/batch counters are dropped (per-shard
    /// loops keep their own tallies and fold them into
    /// [`ShardStats`] at harvest).
    pub fn into_plane(self) -> ShardPlane {
        ShardPlane {
            slots: self
                .shards
                .into_iter()
                .map(|core| ShardSlot {
                    ready_hint: AtomicUsize::new(core.ready_len()),
                    exec_hint: AtomicUsize::new(core.executor_count()),
                    core: Mutex::new(core),
                })
                .collect(),
            ring: self.ring,
            catalog: self.catalog,
        }
    }
}

/// One shard of a [`ShardPlane`]: the core behind its lock, plus
/// lock-free hints the owning loop publishes so *other* shards can pick
/// steal victims without contending on the lock.
struct ShardSlot {
    core: Mutex<FalkonCore>,
    /// Published ready-queue length (refreshed by the owning loop after
    /// every dispatch/absorb, and by a thief after a successful steal).
    ready_hint: AtomicUsize,
    /// Published executor count (refreshed on membership churn).
    exec_hint: AtomicUsize,
}

/// Thread-safe per-shard decomposition of a [`ShardedCore`].
///
/// Each dispatcher thread owns one shard: it locks `self.lock(s)` for
/// short critical sections (apply reports, dispatch a batch), publishes
/// its ready length, and steals through [`ShardPlane::steal_into`] when
/// starved. A coordinator thread may lock any shard — one at a time —
/// for membership churn and harvest. Lock discipline: hold at most one
/// shard lock, except inside `steal_into`, which `try_lock`s the victim
/// while holding the thief and backs off on contention — so no thread
/// ever *blocks* for a second shard lock and no deadlock cycle exists.
pub struct ShardPlane {
    slots: Vec<ShardSlot>,
    /// Task-partitioning ring (same [`PARTITION_SEED`] ring the facade
    /// used; routing stays stable across the decomposition).
    ring: ChordRing,
    catalog: Catalog,
}

impl ShardPlane {
    /// Number of dispatcher shards.
    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    /// The shared object catalog.
    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// The shard owning executor `e` (round-robin, as in the facade).
    pub fn shard_of_executor(&self, e: ExecutorId) -> usize {
        e % self.slots.len()
    }

    /// The shard owning tasks dominated by `obj`.
    pub fn shard_of_object(&self, obj: ObjectId) -> usize {
        self.ring.owner(obj)
    }

    /// Lock shard `s`'s core. Coordinator-side callers must release
    /// before locking another shard.
    pub fn lock(&self, s: usize) -> MutexGuard<'_, FalkonCore> {
        self.slots[s].core.lock().expect("shard core poisoned")
    }

    /// Publish shard `s`'s ready-queue length and executor count for
    /// lock-free victim selection (the owning loop calls this after
    /// each dispatch round and on membership churn).
    pub fn publish(&self, s: usize, ready: usize, executors: usize) {
        self.slots[s].ready_hint.store(ready, Ordering::Relaxed);
        self.slots[s].exec_hint.store(executors, Ordering::Relaxed);
    }

    /// Published ready-queue length of shard `s`.
    pub fn ready_hint(&self, s: usize) -> usize {
        self.slots[s].ready_hint.load(Ordering::Relaxed)
    }

    /// Whether any shard other than `s` advertises stealable work.
    pub fn work_visible_elsewhere(&self, s: usize) -> bool {
        self.slots
            .iter()
            .enumerate()
            .any(|(v, slot)| v != s && slot.ready_hint.load(Ordering::Relaxed) > 0)
    }

    /// Cross-thread steal into shard `s`, whose (locked) core the
    /// calling loop passes as `thief`. Victim selection reads the
    /// published hints; the victim's lock is only `try_lock`ed, so a
    /// contended victim means "no steal this round" rather than a
    /// potential deadlock — the caller retries on its next wake-up.
    ///
    /// Unlike the single-owner facade, a victim with exactly one ready
    /// task is eligible: an executor-less shard has no loop of its own
    /// to ever run that task, so a lone leftover must be able to move.
    /// Returns the number of tasks moved (0 on no victim/contention).
    pub fn steal_into(&self, s: usize, thief: &mut FalkonCore, sizer: &mut StealSizer) -> u64 {
        if self.slots.len() < 2 {
            return 0;
        }
        let thief_idle = thief.idle_count();
        if thief_idle == 0 || thief.ready_len() > 0 {
            return 0;
        }
        let mut victim: Option<(usize, usize)> = None; // (ready_hint, shard)
        for (v, slot) in self.slots.iter().enumerate() {
            if v == s {
                continue;
            }
            let len = slot.ready_hint.load(Ordering::Relaxed);
            if len >= 1 && victim.map(|(best, _)| len > best).unwrap_or(true) {
                victim = Some((len, v));
            }
        }
        let Some((_, v)) = victim else { return 0 };
        let Ok(mut vcore) = self.slots[v].core.try_lock() else {
            return 0;
        };
        let vlen = vcore.ready_len();
        if vlen == 0 {
            return 0;
        }
        let batch = vlen.div_ceil(2).min(thief_idle).min(sizer.cap()).max(1);
        let stolen = vcore.steal_ready(batch);
        self.slots[v].ready_hint.store(vcore.ready_len(), Ordering::Relaxed);
        drop(vcore);
        if stolen.is_empty() {
            return 0;
        }
        sizer.record(vlen, stolen.len());
        let n = stolen.len() as u64;
        for t in stolen {
            thief.absorb(t);
        }
        n
    }

    /// Total wait-queue length across shards (locks one at a time).
    pub fn queue_len(&self) -> usize {
        (0..self.slots.len()).map(|s| self.lock(s).queue_len()).sum()
    }

    /// Sum of per-shard queue high-water marks since the last call.
    pub fn take_queue_peak(&self) -> usize {
        (0..self.slots.len())
            .map(|s| self.lock(s).take_queue_peak())
            .sum()
    }

    /// Executors running nothing at all, ascending across shards.
    pub fn quiescent_executors(&self) -> Vec<ExecutorId> {
        let mut q: Vec<ExecutorId> = (0..self.slots.len())
            .flat_map(|s| self.lock(s).quiescent_executors())
            .collect();
        q.sort_unstable();
        q
    }

    /// All registered executors, ascending across shards.
    pub fn executors(&self) -> Vec<ExecutorId> {
        let mut all: Vec<ExecutorId> = (0..self.slots.len())
            .flat_map(|s| self.lock(s).executors().to_vec())
            .collect();
        all.sort_unstable();
        all
    }

    /// Number of registered executors across shards.
    pub fn executor_count(&self) -> usize {
        (0..self.slots.len())
            .map(|s| self.lock(s).executor_count())
            .sum()
    }

    /// Replica location entries across shards.
    pub fn replica_location_entries(&self) -> usize {
        (0..self.slots.len())
            .map(|s| self.lock(s).replica_location_entries())
            .sum()
    }

    /// Drain control-plane traffic accumulated by every shard's index.
    pub fn take_index_control(&self) -> ControlTraffic {
        let mut total = ControlTraffic::default();
        for s in 0..self.slots.len() {
            let c = self.lock(s).take_index_control();
            total.stabilization_msgs += c.stabilization_msgs;
            total.misroutes += c.misroutes;
            total.update_msgs += c.update_msgs;
            total.latency_s += c.latency_s;
        }
        total
    }

    /// Final wait-queue depth per shard, for the metrics harvest.
    pub fn queue_depths(&self) -> Vec<usize> {
        (0..self.slots.len()).map(|s| self.lock(s).queue_len()).collect()
    }
}

/// Per-shard drain loop for [`ShardedCore::drain_all`]: dispatch a
/// batch, retire it, repeat until the shard's queue is empty or the
/// policy can place nothing more. Parked tasks always make progress
/// here — a task only parks behind an executor this same loop marked
/// busy, and retiring that order releases it.
fn drain_shard(shard: &mut FalkonCore, buf: &mut Vec<DispatchOrder>) -> DrainTally {
    let mut tally = DrainTally::default();
    loop {
        shard.dispatch_into(buf);
        if buf.is_empty() {
            break;
        }
        ShardedCore::record_batch(&mut tally.batches, &mut tally.batch_hist, buf.len());
        for o in buf.drain(..) {
            shard.on_task_complete(o.executor, o.task.id, &[]);
            tally.done += 1;
        }
    }
    tally
}

/// What one shard's drain loop did: retired tasks plus its share of the
/// batch accounting (folded into the core's counters after the scoped
/// threads join — the per-shard loops cannot touch them concurrently).
#[derive(Default)]
struct DrainTally {
    done: u64,
    batches: u64,
    batch_hist: [u64; 6],
}

impl DrainTally {
    fn merge(&mut self, other: DrainTally) {
        self.done += other.done;
        self.batches += other.batches;
        for (h, o) in self.batch_hist.iter_mut().zip(other.batch_hist) {
            *h += o;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerConfig;

    fn catalog(objects: u64) -> Catalog {
        let mut cat = Catalog::new();
        for i in 0..objects {
            cat.insert(ObjectId(i), 100);
        }
        cat
    }

    fn sharded(policy: DispatchPolicy, shards: usize) -> ShardedCore {
        let cfg = SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        };
        ShardedCore::new(&cfg, catalog(64), shards)
    }

    #[test]
    fn partitioning_is_deterministic_and_total() {
        let c = sharded(DispatchPolicy::MaxComputeUtil, 4);
        for i in 0..64u64 {
            let t = Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)]);
            let s = c.shard_of_task(&t);
            assert!(s < 4);
            assert_eq!(s, c.shard_of_task(&t), "stable routing");
            assert_eq!(s, c.shard_of_object(ObjectId(i % 16)));
        }
        // Inputless tasks hash by id and stay in range.
        let t = Task::with_inputs(TaskId(9), vec![]);
        assert!(c.shard_of_task(&t) < 4);
        // Executors split round-robin.
        assert_eq!(c.shard_of_executor(5), 1);
        assert_eq!(c.shard_of_executor(8), 0);
    }

    #[test]
    fn single_shard_matches_falkon_core_orders() {
        let cfg = SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            ..SchedulerConfig::default()
        };
        let mut sharded = ShardedCore::new(&cfg, catalog(16), 1);
        let mut single = FalkonCore::new(&cfg, catalog(16));
        for e in 0..4 {
            sharded.register_executor(e);
            single.register_executor(e);
        }
        for i in 0..8u64 {
            let t = Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)]);
            sharded.submit(t.clone());
            single.submit(t);
        }
        let a = sharded.try_dispatch();
        let b = single.try_dispatch();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.task.id, y.task.id);
            assert_eq!(x.executor, y.executor);
            assert_eq!(x.hints, y.hints);
            assert_eq!(x.cost, y.cost);
        }
    }

    #[test]
    fn dispatch_routes_tasks_to_owning_shards_executors() {
        let mut c = sharded(DispatchPolicy::FirstAvailable, 4);
        for e in 0..8 {
            c.register_executor(e);
        }
        for i in 0..16u64 {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)]));
        }
        let orders = c.try_dispatch();
        assert!(!orders.is_empty());
        // Absent stealing, a shard only dispatches its own tasks to its
        // own executors (a steal legitimately moves a task cross-shard;
        // the dedicated steal test covers that path).
        if c.shard_stats().steals == 0 {
            for o in &orders {
                assert_eq!(
                    c.shard_of_executor(o.executor),
                    c.shard_of_task(&o.task),
                    "a shard only dispatches to its own executors"
                );
            }
        }
        let (sub, disp, _) = c.counters();
        assert_eq!(sub, 16);
        assert_eq!(disp, orders.len() as u64);
    }

    #[test]
    fn starved_shard_steals_from_longest_queue() {
        let mut c = sharded(DispatchPolicy::FirstAvailable, 2);
        // Shard 0 gets executors but no tasks; shard 1 gets tasks but
        // no executors.
        c.register_executor(0);
        c.register_executor(2);
        let victim = (0..65536u64)
            .map(ObjectId)
            .find(|&o| c.shard_of_object(o) == 1)
            .expect("some object owned by shard 1");
        for i in 0..6u64 {
            c.submit(Task::with_inputs(TaskId(i), vec![victim]));
        }
        assert_eq!(c.shard(1).ready_len(), 6);
        assert_eq!(c.shard(0).ready_len(), 0);
        let orders = c.try_dispatch();
        assert_eq!(orders.len(), 2, "stolen tasks run on shard 0's slots");
        for o in &orders {
            assert_eq!(c.shard_of_executor(o.executor), 0);
        }
        let stats = c.shard_stats();
        assert_eq!(stats.steals, 1);
        assert!(stats.stolen_tasks >= 2);
        assert!(stats.batches >= 1);
        assert_eq!(stats.batch_hist.iter().sum::<u64>(), stats.batches);
    }

    #[test]
    fn drain_all_retires_everything_across_shards() {
        for shards in [1usize, 2, 4] {
            let mut c = sharded(DispatchPolicy::MaxComputeUtil, shards);
            for e in 0..8 {
                c.register_executor(e);
            }
            for i in 0..200u64 {
                c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i % 64)]));
            }
            let done = c.drain_all();
            assert_eq!(done, 200, "shards={shards}");
            assert_eq!(c.queue_len(), 0);
            let (sub, disp, comp) = c.counters();
            assert_eq!((sub, disp, comp), (200, 200, 200));
        }
    }

    #[test]
    fn steal_sizer_starts_at_constant_and_clamps() {
        let mut s = StealSizer::new();
        assert_eq!(s.cap(), MAX_STEAL_BATCH, "initial cap is the old constant");
        // Persistent deep residuals grow the cap, but never past 64.
        for _ in 0..64 {
            s.record(1_000, 8);
        }
        assert_eq!(s.cap(), 64, "deep residual backlog saturates at the ceiling");
        // Clean steals (no residual) shrink it, but never below 1.
        for _ in 0..64 {
            s.record(4, 4);
        }
        assert_eq!(s.cap(), 1, "residual-free steals decay to the floor");
        // And it can grow back.
        s.record(40, 1);
        assert!(s.cap() > 1 && s.cap() <= 64);
    }

    #[test]
    fn steal_sizer_tracks_residual_ewma() {
        let mut s = StealSizer::new();
        // One steal leaving 24 behind: EWMA = 0.25*24 + 0.75*8 = 12.
        s.record(32, 8);
        assert_eq!(s.cap(), 12);
        // A clean follow-up decays it: 0.25*0 + 0.75*12 = 9.
        s.record(9, 9);
        assert_eq!(s.cap(), 9);
    }

    #[test]
    fn plane_cross_thread_steal_moves_lone_and_batched_tasks() {
        let mut c = sharded(DispatchPolicy::FirstAvailable, 2);
        // Executors land on shard 0 only; tasks on shard 1 only.
        c.register_executor(0);
        c.register_executor(2);
        let victim_obj = (0..65536u64)
            .map(ObjectId)
            .find(|&o| c.shard_of_object(o) == 1)
            .expect("some object owned by shard 1");
        c.submit(Task::with_inputs(TaskId(0), vec![victim_obj]));
        let plane = c.into_plane();
        assert_eq!(plane.ready_hint(1), 1);
        assert!(plane.work_visible_elsewhere(0));
        let mut sizer = StealSizer::new();
        {
            let mut thief = plane.lock(0);
            // A lone task on an executor-less shard must be stealable —
            // there is no shard-1 loop to ever run it.
            assert_eq!(plane.steal_into(0, &mut thief, &mut sizer), 1);
            let mut orders = Vec::new();
            thief.dispatch_into(&mut orders);
            assert_eq!(orders.len(), 1);
            assert_eq!(orders[0].executor % 2, 0, "runs on shard 0's slot");
        }
        assert_eq!(plane.ready_hint(1), 0, "victim hint refreshed by the thief");
        assert_eq!(plane.queue_len(), 0);
    }

    #[test]
    fn plane_parallel_drain_retires_everything() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let shards = 4;
        let mut c = sharded(DispatchPolicy::MaxComputeUtil, shards);
        for e in 0..8 {
            c.register_executor_with(e, 2);
        }
        let total = 400u64;
        for i in 0..total {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i % 64)]));
        }
        let plane = c.into_plane();
        let done = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for s in 0..shards {
                let (plane, done) = (&plane, &done);
                scope.spawn(move || {
                    let mut sizer = StealSizer::new();
                    let mut orders = Vec::new();
                    let mut idle_rounds = 0;
                    while done.load(Ordering::Relaxed) < total && idle_rounds < 10_000 {
                        let mut core = plane.lock(s);
                        plane.steal_into(s, &mut core, &mut sizer);
                        core.dispatch_into(&mut orders);
                        if orders.is_empty() {
                            idle_rounds += 1;
                        } else {
                            idle_rounds = 0;
                        }
                        for o in orders.drain(..) {
                            core.on_task_complete(o.executor, o.task.id, &[]);
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                        plane.publish(s, core.ready_len(), core.executor_count());
                        drop(core);
                        std::thread::yield_now();
                    }
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), total);
        assert_eq!(plane.queue_len(), 0);
        assert_eq!(plane.executor_count(), 8);
        assert_eq!(plane.executors(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn deregister_and_completion_route_by_executor() {
        let mut c = sharded(DispatchPolicy::MaxComputeUtil, 2);
        for e in 0..4 {
            c.register_executor(e);
        }
        assert_eq!(c.executor_count(), 4);
        for i in 0..4u64 {
            c.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i)]));
        }
        let orders = c.try_dispatch();
        for o in &orders {
            c.on_task_complete(o.executor, o.task.id, &[CacheEvent::Inserted(o.task.inputs[0])]);
        }
        // Each cache event landed in the executor's shard index.
        for o in &orders {
            assert!(c.locations_for(o.executor, o.task.inputs[0]).contains(&o.executor));
        }
        let orphans = c.deregister_executor(orders[0].executor);
        assert!(orphans.contains(&orders[0].task.inputs[0]));
        assert_eq!(c.executor_count(), 3);
        assert!(c.executors().binary_search(&orders[0].executor).is_err());
    }
}
