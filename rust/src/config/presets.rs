//! Named presets reproducing the paper's experimental setups.

use super::Config;
use crate::scheduler::DispatchPolicy;

/// Table 1 platform descriptions, for reference and for the testbed bench.
#[derive(Debug, Clone)]
pub struct Platform {
    /// Cluster name as in Table 1.
    pub name: &'static str,
    /// Node count.
    pub nodes: usize,
    /// Processor description.
    pub processors: &'static str,
    /// Memory per node.
    pub memory: &'static str,
    /// Network.
    pub network: &'static str,
}

/// The paper's Table 1.
pub const TABLE1: &[Platform] = &[
    Platform {
        name: "TG_ANL_IA32",
        nodes: 98,
        processors: "Dual Xeon 2.4 GHz",
        memory: "4GB",
        network: "1Gb/s",
    },
    Platform {
        name: "TG_ANL_IA64",
        nodes: 64,
        processors: "Dual Itanium 1.3 GHz",
        memory: "4GB",
        network: "1Gb/s",
    },
    Platform {
        name: "UC_x64",
        nodes: 1,
        processors: "Dual Xeon 3GHz w/ HT",
        memory: "2GB",
        network: "100Mb/s",
    },
];

/// Total executor nodes in the two compute clusters (98 + 64).
pub const TOTAL_TG_NODES: usize = 162;

/// §4 micro-benchmark testbed: up to 64 executor nodes, GPFS persistent
/// storage, one executor per node.
pub fn microbench(nodes: usize) -> Config {
    let mut c = Config::with_nodes(nodes);
    c.scheduler.policy = DispatchPolicy::MaxComputeUtil;
    c
}

/// §5 stacking-application testbed: up to 128 CPUs (64 dual-CPU nodes),
/// max-compute-util + LRU caching for data diffusion runs.
pub fn stacking(cpus: usize) -> Config {
    // The paper uses up to 128 CPUs on dual-CPU nodes.
    let nodes = cpus.div_ceil(2);
    let mut c = Config::with_nodes(nodes);
    c.testbed.cpus_per_node = if cpus >= 2 { 2 } else { 1 };
    c.scheduler.policy = DispatchPolicy::MaxComputeUtil;
    c
}

/// §5 GPFS baseline: no caching, location-unaware dispatch
/// ("next-available ... no caching").
pub fn stacking_gpfs_baseline(cpus: usize) -> Config {
    let mut c = stacking(cpus);
    c.scheduler.policy = DispatchPolicy::FirstAvailable;
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        assert_eq!(TABLE1.len(), 3);
        assert_eq!(TABLE1[0].nodes + TABLE1[1].nodes, TOTAL_TG_NODES);
    }

    #[test]
    fn stacking_preset_cpu_mapping() {
        let c = stacking(128);
        assert_eq!(c.testbed.nodes, 64);
        assert_eq!(c.testbed.cpus_per_node, 2);
        let c1 = stacking(1);
        assert_eq!(c1.testbed.nodes, 1);
        assert_eq!(c1.testbed.cpus_per_node, 1);
    }

    #[test]
    fn baseline_is_location_unaware() {
        let c = stacking_gpfs_baseline(64);
        assert_eq!(c.scheduler.policy, DispatchPolicy::FirstAvailable);
    }
}
