//! Hand-rolled TOML-subset parser (no `serde`/`toml` offline).
//!
//! Supports the subset our config files use:
//!
//! ```toml
//! # comment
//! [section]
//! key = 3.4          # number
//! name = "gpfs"      # string
//! flag = true        # bool
//! sizes = [1, 2, 3]  # number list
//! ```
//!
//! Nested tables use dotted section headers (`[storage.gpfs]`). Values are
//! stored flat as `"section.key" -> Value`, which keeps lookup trivial and
//! is all the config layer needs.
//!
//! Array-of-tables headers (`[[site]]`) are supported by indexing: the
//! n-th `[[site]]` table stores its keys under `site.<n-1>.key`, and
//! [`Doc::array_len`] reports how many tables a name accumulated, so the
//! config layer iterates `site.0.*`, `site.1.*`, ….

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar or list value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Float or integer (stored as f64; config consumers convert).
    Num(f64),
    /// Quoted string.
    Str(String),
    /// Boolean.
    Bool(bool),
    /// Homogeneous numeric list.
    List(Vec<f64>),
}

/// Flat key → value document.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    map: BTreeMap<String, Value>,
    /// `[[name]]` table counts (name → how many tables were declared).
    arrays: BTreeMap<String, usize>,
}

impl Doc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut map = BTreeMap::new();
        let mut arrays: BTreeMap<String, usize> = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest.strip_suffix("]]").ok_or_else(|| {
                    Error::Config(format!("line {}: unterminated array header", lineno + 1))
                })?;
                let name = name.trim();
                let n = arrays.entry(name.to_string()).or_insert(0);
                section = format!("{name}.{n}");
                *n += 1;
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    Error::Config(format!("line {}: unterminated section header", lineno + 1))
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let key = key.trim();
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim())
                .ok_or_else(|| Error::Config(format!("line {}: bad value {val:?}", lineno + 1)))?;
            map.insert(full, value);
        }
        Ok(Doc { map, arrays })
    }

    /// Look up a raw value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// Numeric value or default.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(Value::Num(n)) => *n,
            _ => default,
        }
    }

    /// String value or default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    /// Bool value or default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// Numeric list or default.
    pub fn list_or(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.map.get(key) {
            Some(Value::List(v)) => v.clone(),
            Some(Value::Num(n)) => vec![*n],
            _ => default.to_vec(),
        }
    }

    /// All keys (for validation / unknown-key warnings).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// How many `[[name]]` tables the document declared (0 if none).
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).copied().unwrap_or(0)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string would break this; our configs don't put
    // `#` in strings, and the parser documents that restriction.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"')?;
        return Some(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']')?;
        let mut out = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            out.push(part.parse().ok()?);
        }
        return Some(Value::List(out));
    }
    s.parse().ok().map(Value::Num)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
# top comment
root = 1
[storage]
gpfs_read_gbps = 3.4   # paper §4.2
name = "gpfs"
enabled = true
[storage.meta]
ops = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.num_or("root", 0.0), 1.0);
        assert_eq!(doc.num_or("storage.gpfs_read_gbps", 0.0), 3.4);
        assert_eq!(doc.str_or("storage.name", ""), "gpfs");
        assert!(doc.bool_or("storage.enabled", false));
        assert_eq!(doc.list_or("storage.meta.ops", &[]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn defaults_on_missing() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.num_or("nope", 7.0), 7.0);
        assert_eq!(doc.str_or("nope", "d"), "d");
        assert!(!doc.bool_or("nope", false));
    }

    #[test]
    fn errors_on_malformed() {
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("[[unterminated]").is_err());
        assert!(Doc::parse("keyonly").is_err());
        assert!(Doc::parse("k = @bogus@").is_err());
    }

    #[test]
    fn array_tables_index_flat_keys() {
        let doc = Doc::parse(
            r#"
[federation]
wan_gbps = 0.1
[[site]]
nodes = 8
[[site]]
nodes = 4
wan_gbps = 0.2
[transfer]
staging_budget = 0.5
"#,
        )
        .unwrap();
        assert_eq!(doc.array_len("site"), 2);
        assert_eq!(doc.array_len("rack"), 0);
        assert_eq!(doc.num_or("site.0.nodes", 0.0), 8.0);
        assert_eq!(doc.num_or("site.1.nodes", 0.0), 4.0);
        assert_eq!(doc.num_or("site.1.wan_gbps", 0.0), 0.2);
        // Plain sections keep working before, between, and after arrays.
        assert_eq!(doc.num_or("federation.wan_gbps", 0.0), 0.1);
        assert_eq!(doc.num_or("transfer.staging_budget", 0.0), 0.5);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Doc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }
}
