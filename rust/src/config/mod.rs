//! Configuration system.
//!
//! A single [`Config`] describes everything an experiment needs: the
//! (simulated) testbed, the storage substrate calibration, cache and
//! scheduler policies, the dynamic resource provisioner, and application
//! cost constants. Configs are built from presets (`presets.rs`) and can
//! be overridden from a TOML-subset file (`parse.rs`) or programmatically.
//!
//! All bandwidth calibration constants default to the values the paper
//! *measured* on the ANL/UC TeraGrid testbed (§4.2), so simulations
//! reproduce the paper's contention shapes out of the box.

pub mod parse;
pub mod presets;

use crate::cache::policy::EvictionPolicy;
use crate::error::Result;
use crate::index::IndexBackend;
use crate::scheduler::DispatchPolicy;
use crate::util::units::{gbps, mbps, BitsPerSec, GB, MB};

/// Testbed description (Table 1 analog).
#[derive(Debug, Clone)]
pub struct TestbedConfig {
    /// Number of executor nodes available for provisioning.
    pub nodes: usize,
    /// CPUs per node actually used for task execution (the paper maps one
    /// executor per node in §4 and per CPU in §5's 128-CPU runs).
    pub cpus_per_node: usize,
    /// Per-node NIC bandwidth (full duplex, each direction).
    pub nic_bps: BitsPerSec,
    /// Dispatcher ⇄ executor one-way message latency, seconds (§4.1: 1–2 ms).
    pub net_latency_s: f64,
}

impl Default for TestbedConfig {
    fn default() -> Self {
        TestbedConfig {
            nodes: 64,
            cpus_per_node: 1,
            nic_bps: gbps(1.0),
            net_latency_s: 0.0015,
        }
    }
}

/// Shared ("persistent storage", GPFS-like) file system calibration.
///
/// Defaults reproduce the paper's measured envelopes (§4.2): read tops out
/// at 3.4 Gb/s, read+write at 1.1 Gb/s aggregate, saturation at ~8 client
/// nodes (there are 8 I/O servers), and a metadata server whose op costs
/// throttle small-file and wrapper-style workloads (~21 tasks/s cap for
/// the mkdir+symlink+rmdir wrapper across 64 nodes).
#[derive(Debug, Clone)]
pub struct SharedFsConfig {
    /// Number of I/O servers (saturation point in client count).
    pub io_servers: usize,
    /// Aggregate read capacity across all I/O servers.
    pub read_cap_bps: BitsPerSec,
    /// Aggregate write capacity (calibrated so mixed read+write workloads
    /// land at the paper's 1.1 Gb/s combined).
    pub write_cap_bps: BitsPerSec,
    /// Per-client share cap: one client cannot exceed this from the shared
    /// FS even when alone (its NIC typically binds first).
    pub per_client_cap_bps: BitsPerSec,
    /// Metadata service time for a plain open/create, seconds. Cheap:
    /// GPFS resolves opens in a few ms even under load.
    pub meta_op_s: f64,
    /// Metadata ops per plain file open (open + stat).
    pub meta_ops_open: u32,
    /// Service time for a *directory-mutating* wrapper op (mkdir /
    /// symlink / rmdir on a shared directory), seconds. Expensive: these
    /// serialize on the directory's metadata and are what cap the §4.3
    /// wrapper configuration at ~21 tasks/s across 64 nodes.
    pub wrapper_op_s: f64,
    /// Wrapper ops per task (mkdir + symlink before, rmdir after).
    pub meta_ops_wrapper: u32,
}

impl Default for SharedFsConfig {
    fn default() -> Self {
        SharedFsConfig {
            io_servers: 8,
            read_cap_bps: gbps(3.4),
            write_cap_bps: gbps(0.66),
            per_client_cap_bps: gbps(1.0),
            meta_op_s: 0.004,
            meta_ops_open: 1,
            wrapper_op_s: 0.015,
            meta_ops_wrapper: 3,
        }
    }
}

/// Per-node local disk calibration.
///
/// The paper measures aggregate local-disk read at 76 Gb/s and read+write
/// at 25 Gb/s across 162 nodes (§4.2) — i.e. ~470 Mb/s read and ~230 Mb/s
/// write per node, scaling linearly because disks are private.
#[derive(Debug, Clone)]
pub struct LocalDiskConfig {
    /// Per-node sequential read bandwidth.
    pub read_bps: BitsPerSec,
    /// Per-node sequential write bandwidth.
    pub write_bps: BitsPerSec,
    /// Fixed per-file access overhead (local FS metadata), seconds.
    pub open_s: f64,
}

impl Default for LocalDiskConfig {
    fn default() -> Self {
        LocalDiskConfig {
            read_bps: mbps(470.0),
            write_bps: mbps(230.0),
            open_s: 0.0005,
        }
    }
}

/// Executor data-cache configuration.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Per-executor cache capacity in bytes (local disk space dedicated to
    /// diffused data).
    pub capacity_bytes: u64,
    /// Eviction policy (paper implements Random/FIFO/LRU/LFU; experiments
    /// use LRU).
    pub policy: EvictionPolicy,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_bytes: 50 * GB,
            policy: EvictionPolicy::Lru,
        }
    }
}

/// Dispatcher / scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Task dispatch policy (§3.2.2).
    pub policy: DispatchPolicy,
    /// Whether executors run tasks through the sandbox wrapper
    /// (configuration (4) in §4.3: mkdir+symlink+rmdir on persistent
    /// storage around every task).
    pub wrapper: bool,
    /// Max tasks dispatched per executor CPU before it must report back
    /// (1 = paper's model: one outstanding task per CPU).
    pub tasks_per_cpu: usize,
    /// Wait-queue scan window for the data-aware matcher: when an
    /// executor frees up, up to this many queued tasks are examined for
    /// one whose data is cached there. §3.2.3's 2.1 ms decision budget at
    /// ~1 µs/lookup supports windows in the thousands.
    pub window: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            wrapper: false,
            tasks_per_cpu: 1,
            window: 2048,
        }
    }
}

/// Dispatcher-core sharding configuration (see
/// [`crate::coordinator::sharded`]).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Number of dispatcher shards
    /// ([`crate::coordinator::ShardedCore`]). 1 (the default) reproduces
    /// the single-loop dispatcher's decisions bit-for-bit; N > 1
    /// partitions executors and tasks across N independent cores with
    /// cross-shard work stealing. 0 in a config file (or `--shards 0`)
    /// resolves at load time to one shard per available core.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { shards: 1 }
    }
}

/// Simulation-engine configuration (see [`crate::sim::parallel`]).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Worker threads for the conservative-lookahead parallel event
    /// engine. 1 (the default) runs the serial engine bit-for-bit; N > 1
    /// executes federation sites on N threads with identical merged
    /// outcomes. 0 in a config file (or `--threads 0`) resolves at load
    /// time to one thread per available core. Single-site runs always
    /// use the serial engine regardless of this setting.
    pub threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { threads: 1 }
    }
}

/// Cache-location index configuration (§3.2.3).
///
/// Selects the [`DataIndex`](crate::index::DataIndex) backend the
/// dispatcher runs against and calibrates its simulated lookup costs.
/// Defaults reproduce the paper's measurements: 0.25–1 µs per central
/// hash-table lookup (we charge the midpoint) and LAN-regime per-hop
/// latency for the distributed (Chord) design.
#[derive(Debug, Clone)]
pub struct IndexConfig {
    /// Which backend serves location lookups.
    pub backend: IndexBackend,
    /// Simulated service time of one centralized-index lookup, seconds.
    pub central_lookup_s: f64,
    /// One-way per-hop network latency on the Chord overlay, seconds
    /// (GigE LAN: ~0.2 ms — same regime as the paper's 1–2 ms
    /// dispatcher-executor latency).
    pub hop_latency_s: f64,
    /// Local processing per overlay hop (hash + finger lookup), seconds.
    pub hop_proc_s: f64,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig {
            backend: IndexBackend::Central,
            central_lookup_s: 0.5e-6,
            hop_latency_s: 0.0002,
            hop_proc_s: 0.00002,
        }
    }
}

/// Dynamic resource provisioner configuration (§3.1).
#[derive(Debug, Clone)]
pub struct ProvisionerConfig {
    /// Whether the drivers run the pool elastically. Off (the default)
    /// reproduces the paper's static-pool experiments: all
    /// `testbed.nodes` executors are registered before t=0 and never
    /// leave. On, the pool starts at `min_executors` and the provisioner
    /// grows/shrinks it mid-run.
    pub enabled: bool,
    /// Allocation policy.
    pub policy: crate::provisioner::policy::AllocationPolicy,
    /// Lower bound on allocated executors.
    pub min_executors: usize,
    /// Upper bound on allocated executors.
    pub max_executors: usize,
    /// Batch-scheduler allocation latency (GRAM4 + LRM), seconds.
    pub allocation_latency_s: f64,
    /// Idle time after which an executor is released, seconds.
    pub idle_release_s: f64,
    /// Wait-queue length per idle executor that triggers growth.
    pub queue_per_executor: usize,
    /// How often the drivers evaluate the provisioner, seconds.
    pub poll_interval_s: f64,
}

impl Default for ProvisionerConfig {
    fn default() -> Self {
        ProvisionerConfig {
            enabled: false,
            policy: crate::provisioner::policy::AllocationPolicy::AllAtOnce,
            min_executors: 0,
            max_executors: 64,
            allocation_latency_s: 40.0,
            idle_release_s: 60.0,
            queue_per_executor: 4,
            poll_interval_s: 5.0,
        }
    }
}

/// Demand-driven replication configuration (the paper's "data diffusion"
/// proper — see [`crate::replication`]).
#[derive(Debug, Clone)]
pub struct ReplicationConfig {
    /// Whether the coordinator runs the [`crate::replication::ReplicationManager`].
    /// Off (the default) reproduces the passive-index behavior: copies
    /// only appear where tasks happen to fetch.
    pub enabled: bool,
    /// Where new replicas land.
    pub policy: crate::replication::PlacementPolicy,
    /// Per-object ceiling on copies (holders + in-flight stages).
    pub max_replicas: usize,
    /// Smoothed per-evaluation demand above which an object earns a new
    /// replica.
    pub demand_threshold: f64,
    /// EWMA smoothing factor per evaluation round (0..1; higher reacts
    /// faster, lower remembers longer).
    pub ewma_alpha: f64,
    /// How often the drivers evaluate the manager, seconds.
    pub evaluate_interval_s: f64,
    /// Hottest objects pre-staged onto a newly joined executor
    /// (re-replication on join; closes the post-churn hit-ratio dip).
    pub prestage_top_k: usize,
    /// Ceiling on concurrent staging transfers (backpressure: replication
    /// must not saturate the peer-transfer paths tasks also use).
    pub max_inflight: usize,
    /// Smoothed demand below which the manager actively releases the
    /// k-th copy ([`crate::replication::ReplicaDirective::Drop`]) instead
    /// of waiting for cache pressure. 0 (the default) disables active
    /// teardown; set it below `demand_threshold` so growth and teardown
    /// never chase each other.
    pub release_threshold: f64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            enabled: false,
            policy: crate::replication::PlacementPolicy::LeastLoaded,
            max_replicas: 4,
            demand_threshold: 2.0,
            ewma_alpha: 0.5,
            evaluate_interval_s: 5.0,
            prestage_top_k: 4,
            max_inflight: 8,
            release_threshold: 0.0,
        }
    }
}

/// One `[[site]]` table: a member cluster of the federation (see
/// [`crate::federation`]).
///
/// Site executor ranges are contiguous in declaration order: the first
/// table owns executors `0..nodes`, the next the following slice, and
/// so on. Site 0 is the *home* site — it hosts the shared filesystem.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteConfig {
    /// Executor nodes in this site.
    pub nodes: usize,
    /// This site's WAN uplink capacity; a cross-site flow is capped by
    /// the slower of the two endpoints' uplinks.
    pub wan_bps: BitsPerSec,
    /// One-way latency from this site to the WAN backbone, seconds.
    /// Pairwise site latency is the sum of the two endpoints'.
    pub wan_latency_s: f64,
    /// Intra-site LAN aggregate capacity — the backplane every
    /// non-node-local transfer inside the site crosses.
    pub lan_bps: BitsPerSec,
}

impl Default for SiteConfig {
    fn default() -> Self {
        SiteConfig {
            nodes: 0,
            wan_bps: gbps(0.5),
            wan_latency_s: 0.025,
            lan_bps: gbps(10.0),
        }
    }
}

/// Multi-cluster federation configuration (see [`crate::federation`]).
///
/// With no `[[site]]` tables (the default) the whole testbed is one
/// cluster and every federation code path is a pure passthrough — the
/// simulation reproduces single-site behavior bit-for-bit.
#[derive(Debug, Clone)]
pub struct FederationConfig {
    /// Member sites, in `[[site]]` declaration order. Their `nodes`
    /// must sum to `testbed.nodes` (the loader derives the total when
    /// it is not given explicitly).
    pub sites: Vec<SiteConfig>,
    /// How the federation scheduler places tasks across sites.
    pub placement: crate::federation::PlacementMode,
    /// Fraction of task *origins* concentrated on site 0, in [0, 1]
    /// (workload-skew knob for sweeps; the remainder spreads uniformly
    /// over all sites).
    pub skew: f64,
    /// Estimated seconds of queueing delay charged per queued task per
    /// executor in the affinity score — the ship-task vs ship-data
    /// trade-off knob (Pilot-Data §affinity).
    pub queue_weight_s: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            sites: Vec::new(),
            placement: crate::federation::PlacementMode::Affinity,
            skew: 0.0,
            queue_weight_s: 1.0,
        }
    }
}

/// Metered transfer plane configuration (see [`crate::transfer`]).
#[derive(Debug, Clone)]
pub struct TransferConfig {
    /// How transfer classes share a source's egress:
    /// [`SharePolicyKind::Binary`] (start-time admission only, unit
    /// weights once running — PR 4's behavior, the default) or
    /// [`SharePolicyKind::Weighted`] (weighted max-min fair shares for
    /// the whole flow lifetime, deferral only above the budget).
    pub share_policy: crate::transfer::SharePolicyKind,
    /// Source-executor egress-utilization budget in (0, 1]: under the
    /// binary policy, background staging/prestage transfers are deferred
    /// while the source runs hotter than this and re-admitted as it
    /// drains; under the weighted policy it is the *hard cap* above
    /// which admit-but-throttle falls back to deferral. 1.0 (the
    /// default) disables deferral — utilization cannot exceed 1 — which
    /// with the binary policy reproduces the pre-metering behavior.
    /// Foreground transfers are never subject to the budget.
    pub staging_budget: f64,
    /// Per-class fair-share weights (weighted policy only; the binary
    /// policy always runs unit weights). Default Foreground 1.0 /
    /// Staging 0.25 / Prestage 0.1.
    pub class_weights: crate::transfer::ClassWeights,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            share_policy: crate::transfer::SharePolicyKind::Binary,
            staging_budget: 1.0,
            class_weights: crate::transfer::ClassWeights::default(),
        }
    }
}

/// Application (image stacking) cost calibration, from §5.2 / Fig 7.
///
/// Compute costs are per stacking *task*; in live mode the real PJRT
/// kernel is used instead and these constants only matter for sim mode.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Compressed (GZ) file size — 2 MB in SDSS DR5.
    pub gz_bytes: u64,
    /// Uncompressed (FIT) file size — 6 MB.
    pub fit_bytes: u64,
    /// CPU time to uncompress one GZ file, seconds (Fig 7: GZ roughly
    /// doubles CPU time; decompression of 2 MB→6 MB on 2008 hardware).
    pub decompress_s: f64,
    /// CPU time for radec2xy per object (Fig 7: 10–20% of total).
    pub radec2xy_s: f64,
    /// CPU time for calibration+interpolation+doStacking per object
    /// (Fig 7: < 1 ms in all cases).
    pub stack_compute_s: f64,
    /// Bytes of a cutout/ROI actually read per object from an open file
    /// (readHDU+getTile reads the image HDU).
    pub roi_read_bytes: u64,
    /// Bytes written out per stacking (the stacked image).
    pub output_bytes: u64,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            gz_bytes: 2 * MB,
            fit_bytes: 6 * MB,
            decompress_s: 0.140,
            radec2xy_s: 0.020,
            stack_compute_s: 0.001,
            roi_read_bytes: 40_000, // 100x100 px ROI, 2 B/px, headers
            output_bytes: 40_000,
        }
    }
}

/// Root configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// Testbed (Table 1 analog).
    pub testbed: TestbedConfig,
    /// Shared persistent storage (GPFS model).
    pub shared_fs: SharedFsConfig,
    /// Per-node local disk model.
    pub local_disk: LocalDiskConfig,
    /// Executor cache settings.
    pub cache: CacheConfig,
    /// Dispatch policy settings.
    pub scheduler: SchedulerConfig,
    /// Dispatcher-core sharding.
    pub coordinator: CoordinatorConfig,
    /// Cache-location index backend + cost calibration.
    pub index: IndexConfig,
    /// Dynamic resource provisioning settings.
    pub provisioner: ProvisionerConfig,
    /// Demand-driven replication settings.
    pub replication: ReplicationConfig,
    /// Metered transfer plane (staging admission control).
    pub transfer: TransferConfig,
    /// Multi-cluster federation (sites, WAN fabric, placement).
    pub federation: FederationConfig,
    /// Simulation-engine settings (parallel event execution).
    pub sim: SimConfig,
    /// Stacking application constants.
    pub app: AppConfig,
    /// Master RNG seed for workload generation and tie-breaking.
    pub seed: u64,
}

impl Config {
    /// Paper-calibrated default config with `nodes` executors.
    pub fn with_nodes(nodes: usize) -> Config {
        let mut c = Config::default();
        c.testbed.nodes = nodes;
        c.provisioner.max_executors = nodes;
        c
    }

    /// Number of federation sites (1 when no `[[site]]` tables: the
    /// whole testbed is one cluster).
    pub fn sites(&self) -> usize {
        self.federation.sites.len().max(1)
    }

    /// Split the testbed into `n` near-equal contiguous sites with
    /// default WAN parameters (the `--sites N` CLI path). `n <= 1`
    /// clears the site list back to single-cluster behavior; `n` is
    /// capped at the node count so every site keeps at least one node.
    pub fn split_into_sites(&mut self, n: usize) {
        if n <= 1 {
            self.federation.sites.clear();
            return;
        }
        let n = n.min(self.testbed.nodes.max(1));
        let base = self.testbed.nodes / n;
        let rem = self.testbed.nodes % n;
        self.federation.sites = (0..n)
            .map(|i| SiteConfig {
                nodes: base + usize::from(i < rem),
                ..SiteConfig::default()
            })
            .collect();
    }

    /// Apply overrides from a TOML-subset document.
    ///
    /// Key names follow the struct paths, e.g. `testbed.nodes = 64`,
    /// `shared_fs.read_cap_gbps = 3.4`, `cache.policy = "lru"`,
    /// `scheduler.policy = "max-compute-util"`.
    pub fn apply_doc(&mut self, doc: &parse::Doc) -> Result<()> {
        let t = &mut self.testbed;
        t.nodes = doc.num_or("testbed.nodes", t.nodes as f64) as usize;
        t.cpus_per_node = doc.num_or("testbed.cpus_per_node", t.cpus_per_node as f64) as usize;
        t.nic_bps = gbps(doc.num_or("testbed.nic_gbps", t.nic_bps / 1e9));
        t.net_latency_s = doc.num_or("testbed.net_latency_s", t.net_latency_s);

        let s = &mut self.shared_fs;
        s.io_servers = doc.num_or("shared_fs.io_servers", s.io_servers as f64) as usize;
        s.read_cap_bps = gbps(doc.num_or("shared_fs.read_cap_gbps", s.read_cap_bps / 1e9));
        s.write_cap_bps = gbps(doc.num_or("shared_fs.write_cap_gbps", s.write_cap_bps / 1e9));
        s.per_client_cap_bps =
            gbps(doc.num_or("shared_fs.per_client_cap_gbps", s.per_client_cap_bps / 1e9));
        s.meta_op_s = doc.num_or("shared_fs.meta_op_s", s.meta_op_s);

        let d = &mut self.local_disk;
        d.read_bps = mbps(doc.num_or("local_disk.read_mbps", d.read_bps / 1e6));
        d.write_bps = mbps(doc.num_or("local_disk.write_mbps", d.write_bps / 1e6));
        d.open_s = doc.num_or("local_disk.open_s", d.open_s);

        let c = &mut self.cache;
        c.capacity_bytes =
            doc.num_or("cache.capacity_gb", c.capacity_bytes as f64 / 1e9) as u64 * GB;
        if let Some(parse::Value::Str(p)) = doc.get("cache.policy") {
            c.policy = EvictionPolicy::parse(p)
                .ok_or_else(|| crate::error::Error::Config(format!("bad cache.policy {p:?}")))?;
        }

        if let Some(parse::Value::Str(p)) = doc.get("scheduler.policy") {
            self.scheduler.policy = DispatchPolicy::parse(p).ok_or_else(|| {
                crate::error::Error::Config(format!("bad scheduler.policy {p:?}"))
            })?;
        }
        self.scheduler.wrapper = doc.bool_or("scheduler.wrapper", self.scheduler.wrapper);

        let co = &mut self.coordinator;
        co.shards = doc.num_or("coordinator.shards", co.shards as f64) as usize;
        if co.shards == 0 {
            // 0 = auto: one shard per available core, resolved at load
            // time so everything downstream sees a concrete count.
            co.shards = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
        }

        let sm = &mut self.sim;
        sm.threads = doc.num_or("sim.threads", sm.threads as f64) as usize;
        if sm.threads == 0 {
            // 0 = auto, resolved at load time exactly like
            // coordinator.shards above.
            sm.threads = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
        }

        let ix = &mut self.index;
        if let Some(parse::Value::Str(b)) = doc.get("index.backend") {
            ix.backend = IndexBackend::parse(b)
                .ok_or_else(|| crate::error::Error::Config(format!("bad index.backend {b:?}")))?;
        }
        ix.central_lookup_s = doc.num_or("index.central_lookup_s", ix.central_lookup_s);
        ix.hop_latency_s = doc.num_or("index.hop_latency_s", ix.hop_latency_s);
        ix.hop_proc_s = doc.num_or("index.hop_proc_s", ix.hop_proc_s);

        let p = &mut self.provisioner;
        p.enabled = doc.bool_or("provisioner.enabled", p.enabled);
        if let Some(parse::Value::Str(s)) = doc.get("provisioner.policy") {
            p.policy = crate::provisioner::policy::AllocationPolicy::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("bad provisioner.policy {s:?}"))
            })?;
        }
        p.min_executors = doc.num_or("provisioner.min_executors", p.min_executors as f64) as usize;
        p.max_executors = doc.num_or("provisioner.max_executors", p.max_executors as f64) as usize;
        p.allocation_latency_s =
            doc.num_or("provisioner.allocation_latency_s", p.allocation_latency_s);
        p.idle_release_s = doc.num_or("provisioner.idle_release_s", p.idle_release_s);
        p.queue_per_executor =
            doc.num_or("provisioner.queue_per_executor", p.queue_per_executor as f64) as usize;
        p.poll_interval_s = doc.num_or("provisioner.poll_interval_s", p.poll_interval_s);

        let r = &mut self.replication;
        r.enabled = doc.bool_or("replication.enabled", r.enabled);
        if let Some(parse::Value::Str(s)) = doc.get("replication.policy") {
            r.policy = crate::replication::PlacementPolicy::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("bad replication.policy {s:?}"))
            })?;
        }
        r.max_replicas = doc.num_or("replication.max_replicas", r.max_replicas as f64) as usize;
        r.demand_threshold = doc.num_or("replication.demand_threshold", r.demand_threshold);
        r.ewma_alpha = doc.num_or("replication.ewma_alpha", r.ewma_alpha);
        r.evaluate_interval_s =
            doc.num_or("replication.evaluate_interval_s", r.evaluate_interval_s);
        r.prestage_top_k =
            doc.num_or("replication.prestage_top_k", r.prestage_top_k as f64) as usize;
        r.max_inflight = doc.num_or("replication.max_inflight", r.max_inflight as f64) as usize;
        r.release_threshold = doc.num_or("replication.release_threshold", r.release_threshold);
        if r.release_threshold > 0.0 && r.release_threshold >= r.demand_threshold {
            return Err(crate::error::Error::Config(format!(
                "replication.release_threshold ({}) must be below demand_threshold ({}) \
                 or the manager would stage and tear down the same object in a loop",
                r.release_threshold, r.demand_threshold
            )));
        }

        let tr = &mut self.transfer;
        if let Some(parse::Value::Str(s)) = doc.get("transfer.share_policy") {
            tr.share_policy = crate::transfer::SharePolicyKind::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("bad transfer.share_policy {s:?}"))
            })?;
        }
        tr.staging_budget = doc.num_or("transfer.staging_budget", tr.staging_budget);
        if !(tr.staging_budget > 0.0 && tr.staging_budget <= 1.0) {
            return Err(crate::error::Error::Config(format!(
                "transfer.staging_budget must be in (0, 1], got {}",
                tr.staging_budget
            )));
        }
        let w = &mut tr.class_weights;
        w.foreground = doc.num_or("transfer.foreground_weight", w.foreground);
        w.staging = doc.num_or("transfer.staging_weight", w.staging);
        w.prestage = doc.num_or("transfer.prestage_weight", w.prestage);
        for (name, v) in [
            ("foreground_weight", w.foreground),
            ("staging_weight", w.staging),
            ("prestage_weight", w.prestage),
        ] {
            if !(v > 0.0 && v.is_finite()) {
                return Err(crate::error::Error::Config(format!(
                    "transfer.{name} must be a positive number, got {v}"
                )));
            }
        }

        let f = &mut self.federation;
        if let Some(parse::Value::Str(s)) = doc.get("federation.placement") {
            f.placement = crate::federation::PlacementMode::parse(s).ok_or_else(|| {
                crate::error::Error::Config(format!("bad federation.placement {s:?}"))
            })?;
        }
        f.skew = doc.num_or("federation.skew", f.skew);
        if !(0.0..=1.0).contains(&f.skew) {
            return Err(crate::error::Error::Config(format!(
                "federation.skew must be in [0, 1], got {}",
                f.skew
            )));
        }
        f.queue_weight_s = doc.num_or("federation.queue_weight_s", f.queue_weight_s);
        // `[federation]` keys set the defaults each `[[site]]` table may
        // override per site.
        let site_default = SiteConfig {
            wan_bps: gbps(doc.num_or(
                "federation.wan_gbps",
                SiteConfig::default().wan_bps / 1e9,
            )),
            wan_latency_s: doc.num_or(
                "federation.wan_latency_s",
                SiteConfig::default().wan_latency_s,
            ),
            lan_bps: gbps(doc.num_or(
                "federation.lan_gbps",
                SiteConfig::default().lan_bps / 1e9,
            )),
            ..SiteConfig::default()
        };
        let n_sites = doc.array_len("site");
        if n_sites > 0 {
            f.sites = (0..n_sites)
                .map(|i| SiteConfig {
                    nodes: doc.num_or(&format!("site.{i}.nodes"), 0.0) as usize,
                    wan_bps: gbps(doc.num_or(
                        &format!("site.{i}.wan_gbps"),
                        site_default.wan_bps / 1e9,
                    )),
                    wan_latency_s: doc.num_or(
                        &format!("site.{i}.wan_latency_s"),
                        site_default.wan_latency_s,
                    ),
                    lan_bps: gbps(doc.num_or(
                        &format!("site.{i}.lan_gbps"),
                        site_default.lan_bps / 1e9,
                    )),
                })
                .collect();
            if f.sites.iter().any(|s| s.nodes == 0) {
                return Err(crate::error::Error::Config(
                    "every [[site]] table needs nodes >= 1".into(),
                ));
            }
            let total: usize = f.sites.iter().map(|s| s.nodes).sum();
            if doc.get("testbed.nodes").is_some() && total != self.testbed.nodes {
                return Err(crate::error::Error::Config(format!(
                    "[[site]] nodes sum to {total} but testbed.nodes = {} — drop \
                     testbed.nodes to derive it, or make them agree",
                    self.testbed.nodes
                )));
            }
            self.testbed.nodes = total;
        }

        self.seed = doc.num_or("seed", self.seed as f64) as u64;
        Ok(())
    }

    /// Load a config file on top of the defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let doc = parse::Doc::parse(&text)?;
        let mut cfg = Config::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_calibration() {
        let c = Config::default();
        assert_eq!(c.shared_fs.io_servers, 8);
        assert!((c.shared_fs.read_cap_bps - 3.4e9).abs() < 1.0);
        assert_eq!(c.app.gz_bytes, 2 * MB);
        assert_eq!(c.app.fit_bytes, 6 * MB);
        assert_eq!(c.cache.policy, EvictionPolicy::Lru);
    }

    #[test]
    fn overrides_apply() {
        let doc = parse::Doc::parse(
            r#"
seed = 99
[testbed]
nodes = 128
nic_gbps = 10
[shared_fs]
read_cap_gbps = 6.8
[cache]
policy = "lfu"
[scheduler]
policy = "first-available"
wrapper = true
[index]
backend = "chord"
hop_latency_s = 0.001
"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.testbed.nodes, 128);
        assert!((c.testbed.nic_bps - 10e9).abs() < 1.0);
        assert!((c.shared_fs.read_cap_bps - 6.8e9).abs() < 1.0);
        assert_eq!(c.cache.policy, EvictionPolicy::Lfu);
        assert_eq!(c.scheduler.policy, DispatchPolicy::FirstAvailable);
        assert!(c.scheduler.wrapper);
        assert_eq!(c.index.backend, IndexBackend::Chord);
        assert!((c.index.hop_latency_s - 0.001).abs() < 1e-12);
        assert_eq!(c.seed, 99);
    }

    #[test]
    fn provisioner_overrides_apply() {
        let doc = parse::Doc::parse(
            r#"
[provisioner]
enabled = true
policy = "adaptive"
min_executors = 2
max_executors = 32
poll_interval_s = 1.5
queue_per_executor = 8
"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert!(c.provisioner.enabled);
        assert_eq!(
            c.provisioner.policy,
            crate::provisioner::policy::AllocationPolicy::Adaptive
        );
        assert_eq!(c.provisioner.min_executors, 2);
        assert_eq!(c.provisioner.max_executors, 32);
        assert!((c.provisioner.poll_interval_s - 1.5).abs() < 1e-12);
        assert_eq!(c.provisioner.queue_per_executor, 8);

        let bad = parse::Doc::parse("[provisioner]\npolicy = \"psychic\"").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
    }

    #[test]
    fn replication_overrides_apply() {
        let doc = parse::Doc::parse(
            r#"
[replication]
enabled = true
policy = "co-locate"
max_replicas = 6
demand_threshold = 1.5
ewma_alpha = 0.25
evaluate_interval_s = 2.0
prestage_top_k = 8
max_inflight = 16
release_threshold = 0.4
"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert!(c.replication.enabled);
        assert_eq!(
            c.replication.policy,
            crate::replication::PlacementPolicy::CoLocate
        );
        assert_eq!(c.replication.max_replicas, 6);
        assert!((c.replication.demand_threshold - 1.5).abs() < 1e-12);
        assert!((c.replication.ewma_alpha - 0.25).abs() < 1e-12);
        assert!((c.replication.evaluate_interval_s - 2.0).abs() < 1e-12);
        assert_eq!(c.replication.prestage_top_k, 8);
        assert_eq!(c.replication.max_inflight, 16);
        assert!((c.replication.release_threshold - 0.4).abs() < 1e-12);

        let bad = parse::Doc::parse("[replication]\npolicy = \"closest\"").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());

        // Teardown above the growth threshold would stage and drop the
        // same object forever: rejected.
        let bad = parse::Doc::parse(
            "[replication]\ndemand_threshold = 0.5\nrelease_threshold = 0.8",
        )
        .unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
    }

    #[test]
    fn transfer_overrides_apply_and_validate() {
        let doc = parse::Doc::parse(
            "[transfer]\nstaging_budget = 0.35\nshare_policy = \"weighted\"\nstaging_weight = 0.5",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert!((c.transfer.staging_budget - 0.35).abs() < 1e-12);
        assert_eq!(c.transfer.share_policy, crate::transfer::SharePolicyKind::Weighted);
        assert!((c.transfer.class_weights.staging - 0.5).abs() < 1e-12);
        assert!((c.transfer.class_weights.foreground - 1.0).abs() < 1e-12);
        // Defaults: binary policy, deferral disabled, paper weights.
        let d = Config::default();
        assert!((d.transfer.staging_budget - 1.0).abs() < 1e-12);
        assert_eq!(d.transfer.share_policy, crate::transfer::SharePolicyKind::Binary);
        assert_eq!(d.transfer.class_weights, crate::transfer::ClassWeights::default());
        // Out-of-range budgets are config errors.
        for bad in ["0", "1.5", "-0.2"] {
            let doc =
                parse::Doc::parse(&format!("[transfer]\nstaging_budget = {bad}")).unwrap();
            assert!(
                Config::default().apply_doc(&doc).is_err(),
                "budget {bad} must be rejected"
            );
        }
        // Nonpositive weights and unknown policies are config errors.
        let bad = parse::Doc::parse("[transfer]\nstaging_weight = 0").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
        let bad = parse::Doc::parse("[transfer]\nshare_policy = \"fair\"").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
    }

    #[test]
    fn coordinator_shards_override_applies_and_resolves_auto() {
        let doc = parse::Doc::parse("[coordinator]\nshards = 4").unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.coordinator.shards, 4);
        assert_eq!(Config::default().coordinator.shards, 1);
        // 0 = auto: resolved to one shard per core at load time, never
        // left as a literal zero for downstream code to trip on.
        let auto = parse::Doc::parse("[coordinator]\nshards = 0").unwrap();
        let mut c = Config::default();
        c.apply_doc(&auto).unwrap();
        assert!(c.coordinator.shards >= 1, "shards={}", c.coordinator.shards);
    }

    #[test]
    fn sim_threads_override_applies_and_resolves_auto() {
        let doc = parse::Doc::parse("[sim]\nthreads = 4").unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.sim.threads, 4);
        assert_eq!(Config::default().sim.threads, 1);
        // 0 = auto: resolved to one thread per core at load time, the
        // same contract as coordinator.shards.
        let auto = parse::Doc::parse("[sim]\nthreads = 0").unwrap();
        let mut c = Config::default();
        c.apply_doc(&auto).unwrap();
        assert!(c.sim.threads >= 1, "threads={}", c.sim.threads);
    }

    #[test]
    fn federation_sites_parse_and_validate() {
        let doc = parse::Doc::parse(
            r#"
[federation]
placement = "home"
skew = 0.6
wan_gbps = 0.25
[[site]]
nodes = 8
[[site]]
nodes = 4
wan_gbps = 1.0
wan_latency_s = 0.05
lan_gbps = 20
"#,
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.sites(), 2);
        assert_eq!(c.testbed.nodes, 12, "nodes derived from site sum");
        assert_eq!(
            c.federation.placement,
            crate::federation::PlacementMode::AlwaysHome
        );
        assert!((c.federation.skew - 0.6).abs() < 1e-12);
        // Site 0 inherits the [federation] default uplink; site 1
        // overrides everything.
        assert!((c.federation.sites[0].wan_bps - 0.25e9).abs() < 1.0);
        assert!((c.federation.sites[1].wan_bps - 1e9).abs() < 1.0);
        assert!((c.federation.sites[1].wan_latency_s - 0.05).abs() < 1e-12);
        assert!((c.federation.sites[1].lan_bps - 20e9).abs() < 1.0);

        // Defaults: no sites, single-cluster behavior.
        let d = Config::default();
        assert_eq!(d.sites(), 1);
        assert!(d.federation.sites.is_empty());

        // Explicit testbed.nodes must agree with the site sum.
        let bad = parse::Doc::parse("[testbed]\nnodes = 9\n[[site]]\nnodes = 8").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
        // Empty sites are rejected.
        let bad = parse::Doc::parse("[[site]]\nnodes = 0").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
        // Skew outside [0,1] is rejected.
        let bad = parse::Doc::parse("[federation]\nskew = 1.5").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
        // Unknown placement is rejected.
        let bad = parse::Doc::parse("[federation]\nplacement = \"psychic\"").unwrap();
        assert!(Config::default().apply_doc(&bad).is_err());
    }

    #[test]
    fn split_into_sites_covers_all_nodes() {
        let mut c = Config::with_nodes(10);
        c.split_into_sites(3);
        let sizes: Vec<usize> = c.federation.sites.iter().map(|s| s.nodes).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(c.sites(), 3);
        c.split_into_sites(1);
        assert_eq!(c.sites(), 1);
        assert!(c.federation.sites.is_empty());
    }

    #[test]
    fn bad_index_backend_is_config_error() {
        let doc = parse::Doc::parse("[index]\nbackend = \"gossip\"").unwrap();
        let mut c = Config::default();
        assert!(c.apply_doc(&doc).is_err());
    }

    #[test]
    fn bad_policy_is_config_error() {
        let doc = parse::Doc::parse("[cache]\npolicy = \"bogus\"").unwrap();
        let mut c = Config::default();
        assert!(c.apply_doc(&doc).is_err());
    }
}
