//! [`FedCore`]: the federation of per-site dispatch cores.
//!
//! One [`ShardedCore`] per site, joined by the [`GlobalIndex`] (so sites
//! can find each other's cached replicas) and the
//! [`FederationScheduler`] (so each submitted task lands at the site
//! where ship-task-vs-ship-data is cheapest). Executor ids stay global —
//! site `s` simply owns the contiguous range from the [`Topology`] — and
//! dispatcher shards pack globally as `site × shards_per_site + local`,
//! so the sharded wake-up protocol in the sim driver keeps working
//! unchanged.
//!
//! Every index-mutating entry point (cache events, replication staging,
//! executor churn) routes through here so the global directory stays
//! consistent with the per-site slices. With one site the facade is a
//! pure passthrough: no global directory, no routing draws, no extra
//! cost anywhere — single-site runs are bit-for-bit the pre-federation
//! simulation.

use crate::cache::store::CacheEvent;
use crate::config::{Config, ReplicationConfig};
use crate::coordinator::core::DispatchOrder;
use crate::coordinator::sharded::{ShardStats, ShardedCore};
use crate::coordinator::task::{Task, TaskId};
use crate::index::{ControlTraffic, ExecutorId, LookupCost};
use crate::replication::ReplicaDirective;
use crate::scheduler::DispatchPolicy;
use crate::storage::object::{Catalog, ObjectId};

use super::sched::SiteLoad;
use super::{FederationScheduler, GlobalIndex, SiteId, Topology};

/// Varies per-site index seeds so overlay layouts differ between sites
/// (site 0 keeps the configured seed unchanged).
const SITE_SEED_SALT: u64 = 0xA24B_AED4_963E_E407;

/// The federation facade the driver talks to (see module docs).
pub struct FedCore {
    sites: Vec<ShardedCore>,
    topo: Topology,
    sched: FederationScheduler,
    /// Cross-site replica directory; `None` with a single site.
    global: Option<GlobalIndex>,
    shards_per_site: usize,
    /// Combined registered-executor set, sorted ascending.
    all: Vec<ExecutorId>,
    /// Tasks placed at a site other than their origin.
    cross_site_tasks: u64,
    /// Accumulated placement-routing cost, drained by the driver.
    route_cost: LookupCost,
}

impl FedCore {
    /// Build one site core per `[[site]]` table (or a single passthrough
    /// core), each with its own per-shard index slices.
    pub fn new(cfg: &Config, catalog: Catalog) -> FedCore {
        let topo = Topology::from_config(cfg);
        let shards_per_site = cfg.coordinator.shards.max(1);
        let n = topo.sites();
        let mut sites = Vec::with_capacity(n);
        for s in 0..n {
            let seed = cfg.seed ^ (s as u64).wrapping_mul(SITE_SEED_SALT);
            let indexes = (0..shards_per_site)
                .map(|_| crate::index::build(&cfg.index, seed))
                .collect();
            sites.push(ShardedCore::with_indexes(
                &cfg.scheduler,
                catalog.clone(),
                indexes,
            ));
        }
        let sched = FederationScheduler::new(
            topo.clone(),
            cfg.federation.placement,
            cfg.federation.skew,
            cfg.federation.queue_weight_s,
            cfg.seed,
        );
        let global = if n > 1 { Some(GlobalIndex::new(topo.clone())) } else { None };
        FedCore {
            sites,
            topo,
            sched,
            global,
            shards_per_site,
            all: Vec::new(),
            cross_site_tasks: 0,
            route_cost: LookupCost::ZERO,
        }
    }

    // ---- topology / site accessors -------------------------------------

    /// The site layout.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Number of member sites.
    pub fn site_count(&self) -> usize {
        self.sites.len()
    }

    /// One site's dispatch core.
    pub fn site(&self, s: SiteId) -> &ShardedCore {
        &self.sites[s.index()]
    }

    /// The site owning executor `e`.
    pub fn site_of(&self, e: ExecutorId) -> SiteId {
        self.topo.site_of(e)
    }

    /// Tasks placed at a site other than their origin so far.
    pub fn cross_site_tasks(&self) -> u64 {
        self.cross_site_tasks
    }

    /// Drain the accumulated placement-routing cost (global-directory
    /// consultations at submit time).
    pub fn take_route_cost(&mut self) -> LookupCost {
        std::mem::replace(&mut self.route_cost, LookupCost::ZERO)
    }

    // ---- submit / dispatch ---------------------------------------------

    /// Route `task` to its run site (per the placement policy) and
    /// enqueue it there. Returns the chosen site.
    pub fn submit(&mut self, task: Task) -> SiteId {
        if self.sites.len() == 1 {
            self.sites[0].submit(task);
            return SiteId::HOME;
        }
        let origin = self.sched.origin_site(task.id.0);
        let mut cost = LookupCost::ZERO;
        let inputs: Vec<(u64, Option<SiteId>)> = {
            let global = self.global.as_ref().expect("multi-site has a global index");
            task.inputs
                .iter()
                .map(|&obj| {
                    let bytes = self.sites[0].catalog().size(obj).unwrap_or(0);
                    let (hit, c) = global.locate(origin, obj);
                    cost.accumulate(c);
                    (bytes, hit.map(|(s, _)| s))
                })
                .collect()
        };
        let load: Vec<SiteLoad> = self
            .sites
            .iter()
            .map(|c| SiteLoad { queued: c.queue_len(), executors: c.executor_count() })
            .collect();
        let chosen = self.sched.choose(task.id.0, &inputs, &load);
        if chosen != origin {
            self.cross_site_tasks += 1;
        }
        self.route_cost.accumulate(cost);
        self.sites[chosen.index()].submit(task);
        chosen
    }

    /// Enqueue `task` directly at `site`, bypassing placement. The
    /// parallel federated driver routes at the home site's frontend and
    /// delivers each task to its run site as a timestamped message;
    /// that site's world then submits it here.
    pub fn submit_at(&mut self, site: SiteId, task: Task) {
        self.sites[site.index()].submit(task);
    }

    /// Run every site's dispatch loop; orders concatenate in site order.
    pub fn try_dispatch(&mut self) -> Vec<DispatchOrder> {
        if self.sites.len() == 1 {
            return self.sites[0].try_dispatch();
        }
        let mut orders = Vec::new();
        for c in self.sites.iter_mut() {
            orders.append(&mut c.try_dispatch());
        }
        orders
    }

    /// Run one global shard's dispatch loop
    /// (`global = site × shards_per_site + local`).
    pub fn try_dispatch_shard(&mut self, g: usize) -> Vec<DispatchOrder> {
        self.sites[g / self.shards_per_site].try_dispatch_shard(g % self.shards_per_site)
    }

    /// Drain every site to quiescence; returns tasks dispatched.
    pub fn drain_all(&mut self) -> u64 {
        self.sites.iter_mut().map(|c| c.drain_all()).sum()
    }

    /// Total dispatcher shards across sites.
    pub fn shard_count(&self) -> usize {
        self.sites.len() * self.shards_per_site
    }

    /// The global shard owning executor `e`.
    pub fn shard_of_executor(&self, e: ExecutorId) -> usize {
        let s = self.topo.site_of(e);
        s.index() * self.shards_per_site + self.sites[s.index()].shard_of_executor(e)
    }

    /// The dispatch policy in force (uniform across sites).
    pub fn policy(&self) -> DispatchPolicy {
        self.sites[0].policy()
    }

    /// The shared object catalog.
    pub fn catalog(&self) -> &Catalog {
        self.sites[0].catalog()
    }

    /// The index backend label (uniform across sites).
    pub fn backend(&self) -> &'static str {
        self.sites[0].backend()
    }

    // ---- executor membership -------------------------------------------

    /// Register executor `e` (at its owning site) with `capacity` slots.
    pub fn register_executor_with(&mut self, e: ExecutorId, capacity: usize) {
        let s = self.topo.site_of(e);
        self.sites[s.index()].register_executor_with(e, capacity);
        if let Err(pos) = self.all.binary_search(&e) {
            self.all.insert(pos, e);
        }
    }

    /// Deregister executor `e`; returns the objects its departure
    /// removed from the site index.
    pub fn deregister_executor(&mut self, e: ExecutorId) -> Vec<ObjectId> {
        let s = self.topo.site_of(e);
        if let Ok(pos) = self.all.binary_search(&e) {
            self.all.remove(pos);
        }
        if let Some(g) = self.global.as_mut() {
            g.drop_executor(e);
        }
        self.sites[s.index()].deregister_executor(e)
    }

    /// All registered executors, ascending.
    pub fn executors(&self) -> &[ExecutorId] {
        &self.all
    }

    /// Registered executors across all sites.
    pub fn executor_count(&self) -> usize {
        self.all.len()
    }

    /// Idle executors across all sites.
    pub fn idle_count(&self) -> usize {
        self.sites.iter().map(|c| c.idle_count()).sum()
    }

    /// Executors with no running work anywhere, ascending.
    pub fn quiescent_executors(&self) -> Vec<ExecutorId> {
        if self.sites.len() == 1 {
            return self.sites[0].quiescent_executors();
        }
        let mut q: Vec<ExecutorId> = self
            .sites
            .iter()
            .flat_map(|c| c.quiescent_executors())
            .collect();
        q.sort_unstable();
        q
    }

    /// Executor busy fraction (dispatch-time load signal).
    pub fn busy_fraction(&self, e: ExecutorId) -> f64 {
        self.sites[self.topo.site_of(e).index()].busy_fraction(e)
    }

    // ---- queue state ----------------------------------------------------

    /// Waiting tasks across all sites.
    pub fn queue_len(&self) -> usize {
        self.sites.iter().map(|c| c.queue_len()).sum()
    }

    /// Waiting tasks at one site.
    pub fn site_queue_len(&self, s: SiteId) -> usize {
        self.sites[s.index()].queue_len()
    }

    /// Ready (dispatchable now) tasks across all sites.
    pub fn ready_len(&self) -> usize {
        self.sites.iter().map(|c| c.ready_len()).sum()
    }

    /// Harvest and reset the summed per-site queue peaks.
    pub fn take_queue_peak(&mut self) -> usize {
        self.sites.iter_mut().map(|c| c.take_queue_peak()).sum()
    }

    /// Harvest and reset one site's queue high-water mark (per-site
    /// provisioners size their pools against local demand only).
    pub fn site_take_queue_peak(&mut self, s: SiteId) -> usize {
        self.sites[s.index()].take_queue_peak()
    }

    /// (submitted, dispatched, completed) across all sites.
    pub fn counters(&self) -> (u64, u64, u64) {
        self.sites.iter().fold((0, 0, 0), |acc, c| {
            let x = c.counters();
            (acc.0 + x.0, acc.1 + x.1, acc.2 + x.2)
        })
    }

    // ---- index + cache coherence ---------------------------------------

    /// Cost of resolving `obj` from executor `e`'s site index.
    pub fn lookup_cost_for(&self, e: ExecutorId, obj: ObjectId) -> LookupCost {
        self.sites[self.topo.site_of(e).index()].lookup_cost_for(e, obj)
    }

    /// Locations of `obj` as seen from executor `e`'s site index.
    pub fn locations_for(&self, e: ExecutorId, obj: ObjectId) -> &[ExecutorId] {
        self.sites[self.topo.site_of(e).index()].locations_for(e, obj)
    }

    /// A holder of `obj` at some *other* site than executor `e`'s, with
    /// the WAN lookup cost of finding it. `None` with one site, when the
    /// object is cached at `e`'s own site (local hints cover that), or
    /// when no site caches it.
    pub fn remote_holder(&self, e: ExecutorId, obj: ObjectId) -> Option<(ExecutorId, LookupCost)> {
        let global = self.global.as_ref()?;
        let from = self.topo.site_of(e);
        let (hit, cost) = global.locate(from, obj);
        let (site, holders) = hit?;
        if site == from {
            return None;
        }
        let src = *holders.first()?;
        Some((src, cost))
    }

    /// Apply buffered cache events from `e` at task completion.
    pub fn on_task_complete(&mut self, e: ExecutorId, task: TaskId, events: &[CacheEvent]) {
        self.mirror_events(e, events);
        self.sites[self.topo.site_of(e).index()].on_task_complete(e, task, events);
    }

    /// Apply cache events outside task completion (prewarm, staging).
    pub fn apply_cache_events(&mut self, e: ExecutorId, events: &[CacheEvent]) {
        self.mirror_events(e, events);
        self.sites[self.topo.site_of(e).index()].apply_cache_events(e, events);
    }

    /// Harvest control-plane traffic from every site's index slices.
    pub fn take_index_control(&mut self) -> ControlTraffic {
        let mut total = ControlTraffic::default();
        for c in self.sites.iter_mut() {
            let t = c.take_index_control();
            total.stabilization_msgs += t.stabilization_msgs;
            total.misroutes += t.misroutes;
            total.update_msgs += t.update_msgs;
            total.latency_s += t.latency_s;
        }
        total
    }

    /// Keep the global directory in step with a site's cache updates.
    fn mirror_events(&mut self, e: ExecutorId, events: &[CacheEvent]) {
        let Some(g) = self.global.as_mut() else { return };
        for ev in events {
            match *ev {
                CacheEvent::Inserted(obj) => g.insert(obj, e),
                CacheEvent::Evicted(obj) => g.remove(obj, e),
            }
        }
    }

    // ---- replication ----------------------------------------------------

    /// Turn on proactive replication at every site.
    pub fn enable_replication(&mut self, cfg: &ReplicationConfig) {
        for c in self.sites.iter_mut() {
            c.enable_replication(cfg);
        }
    }

    /// Whether any site replicates.
    pub fn replication_enabled(&self) -> bool {
        self.sites.iter().any(|c| c.replication_enabled())
    }

    /// Replica-directory entries across all sites.
    pub fn replica_location_entries(&self) -> usize {
        self.sites.iter().map(|c| c.replica_location_entries()).sum()
    }

    /// Collect staging directives from every site.
    pub fn poll_replication(&mut self) -> Vec<ReplicaDirective> {
        if self.sites.len() == 1 {
            return self.sites[0].poll_replication();
        }
        let mut dirs = Vec::new();
        for c in self.sites.iter_mut() {
            dirs.append(&mut c.poll_replication());
        }
        dirs
    }

    /// Note a peer fetch of `obj` toward `dst` (replication demand).
    pub fn note_peer_fetch(&mut self, obj: ObjectId, dst: ExecutorId) {
        self.sites[self.topo.site_of(dst).index()].note_peer_fetch(obj, dst);
    }

    /// A staged replica of `obj` landed at `dst`.
    pub fn replication_staged(&mut self, obj: ObjectId, dst: ExecutorId) {
        if let Some(g) = self.global.as_mut() {
            g.insert(obj, dst);
        }
        self.sites[self.topo.site_of(dst).index()].replication_staged(obj, dst);
    }

    /// A staged replica of `obj` was evicted from `victim`.
    pub fn replication_dropped(&mut self, obj: ObjectId, victim: ExecutorId) {
        if let Some(g) = self.global.as_mut() {
            g.remove(obj, victim);
        }
        self.sites[self.topo.site_of(victim).index()].replication_dropped(obj, victim);
    }

    // ---- diagnostics -----------------------------------------------------

    /// Merged work-stealing / batching statistics across sites.
    pub fn shard_stats(&self) -> ShardStats {
        let mut total = ShardStats::default();
        for c in &self.sites {
            let s = c.shard_stats();
            total.steals += s.steals;
            total.stolen_tasks += s.stolen_tasks;
            total.batches += s.batches;
            for (t, x) in total.batch_hist.iter_mut().zip(s.batch_hist) {
                *t += x;
            }
            total.queue_depths.extend(s.queue_depths);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::super::PlacementMode;
    use super::*;
    use crate::config::SiteConfig;
    use crate::util::units::MB;

    fn catalog(n: u64) -> Catalog {
        let mut c = Catalog::new();
        for i in 0..n {
            c.insert(ObjectId(i), MB);
        }
        c
    }

    fn two_site_cfg() -> Config {
        let mut cfg = Config::with_nodes(8);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 4, ..SiteConfig::default() },
            SiteConfig { nodes: 4, ..SiteConfig::default() },
        ];
        cfg
    }

    fn fed(cfg: &Config, objects: u64) -> FedCore {
        let mut core = FedCore::new(cfg, catalog(objects));
        for e in 0..cfg.testbed.nodes {
            core.register_executor_with(e, 2);
        }
        core
    }

    #[test]
    fn single_site_is_passthrough() {
        let cfg = Config::with_nodes(4);
        let mut core = fed(&cfg, 8);
        assert_eq!(core.site_count(), 1);
        assert_eq!(core.shard_count(), cfg.coordinator.shards.max(1));
        for i in 0..8u64 {
            assert_eq!(core.submit(Task::with_inputs(TaskId(i), vec![ObjectId(i)])), SiteId::HOME);
        }
        let orders = core.try_dispatch();
        assert_eq!(orders.len(), 8);
        assert_eq!(core.cross_site_tasks(), 0);
        let cost = core.take_route_cost();
        assert_eq!(cost.lookups, 0, "no routing charges with one site");
    }

    #[test]
    fn membership_merges_across_sites() {
        let cfg = two_site_cfg();
        let mut core = fed(&cfg, 4);
        assert_eq!(core.executor_count(), 8);
        assert_eq!(core.executors(), &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(core.site(SiteId(0)).executor_count(), 4);
        assert_eq!(core.site(SiteId(1)).executor_count(), 4);
        core.deregister_executor(5);
        assert_eq!(core.executor_count(), 7);
        assert_eq!(core.site(SiteId(1)).executor_count(), 3);
        assert!(!core.executors().contains(&5));
    }

    #[test]
    fn shard_packing_is_global() {
        let mut cfg = two_site_cfg();
        cfg.coordinator.shards = 2;
        let core = fed(&cfg, 4);
        assert_eq!(core.shard_count(), 4);
        // Site 0 executors land in shards 0..2, site 1 in shards 2..4.
        for e in 0..4 {
            assert!(core.shard_of_executor(e) < 2, "exec {e}");
        }
        for e in 4..8 {
            let g = core.shard_of_executor(e);
            assert!((2..4).contains(&g), "exec {e} -> {g}");
        }
    }

    #[test]
    fn cache_events_mirror_into_global_directory() {
        let cfg = two_site_cfg();
        let mut core = fed(&cfg, 4);
        // Executor 6 (site 1) caches object 2.
        core.apply_cache_events(6, &[CacheEvent::Inserted(ObjectId(2))]);
        // From site 0 the holder is remote; from site 1 it is local.
        let (src, cost) = core.remote_holder(0, ObjectId(2)).expect("remote holder");
        assert_eq!(src, 6);
        assert!(cost.latency_s > 0.0, "WAN round-trip charged");
        assert!(core.remote_holder(6, ObjectId(2)).is_none(), "own site is not remote");
        // Eviction clears it.
        core.apply_cache_events(6, &[CacheEvent::Evicted(ObjectId(2))]);
        assert!(core.remote_holder(0, ObjectId(2)).is_none());
    }

    #[test]
    fn affinity_submit_ships_task_to_holding_site() {
        let cfg = two_site_cfg();
        let mut core = fed(&cfg, 4);
        core.apply_cache_events(7, &[CacheEvent::Inserted(ObjectId(3))]);
        // Find a task id originating at site 0 so the placement is a
        // genuine cross-site decision.
        let t = (0..100)
            .find(|&t| {
                FederationScheduler::new(
                    core.topology().clone(),
                    PlacementMode::Affinity,
                    0.0,
                    1.0,
                    cfg.seed,
                )
                .origin_site(t)
                    == SiteId::HOME
            })
            .unwrap();
        let chosen = core.submit(Task::with_inputs(TaskId(t), vec![ObjectId(3)]));
        assert_eq!(chosen, SiteId(1), "task follows its cached input");
        assert_eq!(core.cross_site_tasks(), 1);
        assert!(core.take_route_cost().lookups > 0, "routing consults the directory");
        assert_eq!(core.site(SiteId(1)).queue_len() + core.site(SiteId(1)).ready_len(), 1);
    }
}
