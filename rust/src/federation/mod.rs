//! Multi-cluster federation (Pilot-Data, arXiv:1301.6228).
//!
//! Generalizes the single-cluster data-diffusion loop to a *federation*
//! of sites: each `[[site]]` config table is an independent cluster with
//! its own contiguous executor range, its own dispatcher shards, its own
//! provisioner, and its own slice of the cache-location index. Sites are
//! joined by a WAN fabric that is much slower (and higher-latency) than
//! any intra-site path, which makes *where a task runs* the dominant
//! cost decision — exactly the regime Pilot-Data's affinity scheduling
//! targets.
//!
//! ## Site topology
//!
//! [`Topology`] pins the site layout for a run:
//!
//! * **Executor ranges** — site `s` owns the contiguous executor ids
//!   `first[s]..first[s+1]`, in `[[site]]` declaration order. Everything
//!   (index slices, provisioners, dispatch shards) partitions along
//!   these ranges; [`GlobalIndex`] enforces that no site's directory
//!   ever reports a location outside its own range.
//! * **LAN caps** — each site has one aggregate LAN resource that every
//!   non-node-local transfer inside the site crosses (GPFS traffic,
//!   peer-to-peer staging), modeling the site backplane.
//! * **WAN matrix** — every ordered site pair has a WAN link whose
//!   capacity is the slower of the two endpoints' uplinks and whose
//!   latency is the sum of their backbone latencies. Cross-site flows
//!   cross the WAN link *and* both LANs, and they carry transfer-class
//!   weights like any other flow — QoS pacing applies on WAN links too.
//!
//! Site 0 is the **home site**: it hosts the shared filesystem, so GPFS
//! reads from (and writes by) any other site traverse the WAN.
//!
//! ## The ship-task / ship-data contract
//!
//! Every submitted task has an *origin* site (where its user lives —
//! derived deterministically from the task id plus the configured skew).
//! The [`FederationScheduler`] then picks the site the task actually
//! runs at:
//!
//! * **ship the task** to the site already caching its inputs — pay a
//!   dispatch hop, save the transfer; or
//! * **ship the data** — run it where queues are short and accept the
//!   WAN fetch for whatever bytes are missing.
//!
//! The affinity score is the estimated WAN transfer time of the missing
//! bytes (source = the holding site found home-first through the
//! [`GlobalIndex`], else GPFS at site 0) plus a queue-depth penalty
//! (`queue_weight_s × queued-per-executor`); the task goes to the
//! argmin, ties to the lower site id. `AlwaysHome` (run at the origin)
//! and `RandomSite` (uniform) are the measured baselines the
//! `fig_federation` sweep compares against.
//!
//! With a single site every type here collapses to a passthrough —
//! [`FedCore`] delegates 1:1 to one [`crate::coordinator::ShardedCore`]
//! and the simulation reproduces pre-federation behavior bit-for-bit.

pub mod core;
pub mod index;
pub mod sched;

pub use self::core::FedCore;
pub use index::GlobalIndex;
pub use sched::{FederationScheduler, PlacementMode};

use crate::config::Config;

/// Identifies a federation site (one member cluster). Site 0 is the
/// *home* site: it hosts the shared filesystem, and single-site configs
/// collapse to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SiteId(pub u32);

impl SiteId {
    /// The home site (shared-filesystem host).
    pub const HOME: SiteId = SiteId(0);

    /// Index into per-site vectors.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Per-site executor ranges plus the WAN fabric between sites (see the
/// module docs for the full contract).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Prefix sums of site sizes: site `s` owns executors
    /// `first[s]..first[s+1]`; `first.len() == sites + 1`.
    first: Vec<usize>,
    /// Per-site LAN aggregate capacity, bits/sec.
    lan_bps: Vec<f64>,
    /// Row-major `sites × sites` pairwise WAN capacity (min of the two
    /// endpoints' uplinks), bits/sec. Diagonal unused.
    wan_bps: Vec<f64>,
    /// Row-major pairwise one-way WAN latency (sum of the two
    /// endpoints' backbone latencies), seconds. Diagonal zero.
    wan_latency_s: Vec<f64>,
}

impl Topology {
    /// Build the topology from `cfg.federation`. With no `[[site]]`
    /// tables the whole testbed is one site with no WAN fabric.
    pub fn from_config(cfg: &Config) -> Topology {
        let sites = &cfg.federation.sites;
        if sites.is_empty() {
            return Topology {
                first: vec![0, cfg.testbed.nodes],
                lan_bps: vec![0.0],
                wan_bps: vec![0.0],
                wan_latency_s: vec![0.0],
            };
        }
        let mut first = Vec::with_capacity(sites.len() + 1);
        first.push(0usize);
        for s in sites {
            first.push(first.last().unwrap() + s.nodes);
        }
        let n = sites.len();
        let mut wan_bps = vec![0.0; n * n];
        let mut wan_latency_s = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    wan_bps[i * n + j] = sites[i].wan_bps.min(sites[j].wan_bps);
                    wan_latency_s[i * n + j] = sites[i].wan_latency_s + sites[j].wan_latency_s;
                }
            }
        }
        Topology {
            first,
            lan_bps: sites.iter().map(|s| s.lan_bps).collect(),
            wan_bps,
            wan_latency_s,
        }
    }

    /// Number of sites (>= 1).
    pub fn sites(&self) -> usize {
        self.first.len() - 1
    }

    /// Whether this is the degenerate single-site topology.
    pub fn is_single(&self) -> bool {
        self.sites() == 1
    }

    /// Total executor nodes across all sites.
    pub fn nodes(&self) -> usize {
        *self.first.last().unwrap()
    }

    /// The site owning executor `exec`. Ids at or past the last range
    /// clamp to the last site (elastic pools never allocate outside
    /// `0..nodes`, but stale ids must not panic).
    pub fn site_of(&self, exec: usize) -> SiteId {
        let s = self.first.partition_point(|&f| f <= exec);
        SiteId((s.max(1).min(self.sites()) - 1) as u32)
    }

    /// The contiguous executor-id range site `s` owns.
    pub fn executor_range(&self, s: SiteId) -> std::ops::Range<usize> {
        self.first[s.index()]..self.first[s.index() + 1]
    }

    /// Executor nodes in site `s`.
    pub fn site_nodes(&self, s: SiteId) -> usize {
        self.executor_range(s).len()
    }

    /// Site `s`'s LAN aggregate capacity, bits/sec.
    pub fn lan_bps(&self, s: SiteId) -> f64 {
        self.lan_bps[s.index()]
    }

    /// WAN capacity between two distinct sites, bits/sec.
    pub fn wan_bps(&self, from: SiteId, to: SiteId) -> f64 {
        self.wan_bps[from.index() * self.sites() + to.index()]
    }

    /// One-way WAN latency between two sites, seconds (zero when
    /// `from == to`).
    pub fn wan_latency_s(&self, from: SiteId, to: SiteId) -> f64 {
        self.wan_latency_s[from.index() * self.sites() + to.index()]
    }

    /// Conservative lookahead into site `to`: the minimum one-way WAN
    /// latency over all *other* sites, i.e. the earliest any cross-site
    /// message emitted "now" can arrive. The parallel engine
    /// ([`crate::sim::parallel`]) lets `to` safely execute up to
    /// `min(next event times) + lookahead_in(to)`. `∞` for a
    /// single-site topology (nothing can send to it).
    pub fn lookahead_in(&self, to: SiteId) -> f64 {
        let n = self.sites();
        (0..n)
            .filter(|&j| j != to.index())
            .map(|j| self.wan_latency_s[j * n + to.index()])
            .fold(f64::INFINITY, f64::min)
    }

    /// The lookahead floor across all sites (`∞` for a single site):
    /// the tightest bound any site's window is subject to.
    pub fn lookahead_floor(&self) -> f64 {
        (0..self.sites() as u32)
            .map(|s| self.lookahead_in(SiteId(s)))
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiteConfig;
    use crate::util::units::gbps;

    fn two_site_cfg() -> Config {
        let mut cfg = Config::with_nodes(12);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 8, wan_bps: gbps(0.5), wan_latency_s: 0.02, ..SiteConfig::default() },
            SiteConfig { nodes: 4, wan_bps: gbps(0.2), wan_latency_s: 0.03, ..SiteConfig::default() },
        ];
        cfg
    }

    #[test]
    fn topology_partitions_executors_contiguously() {
        let topo = Topology::from_config(&two_site_cfg());
        assert_eq!(topo.sites(), 2);
        assert_eq!(topo.nodes(), 12);
        assert_eq!(topo.executor_range(SiteId(0)), 0..8);
        assert_eq!(topo.executor_range(SiteId(1)), 8..12);
        for e in 0..8 {
            assert_eq!(topo.site_of(e), SiteId(0));
        }
        for e in 8..12 {
            assert_eq!(topo.site_of(e), SiteId(1));
        }
        // Stale / out-of-range ids clamp rather than panic.
        assert_eq!(topo.site_of(99), SiteId(1));
    }

    #[test]
    fn wan_matrix_takes_min_uplink_and_summed_latency() {
        let topo = Topology::from_config(&two_site_cfg());
        let (a, b) = (SiteId(0), SiteId(1));
        assert!((topo.wan_bps(a, b) - gbps(0.2)).abs() < 1.0, "min of uplinks");
        assert!((topo.wan_bps(b, a) - gbps(0.2)).abs() < 1.0);
        assert!((topo.wan_latency_s(a, b) - 0.05).abs() < 1e-12, "sum of latencies");
        assert!((topo.wan_latency_s(a, a)).abs() < 1e-12);
    }

    #[test]
    fn lookahead_is_the_min_incoming_latency() {
        let mut cfg = Config::with_nodes(9);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 3, wan_latency_s: 0.02, ..SiteConfig::default() },
            SiteConfig { nodes: 3, wan_latency_s: 0.03, ..SiteConfig::default() },
            SiteConfig { nodes: 3, wan_latency_s: 0.10, ..SiteConfig::default() },
        ];
        let topo = Topology::from_config(&cfg);
        // Into site 0: min(0.03+0.02, 0.10+0.02) = 0.05; into site 2 the
        // cheapest sender is site 0 (0.02+0.10).
        assert!((topo.lookahead_in(SiteId(0)) - 0.05).abs() < 1e-12);
        assert!((topo.lookahead_in(SiteId(2)) - 0.12).abs() < 1e-12);
        assert!((topo.lookahead_floor() - 0.05).abs() < 1e-12);
        // Single site: unbounded window.
        let single = Topology::from_config(&Config::with_nodes(4));
        assert_eq!(single.lookahead_in(SiteId::HOME), f64::INFINITY);
    }

    #[test]
    fn single_site_topology_is_degenerate() {
        let topo = Topology::from_config(&Config::with_nodes(5));
        assert!(topo.is_single());
        assert_eq!(topo.sites(), 1);
        assert_eq!(topo.executor_range(SiteId::HOME), 0..5);
        assert_eq!(topo.site_of(4), SiteId::HOME);
    }
}
