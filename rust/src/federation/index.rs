//! Federated cache-location lookup: per-site directories under one roof.
//!
//! Each site keeps its own location directory (a zero-cost
//! [`CentralIndex`] slice — the *intra-site* lookup cost is already
//! charged by the site's own `DataIndex` backend; this layer only prices
//! the *cross-site* part). [`GlobalIndex::locate`] resolves an object by
//! asking the querying site's own directory first, then peers in
//! ascending site order, charging one WAN round-trip per off-site
//! directory consulted.
//!
//! Because inserts route by the owning site of the caching executor, a
//! site's directory can only ever name executors inside that site's
//! range — the invariant the federation property tests pin.

use crate::index::{CentralIndex, DataIndex, ExecutorId, LookupCost};
use crate::storage::object::ObjectId;

use super::{SiteId, Topology};

/// Thin federation layer over per-site location directories.
#[derive(Debug)]
pub struct GlobalIndex {
    topo: Topology,
    per_site: Vec<CentralIndex>,
}

impl GlobalIndex {
    /// One empty directory per site in `topo`.
    pub fn new(topo: Topology) -> GlobalIndex {
        let per_site = (0..topo.sites()).map(|_| CentralIndex::with_cost(0.0)).collect();
        GlobalIndex { topo, per_site }
    }

    /// Record that `exec` (at its owning site) now caches `obj`.
    pub fn insert(&mut self, obj: ObjectId, exec: ExecutorId) {
        let s = self.topo.site_of(exec);
        self.per_site[s.index()].insert(obj, exec);
    }

    /// Forget one replica.
    pub fn remove(&mut self, obj: ObjectId, exec: ExecutorId) {
        let s = self.topo.site_of(exec);
        self.per_site[s.index()].remove(obj, exec);
    }

    /// Drop every entry naming `exec` (site departure / churn).
    pub fn drop_executor(&mut self, exec: ExecutorId) -> Vec<ObjectId> {
        let s = self.topo.site_of(exec);
        self.per_site[s.index()].drop_executor(exec)
    }

    /// Find a site caching `obj`, searching the querying site's own
    /// directory first and then peers in ascending site order. The cost
    /// charges one lookup per directory consulted plus a WAN round-trip
    /// (and a hop) for each *off-site* directory.
    pub fn locate(
        &self,
        from: SiteId,
        obj: ObjectId,
    ) -> (Option<(SiteId, &[ExecutorId])>, LookupCost) {
        let mut cost = LookupCost::ZERO;
        let order = std::iter::once(from)
            .chain((0..self.topo.sites() as u32).map(SiteId).filter(|&s| s != from));
        for s in order {
            cost.lookups += 1;
            if s != from {
                cost.hops += 1;
                cost.latency_s += 2.0 * self.topo.wan_latency_s(from, s);
            }
            let locs = self.per_site[s.index()].locations(obj);
            if !locs.is_empty() {
                return (Some((s, locs)), cost);
            }
        }
        (None, cost)
    }

    /// Executors at site `s` caching `obj` (empty if none).
    pub fn site_locations(&self, s: SiteId, obj: ObjectId) -> &[ExecutorId] {
        self.per_site[s.index()].locations(obj)
    }

    /// Total location entries across all site directories.
    pub fn entries(&self) -> usize {
        self.per_site.iter().map(|i| i.entries()).sum()
    }

    /// The topology this index partitions by.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, SiteConfig};

    fn topo2() -> Topology {
        let mut cfg = Config::with_nodes(12);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 8, ..SiteConfig::default() },
            SiteConfig { nodes: 4, ..SiteConfig::default() },
        ];
        Topology::from_config(&cfg)
    }

    #[test]
    fn inserts_route_to_owning_site() {
        let mut g = GlobalIndex::new(topo2());
        g.insert(ObjectId(1), 2); // site 0
        g.insert(ObjectId(1), 9); // site 1
        assert_eq!(g.site_locations(SiteId(0), ObjectId(1)), &[2]);
        assert_eq!(g.site_locations(SiteId(1), ObjectId(1)), &[9]);
        assert_eq!(g.entries(), 2);
        g.remove(ObjectId(1), 9);
        assert!(g.site_locations(SiteId(1), ObjectId(1)).is_empty());
    }

    #[test]
    fn locate_prefers_home_and_charges_wan_for_peers() {
        let mut g = GlobalIndex::new(topo2());
        g.insert(ObjectId(7), 1); // site 0
        g.insert(ObjectId(7), 10); // site 1

        // Both sites hold it: each site finds its own copy for free.
        let (hit, cost) = g.locate(SiteId(1), ObjectId(7));
        assert_eq!(hit, Some((SiteId(1), &[10usize][..])));
        assert_eq!((cost.lookups, cost.hops), (1, 0));
        assert!(cost.latency_s.abs() < 1e-12);

        // Only site 0 holds it: site 1 pays one WAN round-trip.
        g.remove(ObjectId(7), 10);
        let (hit, cost) = g.locate(SiteId(1), ObjectId(7));
        assert_eq!(hit, Some((SiteId(0), &[1usize][..])));
        assert_eq!((cost.lookups, cost.hops), (2, 1));
        let rtt = 2.0 * g.topology().wan_latency_s(SiteId(1), SiteId(0));
        assert!((cost.latency_s - rtt).abs() < 1e-12);

        // Nowhere: every directory consulted, all misses charged.
        let (hit, cost) = g.locate(SiteId(0), ObjectId(99));
        assert_eq!(hit, None);
        assert_eq!((cost.lookups, cost.hops), (2, 1));
    }

    #[test]
    fn drop_executor_clears_only_its_site() {
        let mut g = GlobalIndex::new(topo2());
        g.insert(ObjectId(1), 3);
        g.insert(ObjectId(2), 3);
        g.insert(ObjectId(1), 11);
        let mut dropped = g.drop_executor(3);
        dropped.sort_unstable();
        assert_eq!(dropped, vec![ObjectId(1), ObjectId(2)]);
        assert_eq!(g.site_locations(SiteId(1), ObjectId(1)), &[11]);
        assert_eq!(g.entries(), 1);
    }
}
