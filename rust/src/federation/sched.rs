//! Site placement: ship-task vs ship-data (Pilot-Data §affinity).
//!
//! For every submitted task the federation must pick the site it runs
//! at. [`FederationScheduler`] implements the affinity policy from
//! Pilot-Data (arXiv:1301.6228) — estimate the WAN time to move each
//! input to each candidate site, add a queue-depth penalty, run where
//! the sum is smallest — plus the two baselines the `fig_federation`
//! sweep measures it against ([`PlacementMode::AlwaysHome`],
//! [`PlacementMode::RandomSite`]).
//!
//! Origins are synthetic: task `t`'s submitting user lives at a site
//! derived deterministically from `t` (so reruns are reproducible), with
//! a configurable `skew` fraction pinned to the home site to model the
//! common one-hot-site workload.

use crate::util::rng::Rng;

use super::{SiteId, Topology};

/// Distinguishes the origin draw from the random-placement draw so the
/// two hash streams stay independent for the same task id.
const ORIGIN_SALT: u64 = 0x9E6C_8FBB_52B8_3E55;
const RANDOM_SALT: u64 = 0xC2B2_AE3D_27D4_EB4F;

/// Which site-placement policy the federation runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementMode {
    /// Pilot-Data affinity: weigh estimated WAN transfer time of the
    /// missing inputs against remote queue depth, run at the argmin.
    #[default]
    Affinity,
    /// Always run at the task's origin site (no federation awareness).
    AlwaysHome,
    /// Uniform-random site (load spreading with no data awareness).
    RandomSite,
}

impl PlacementMode {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<PlacementMode> {
        match s {
            "affinity" => Some(PlacementMode::Affinity),
            "home" | "always_home" => Some(PlacementMode::AlwaysHome),
            "random" | "random_site" => Some(PlacementMode::RandomSite),
            _ => None,
        }
    }

    /// Canonical label (CSV columns, figure legends).
    pub fn label(self) -> &'static str {
        match self {
            PlacementMode::Affinity => "affinity",
            PlacementMode::AlwaysHome => "home",
            PlacementMode::RandomSite => "random",
        }
    }
}

/// A candidate site's scheduling load, as seen at submit time.
#[derive(Debug, Clone, Copy)]
pub struct SiteLoad {
    /// Tasks waiting (not yet dispatched) at the site.
    pub queued: usize,
    /// Executors currently registered at the site.
    pub executors: usize,
}

/// Picks the run site for each task (see module docs).
#[derive(Debug, Clone)]
pub struct FederationScheduler {
    topo: Topology,
    mode: PlacementMode,
    /// Fraction of task origins pinned to the home site; the rest are
    /// uniform across all sites.
    skew: f64,
    /// Seconds of estimated delay charged per queued-task-per-executor
    /// at a candidate site (converts queue depth into the same unit as
    /// WAN transfer time).
    queue_weight_s: f64,
    seed: u64,
}

impl FederationScheduler {
    /// Build a scheduler over `topo` with the configured policy knobs.
    pub fn new(
        topo: Topology,
        mode: PlacementMode,
        skew: f64,
        queue_weight_s: f64,
        seed: u64,
    ) -> FederationScheduler {
        FederationScheduler {
            topo,
            mode,
            skew,
            queue_weight_s,
            seed,
        }
    }

    /// The placement policy in force.
    pub fn mode(&self) -> PlacementMode {
        self.mode
    }

    /// The site task `task` originates from: home with probability
    /// `skew`, else uniform. Deterministic in (seed, task).
    pub fn origin_site(&self, task: u64) -> SiteId {
        let n = self.topo.sites();
        if n <= 1 {
            return SiteId::HOME;
        }
        let mut r = Rng::new(self.seed ^ task.wrapping_mul(ORIGIN_SALT));
        if r.next_f64() < self.skew {
            SiteId::HOME
        } else {
            SiteId(r.below(n as u64) as u32)
        }
    }

    /// Pick the site task `task` runs at. `inputs` is `(stored bytes,
    /// holding site if some cache has it)` per input — inputs nowhere
    /// cached fall back to GPFS at the home site. `load` must have one
    /// entry per site.
    pub fn choose(&self, task: u64, inputs: &[(u64, Option<SiteId>)], load: &[SiteLoad]) -> SiteId {
        let n = self.topo.sites();
        if n <= 1 {
            return SiteId::HOME;
        }
        match self.mode {
            PlacementMode::AlwaysHome => self.origin_site(task),
            PlacementMode::RandomSite => {
                let mut r = Rng::new(self.seed ^ task.wrapping_mul(RANDOM_SALT));
                SiteId(r.below(n as u64) as u32)
            }
            PlacementMode::Affinity => {
                let mut best = SiteId::HOME;
                let mut best_score = f64::INFINITY;
                for s in 0..n {
                    let site = SiteId(s as u32);
                    let score = self.affinity_score(site, inputs, &load[s]);
                    if score < best_score {
                        best_score = score;
                        best = site;
                    }
                }
                best
            }
        }
    }

    /// Estimated seconds until task start if placed at `site`: WAN time
    /// for every input not already there, plus the queue penalty.
    fn affinity_score(&self, site: SiteId, inputs: &[(u64, Option<SiteId>)], load: &SiteLoad) -> f64 {
        let mut score = 0.0;
        for &(bytes, holder) in inputs {
            let src = holder.unwrap_or(SiteId::HOME);
            if src != site {
                let bps = self.topo.wan_bps(src, site).max(1.0);
                score += bytes as f64 * 8.0 / bps + self.topo.wan_latency_s(src, site);
            }
        }
        score + self.queue_weight_s * load.queued as f64 / load.executors.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, SiteConfig};
    use crate::util::units::{gbps, MB};

    fn topo2() -> Topology {
        let mut cfg = Config::with_nodes(8);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 4, ..SiteConfig::default() },
            SiteConfig { nodes: 4, ..SiteConfig::default() },
        ];
        Topology::from_config(&cfg)
    }

    fn idle(sites: usize) -> Vec<SiteLoad> {
        vec![SiteLoad { queued: 0, executors: 4 }; sites]
    }

    #[test]
    fn affinity_follows_the_data() {
        let sched =
            FederationScheduler::new(topo2(), PlacementMode::Affinity, 0.0, 1.0, 42);
        // One big input cached at site 1: ship the task there.
        let inputs = [(100 * MB, Some(SiteId(1)))];
        assert_eq!(sched.choose(7, &inputs, &idle(2)), SiteId(1));
        // Uncached input: GPFS lives at home, stay home.
        let inputs = [(100 * MB, None)];
        assert_eq!(sched.choose(7, &inputs, &idle(2)), SiteId::HOME);
    }

    #[test]
    fn deep_queues_overcome_affinity() {
        let sched =
            FederationScheduler::new(topo2(), PlacementMode::Affinity, 0.0, 1.0, 42);
        let inputs = [(MB, Some(SiteId(1)))];
        // ~1 MB over a 0.2 Gb/s WAN is ~0.04 s; a 4-deep-per-executor
        // queue at site 1 costs 4 s — run at the idle home site instead.
        let load = [
            SiteLoad { queued: 0, executors: 4 },
            SiteLoad { queued: 16, executors: 4 },
        ];
        assert_eq!(sched.choose(7, &inputs, &load), SiteId::HOME);
    }

    #[test]
    fn origin_skew_pins_to_home() {
        let pinned =
            FederationScheduler::new(topo2(), PlacementMode::Affinity, 1.0, 1.0, 42);
        for t in 0..200 {
            assert_eq!(pinned.origin_site(t), SiteId::HOME);
        }
        let uniform =
            FederationScheduler::new(topo2(), PlacementMode::Affinity, 0.0, 1.0, 42);
        let offsite = (0..200).filter(|&t| uniform.origin_site(t) != SiteId::HOME).count();
        assert!(offsite > 50, "uniform origins must reach other sites: {offsite}");
        // Deterministic in (seed, task).
        assert_eq!(uniform.origin_site(17), uniform.origin_site(17));
    }

    #[test]
    fn baselines_ignore_data_location() {
        let inputs = [(100 * MB, Some(SiteId(1)))];
        let home =
            FederationScheduler::new(topo2(), PlacementMode::AlwaysHome, 1.0, 1.0, 42);
        assert_eq!(home.choose(3, &inputs, &idle(2)), home.origin_site(3));
        let random =
            FederationScheduler::new(topo2(), PlacementMode::RandomSite, 0.0, 1.0, 42);
        let hits: Vec<SiteId> = (0..100).map(|t| random.choose(t, &inputs, &idle(2))).collect();
        assert!(hits.iter().any(|&s| s == SiteId(0)));
        assert!(hits.iter().any(|&s| s == SiteId(1)));
    }

    #[test]
    fn mode_labels_round_trip() {
        for mode in [
            PlacementMode::Affinity,
            PlacementMode::AlwaysHome,
            PlacementMode::RandomSite,
        ] {
            assert_eq!(PlacementMode::parse(mode.label()), Some(mode));
        }
        assert_eq!(PlacementMode::parse("bogus"), None);
    }

    #[test]
    fn single_site_short_circuits() {
        let topo = Topology::from_config(&Config::with_nodes(4));
        let sched =
            FederationScheduler::new(topo, PlacementMode::RandomSite, 0.5, 1.0, 42);
        assert_eq!(sched.origin_site(9), SiteId::HOME);
        assert_eq!(sched.choose(9, &[(MB, None)], &idle(1)), SiteId::HOME);
    }

    #[test]
    fn wan_bandwidth_asymmetry_matters() {
        // Site 2 has a fat uplink; data there is cheap to leave behind.
        let mut cfg = Config::with_nodes(12);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 4, wan_bps: gbps(0.5), ..SiteConfig::default() },
            SiteConfig { nodes: 4, wan_bps: gbps(0.01), ..SiteConfig::default() },
            SiteConfig { nodes: 4, wan_bps: gbps(0.5), ..SiteConfig::default() },
        ];
        let topo = Topology::from_config(&cfg);
        let sched = FederationScheduler::new(topo, PlacementMode::Affinity, 0.0, 1.0, 42);
        // Input pinned behind site 1's thin uplink: fetching it anywhere
        // else costs ~80 s, so affinity ships the task to site 1.
        let inputs = [(100 * MB, Some(SiteId(1)))];
        assert_eq!(sched.choose(7, &inputs, &idle(3)), SiteId(1));
    }
}
