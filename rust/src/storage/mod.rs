//! Storage substrates.
//!
//! * [`object`] — data-object identity and the persistent-store catalog
//!   (what exists, how big it is, compressed/uncompressed variants).
//! * [`testbed`] — the simulated testbed's capacity resources (GPFS pools,
//!   per-node NICs and disks, the metadata server) expressed over the
//!   [`crate::sim::flownet`] fair-share network. Every §4/§5 experiment's
//!   contention behaviour comes from this wiring.
//! * [`live`] — the live backend: a real directory tree as persistent
//!   storage, real per-executor cache directories, real gzip
//!   (de)compression. Used by the end-to-end example and integration
//!   tests; the coordinator code is identical in both modes.

pub mod live;
pub mod object;
pub mod testbed;

pub use object::{Catalog, DataFormat, ObjectId};
pub use testbed::{ResourceSet, SimTestbed, TransferKind};
