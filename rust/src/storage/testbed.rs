//! Simulated testbed resources over the fair-share flow network.
//!
//! Wires the paper's ANL/UC testbed (Table 1 + §4.2 measurements) as
//! capacity resources:
//!
//! * one aggregate **GPFS read pool** (3.4 Gb/s) and **GPFS write pool**
//!   (calibrated so mixed read+write saturates at ~1.1 Gb/s combined) —
//!   the 8 I/O servers are modeled as the aggregate cap, which is what
//!   the paper's own figures resolve;
//! * one **GPFS metadata server** (FIFO, fixed per-op cost) — the
//!   resource that caps the wrapper configuration at ~21 tasks/s;
//! * per node: **NIC-in / NIC-out** (1 Gb/s each) and **disk read /
//!   disk write** pools (470 / 230 Mb/s, §4.2's 76 Gb/s / 162 nodes).
//!
//! Every data movement is a flow across the right set of these resources
//! ([`TransferKind::resources`]); saturation curves, the 8-node GPFS
//! crossover, and linear cache scaling all emerge from max-min sharing.

use crate::config::Config;
use crate::sim::flownet::{FlowNetwork, ResourceId};
use crate::sim::server::FifoServer;

/// A transfer's resource set, inline and `Copy` (at most four legs), so
/// the per-flow hot path allocates nothing. Derefs to `[ResourceId]`.
#[derive(Debug, Clone, Copy)]
pub struct ResourceSet {
    ids: [ResourceId; 4],
    len: u8,
}

impl ResourceSet {
    fn new(ids: &[ResourceId]) -> Self {
        debug_assert!(!ids.is_empty() && ids.len() <= 4);
        let mut set = ResourceSet {
            ids: [ResourceId(0); 4],
            len: ids.len() as u8,
        };
        set.ids[..ids.len()].copy_from_slice(ids);
        set
    }
}

impl std::ops::Deref for ResourceSet {
    type Target = [ResourceId];
    fn deref(&self) -> &[ResourceId] {
        &self.ids[..self.len as usize]
    }
}

/// Per-node resource handles.
#[derive(Debug, Clone, Copy)]
pub struct NodeResources {
    /// NIC ingress capacity.
    pub nic_in: ResourceId,
    /// NIC egress capacity.
    pub nic_out: ResourceId,
    /// Local disk read bandwidth.
    pub disk_read: ResourceId,
    /// Local disk write bandwidth.
    pub disk_write: ResourceId,
}

/// What a transfer is, in terms the coordinator understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Read from persistent storage into node memory (no caching).
    GpfsRead { node: usize },
    /// Read from persistent storage and persist into the node cache
    /// (adds the local disk-write leg).
    GpfsReadCached { node: usize },
    /// Write a result back to persistent storage.
    GpfsWrite { node: usize },
    /// Cache-to-cache fetch from a peer executor (GridFTP path).
    Peer { src: usize, dst: usize },
    /// Read from the node's own cache.
    LocalRead { node: usize },
    /// Write to the node's own cache/scratch.
    LocalWrite { node: usize },
}

/// The wired testbed: flow network + resource handles + metadata server.
pub struct SimTestbed {
    /// The underlying fair-share network.
    pub net: FlowNetwork,
    /// GPFS aggregate read pool.
    pub gpfs_read: ResourceId,
    /// GPFS aggregate write pool.
    pub gpfs_write: ResourceId,
    /// GPFS per-client share caps (one per node) — a single client can't
    /// pull more than ~its NIC from GPFS even when alone.
    pub nodes: Vec<NodeResources>,
    /// GPFS metadata server (opens, wrapper mkdir/symlink/rmdir).
    pub metadata: FifoServer,
}

impl SimTestbed {
    /// Build the testbed for `cfg.testbed.nodes` nodes.
    pub fn new(cfg: &Config) -> Self {
        let mut net = FlowNetwork::new();
        let gpfs_read = net.add_resource(cfg.shared_fs.read_cap_bps);
        let gpfs_write = net.add_resource(cfg.shared_fs.write_cap_bps);
        let nodes = (0..cfg.testbed.nodes)
            .map(|_| NodeResources {
                nic_in: net.add_resource(cfg.testbed.nic_bps),
                nic_out: net.add_resource(cfg.testbed.nic_bps),
                disk_read: net.add_resource(cfg.local_disk.read_bps),
                disk_write: net.add_resource(cfg.local_disk.write_bps),
            })
            .collect();
        SimTestbed {
            net,
            gpfs_read,
            gpfs_write,
            nodes,
            metadata: FifoServer::new(cfg.shared_fs.meta_op_s),
        }
    }

    /// Resource set a transfer of the given kind crosses (inline `Copy`
    /// set — no allocation; pair with `FlowNetwork::start_flow_on`).
    pub fn resource_set(&self, kind: TransferKind) -> ResourceSet {
        match kind {
            TransferKind::GpfsRead { node } => {
                ResourceSet::new(&[self.gpfs_read, self.nodes[node].nic_in])
            }
            TransferKind::GpfsReadCached { node } => ResourceSet::new(&[
                self.gpfs_read,
                self.nodes[node].nic_in,
                self.nodes[node].disk_write,
            ]),
            TransferKind::GpfsWrite { node } => {
                ResourceSet::new(&[self.gpfs_write, self.nodes[node].nic_out])
            }
            TransferKind::Peer { src, dst } => ResourceSet::new(&[
                self.nodes[src].disk_read,
                self.nodes[src].nic_out,
                self.nodes[dst].nic_in,
                self.nodes[dst].disk_write,
            ]),
            TransferKind::LocalRead { node } => ResourceSet::new(&[self.nodes[node].disk_read]),
            TransferKind::LocalWrite { node } => ResourceSet::new(&[self.nodes[node].disk_write]),
        }
    }

    /// Resource set a transfer of the given kind crosses, as an owned
    /// vector (benchmark/test convenience).
    pub fn resources(&self, kind: TransferKind) -> Vec<ResourceId> {
        self.resource_set(kind).to_vec()
    }

    /// Number of nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::units::{gbps, MB};

    fn testbed(n: usize) -> SimTestbed {
        SimTestbed::new(&Config::with_nodes(n))
    }

    #[test]
    fn gpfs_saturates_at_aggregate_cap() {
        // 64 nodes all reading from GPFS: aggregate pinned at 3.4 Gb/s.
        let mut tb = testbed(64);
        let flows: Vec<_> = (0..64)
            .map(|n| {
                let rs = tb.resources(TransferKind::GpfsRead { node: n });
                tb.net.start_flow(0.0, rs, 100 * MB)
            })
            .collect();
        let agg: f64 = flows.iter().map(|&f| tb.net.rate(f)).sum();
        assert!((agg - gbps(3.4)).abs() < 1.0, "agg={agg}");
    }

    #[test]
    fn single_gpfs_client_is_nic_bound() {
        // One client alone: NIC (1 Gb/s) binds before GPFS (3.4 Gb/s).
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::GpfsRead { node: 0 });
        let f = tb.net.start_flow(0.0, rs, 100 * MB);
        assert!((tb.net.rate(f) - gbps(1.0)).abs() < 1.0);
    }

    #[test]
    fn local_reads_scale_linearly() {
        let mut tb = testbed(64);
        let flows: Vec<_> = (0..64)
            .map(|n| {
                let rs = tb.resources(TransferKind::LocalRead { node: n });
                tb.net.start_flow(0.0, rs, 100 * MB)
            })
            .collect();
        let agg: f64 = flows.iter().map(|&f| tb.net.rate(f)).sum();
        // 64 × 470 Mb/s ≈ 30 Gb/s — vs GPFS's fixed 3.4.
        assert!((agg - 64.0 * 470e6).abs() < 1.0, "agg={agg}");
    }

    #[test]
    fn peer_transfer_crosses_both_nics_and_disks() {
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::Peer { src: 0, dst: 1 });
        assert_eq!(rs.len(), 4);
        let f = tb.net.start_flow(0.0, rs, 100 * MB);
        // Bound by dst disk write (230 Mb/s), the tightest leg.
        assert!((tb.net.rate(f) - 230e6).abs() < 1.0);
    }

    #[test]
    fn cached_gpfs_read_bound_by_disk_write() {
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::GpfsReadCached { node: 2 });
        let f = tb.net.start_flow(0.0, rs, 100 * MB);
        assert!((tb.net.rate(f) - 230e6).abs() < 1.0);
    }

    #[test]
    fn resource_set_matches_vec_for_every_kind() {
        let tb = testbed(4);
        for kind in [
            TransferKind::GpfsRead { node: 1 },
            TransferKind::GpfsReadCached { node: 2 },
            TransferKind::GpfsWrite { node: 0 },
            TransferKind::Peer { src: 0, dst: 3 },
            TransferKind::LocalRead { node: 2 },
            TransferKind::LocalWrite { node: 1 },
        ] {
            assert_eq!(&*tb.resource_set(kind), tb.resources(kind).as_slice());
        }
    }
}
