//! Simulated testbed resources over the fair-share flow network.
//!
//! Wires the paper's ANL/UC testbed (Table 1 + §4.2 measurements) as
//! capacity resources:
//!
//! * one aggregate **GPFS read pool** (3.4 Gb/s) and **GPFS write pool**
//!   (calibrated so mixed read+write saturates at ~1.1 Gb/s combined) —
//!   the 8 I/O servers are modeled as the aggregate cap, which is what
//!   the paper's own figures resolve;
//! * one **GPFS metadata server** (FIFO, fixed per-op cost) — the
//!   resource that caps the wrapper configuration at ~21 tasks/s;
//! * per node: **NIC-in / NIC-out** (1 Gb/s each) and **disk read /
//!   disk write** pools (470 / 230 Mb/s, §4.2's 76 Gb/s / 162 nodes).
//!
//! Every data movement is a flow across the right set of these resources
//! ([`TransferKind::resources`]); saturation curves, the 8-node GPFS
//! crossover, and linear cache scaling all emerge from max-min sharing.
//!
//! With `[[site]]` tables configured, a [`WanFabric`] is wired on top:
//! one aggregate LAN backplane per site plus a directed WAN link per
//! site pair. Non-node-local transfers then also cross their site
//! backplane(s), and cross-site transfers cross the WAN link — as
//! ordinary flow legs, so class weights pace WAN traffic exactly like
//! any other resource. GPFS is homed at site 0: shared-filesystem
//! traffic from any other site traverses the WAN.

use crate::config::Config;
use crate::federation::{SiteId, Topology};
use crate::sim::flownet::{FlowNetwork, ResourceId};
use crate::sim::server::FifoServer;

/// A transfer's resource set, inline and `Copy` (at most eight legs —
/// the cross-site peer path is seven), so the per-flow hot path
/// allocates nothing. Derefs to `[ResourceId]`.
#[derive(Debug, Clone, Copy)]
pub struct ResourceSet {
    ids: [ResourceId; 8],
    len: u8,
}

impl ResourceSet {
    fn new(ids: &[ResourceId]) -> Self {
        debug_assert!(!ids.is_empty() && ids.len() <= 8);
        let mut set = ResourceSet {
            ids: [ResourceId(0); 8],
            len: ids.len() as u8,
        };
        set.ids[..ids.len()].copy_from_slice(ids);
        set
    }
}

impl std::ops::Deref for ResourceSet {
    type Target = [ResourceId];
    fn deref(&self) -> &[ResourceId] {
        &self.ids[..self.len as usize]
    }
}

/// Per-node resource handles.
#[derive(Debug, Clone, Copy)]
pub struct NodeResources {
    /// NIC ingress capacity.
    pub nic_in: ResourceId,
    /// NIC egress capacity.
    pub nic_out: ResourceId,
    /// Local disk read bandwidth.
    pub disk_read: ResourceId,
    /// Local disk write bandwidth.
    pub disk_write: ResourceId,
}

/// What a transfer is, in terms the coordinator understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Read from persistent storage into node memory (no caching).
    GpfsRead { node: usize },
    /// Read from persistent storage and persist into the node cache
    /// (adds the local disk-write leg).
    GpfsReadCached { node: usize },
    /// Write a result back to persistent storage.
    GpfsWrite { node: usize },
    /// Cache-to-cache fetch from a peer executor (GridFTP path).
    Peer { src: usize, dst: usize },
    /// Read from the node's own cache.
    LocalRead { node: usize },
    /// Write to the node's own cache/scratch.
    LocalWrite { node: usize },
}

/// The inter-site fabric: per-site LAN backplanes plus a directed WAN
/// link per site pair (present only with two or more sites).
#[derive(Debug)]
pub struct WanFabric {
    topo: Topology,
    /// Per-site aggregate LAN backplane.
    lan: Vec<ResourceId>,
    /// Row-major `sites × sites` directed WAN links (diagonal unused).
    links: Vec<ResourceId>,
}

impl WanFabric {
    /// Site `s`'s LAN backplane resource.
    pub fn lan(&self, s: SiteId) -> ResourceId {
        self.lan[s.index()]
    }

    /// The directed WAN link from `from` to `to` (`from != to`).
    pub fn wan(&self, from: SiteId, to: SiteId) -> ResourceId {
        self.links[from.index() * self.topo.sites() + to.index()]
    }

    /// The site topology this fabric was wired from.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }
}

/// The wired testbed: flow network + resource handles + metadata server.
pub struct SimTestbed {
    /// The underlying fair-share network.
    pub net: FlowNetwork,
    /// GPFS aggregate read pool.
    pub gpfs_read: ResourceId,
    /// GPFS aggregate write pool.
    pub gpfs_write: ResourceId,
    /// GPFS per-client share caps (one per node) — a single client can't
    /// pull more than ~its NIC from GPFS even when alone.
    pub nodes: Vec<NodeResources>,
    /// GPFS metadata server (opens, wrapper mkdir/symlink/rmdir).
    pub metadata: FifoServer,
    /// Inter-site fabric; `None` for single-site configs, whose resource
    /// wiring (and therefore whose simulations) are untouched.
    pub wan: Option<WanFabric>,
}

impl SimTestbed {
    /// Build the testbed for `cfg.testbed.nodes` nodes (plus the WAN
    /// fabric when `[[site]]` tables declare a federation).
    pub fn new(cfg: &Config) -> Self {
        let mut net = FlowNetwork::new();
        let gpfs_read = net.add_resource(cfg.shared_fs.read_cap_bps);
        let gpfs_write = net.add_resource(cfg.shared_fs.write_cap_bps);
        let nodes = (0..cfg.testbed.nodes)
            .map(|_| NodeResources {
                nic_in: net.add_resource(cfg.testbed.nic_bps),
                nic_out: net.add_resource(cfg.testbed.nic_bps),
                disk_read: net.add_resource(cfg.local_disk.read_bps),
                disk_write: net.add_resource(cfg.local_disk.write_bps),
            })
            .collect();
        // Fabric resources append after the single-site set, and only
        // when federated, so existing configs keep identical wiring.
        let wan = (cfg.sites() > 1).then(|| {
            let topo = Topology::from_config(cfg);
            let n = topo.sites();
            let lan = (0..n)
                .map(|s| net.add_resource(topo.lan_bps(SiteId(s as u32))))
                .collect();
            let links = (0..n * n)
                .map(|i| {
                    let (a, b) = (SiteId((i / n) as u32), SiteId((i % n) as u32));
                    net.add_resource(topo.wan_bps(a, b).max(1.0))
                })
                .collect();
            WanFabric { topo, lan, links }
        });
        SimTestbed {
            net,
            gpfs_read,
            gpfs_write,
            nodes,
            metadata: FifoServer::new(cfg.shared_fs.meta_op_s),
            wan,
        }
    }

    /// Whether a transfer of this kind crosses the WAN (always false
    /// without a fabric). GPFS is homed at site 0.
    pub fn cross_site(&self, kind: TransferKind) -> bool {
        let Some(fab) = &self.wan else { return false };
        match kind {
            TransferKind::GpfsRead { node }
            | TransferKind::GpfsReadCached { node }
            | TransferKind::GpfsWrite { node } => fab.topo.site_of(node) != SiteId::HOME,
            TransferKind::Peer { src, dst } => fab.topo.site_of(src) != fab.topo.site_of(dst),
            TransferKind::LocalRead { .. } | TransferKind::LocalWrite { .. } => false,
        }
    }

    /// Resource set a transfer of the given kind crosses (inline `Copy`
    /// set — no allocation; pair with
    /// [`FlowNetwork::start`](crate::sim::flownet::FlowNetwork::start)).
    ///
    /// Without a WAN fabric these are the paper's single-cluster paths.
    /// With one, non-node-local paths gain their site backplane leg(s),
    /// and cross-site paths the WAN link, in path order.
    pub fn resource_set(&self, kind: TransferKind) -> ResourceSet {
        if let Some(fab) = &self.wan {
            return self.federated_set(fab, kind);
        }
        match kind {
            TransferKind::GpfsRead { node } => {
                ResourceSet::new(&[self.gpfs_read, self.nodes[node].nic_in])
            }
            TransferKind::GpfsReadCached { node } => ResourceSet::new(&[
                self.gpfs_read,
                self.nodes[node].nic_in,
                self.nodes[node].disk_write,
            ]),
            TransferKind::GpfsWrite { node } => {
                ResourceSet::new(&[self.gpfs_write, self.nodes[node].nic_out])
            }
            TransferKind::Peer { src, dst } => ResourceSet::new(&[
                self.nodes[src].disk_read,
                self.nodes[src].nic_out,
                self.nodes[dst].nic_in,
                self.nodes[dst].disk_write,
            ]),
            TransferKind::LocalRead { node } => ResourceSet::new(&[self.nodes[node].disk_read]),
            TransferKind::LocalWrite { node } => ResourceSet::new(&[self.nodes[node].disk_write]),
        }
    }

    /// Site-aware path (GPFS homed at site 0; see `resource_set`).
    fn federated_set(&self, fab: &WanFabric, kind: TransferKind) -> ResourceSet {
        let home = SiteId::HOME;
        match kind {
            TransferKind::GpfsRead { node } | TransferKind::GpfsReadCached { node } => {
                let s = fab.topo.site_of(node);
                let mut legs = [ResourceId(0); 8];
                let mut n = 0;
                for leg in [self.gpfs_read, fab.lan(home)] {
                    legs[n] = leg;
                    n += 1;
                }
                if s != home {
                    legs[n] = fab.wan(home, s);
                    legs[n + 1] = fab.lan(s);
                    n += 2;
                }
                legs[n] = self.nodes[node].nic_in;
                n += 1;
                if matches!(kind, TransferKind::GpfsReadCached { .. }) {
                    legs[n] = self.nodes[node].disk_write;
                    n += 1;
                }
                ResourceSet::new(&legs[..n])
            }
            TransferKind::GpfsWrite { node } => {
                let s = fab.topo.site_of(node);
                if s == home {
                    ResourceSet::new(&[self.nodes[node].nic_out, fab.lan(home), self.gpfs_write])
                } else {
                    ResourceSet::new(&[
                        self.nodes[node].nic_out,
                        fab.lan(s),
                        fab.wan(s, home),
                        fab.lan(home),
                        self.gpfs_write,
                    ])
                }
            }
            TransferKind::Peer { src, dst } => {
                let (ss, ds) = (fab.topo.site_of(src), fab.topo.site_of(dst));
                if ss == ds {
                    ResourceSet::new(&[
                        self.nodes[src].disk_read,
                        self.nodes[src].nic_out,
                        fab.lan(ss),
                        self.nodes[dst].nic_in,
                        self.nodes[dst].disk_write,
                    ])
                } else {
                    ResourceSet::new(&[
                        self.nodes[src].disk_read,
                        self.nodes[src].nic_out,
                        fab.lan(ss),
                        fab.wan(ss, ds),
                        fab.lan(ds),
                        self.nodes[dst].nic_in,
                        self.nodes[dst].disk_write,
                    ])
                }
            }
            TransferKind::LocalRead { node } => ResourceSet::new(&[self.nodes[node].disk_read]),
            TransferKind::LocalWrite { node } => ResourceSet::new(&[self.nodes[node].disk_write]),
        }
    }

    /// Resource set a transfer of the given kind crosses, as an owned
    /// vector (benchmark/test convenience).
    pub fn resources(&self, kind: TransferKind) -> Vec<ResourceId> {
        self.resource_set(kind).to_vec()
    }

    // ---- Cross-site leg halves (federated parallel runs) ----
    //
    // The parallel engine gives every site its own world — its own flow
    // network — so a cross-site transfer cannot be one flow over both
    // sites' resources. It is split at the WAN boundary into an egress
    // half owned by the sender (ending at the directed WAN link, which
    // the sender owns) and an ingress half owned by the receiver,
    // started when the data "arrives" as a message. The legs below are
    // the exact halves of `federated_set`'s cross-site paths. All of
    // them require the WAN fabric and panic without one.

    /// Sender half of a cross-site peer fetch out of executor `src`.
    pub fn peer_egress(&self, src: usize, to: SiteId) -> ResourceSet {
        let fab = self.wan.as_ref().expect("peer_egress needs a WAN fabric");
        let ss = fab.topo.site_of(src);
        ResourceSet::new(&[
            self.nodes[src].disk_read,
            self.nodes[src].nic_out,
            fab.lan(ss),
            fab.wan(ss, to),
        ])
    }

    /// Receiver half of any cross-site fetch into executor `dst` (peer
    /// or GPFS data — the local path is the same).
    pub fn site_ingress(&self, dst: usize, caching: bool) -> ResourceSet {
        let fab = self.wan.as_ref().expect("site_ingress needs a WAN fabric");
        let ds = fab.topo.site_of(dst);
        if caching {
            ResourceSet::new(&[fab.lan(ds), self.nodes[dst].nic_in, self.nodes[dst].disk_write])
        } else {
            ResourceSet::new(&[fab.lan(ds), self.nodes[dst].nic_in])
        }
    }

    /// Home half of a remote GPFS read toward site `to`.
    pub fn gpfs_egress(&self, to: SiteId) -> ResourceSet {
        let fab = self.wan.as_ref().expect("gpfs_egress needs a WAN fabric");
        ResourceSet::new(&[self.gpfs_read, fab.lan(SiteId::HOME), fab.wan(SiteId::HOME, to)])
    }

    /// Sender half of a remote GPFS write out of executor `src`.
    pub fn gpfs_write_egress(&self, src: usize) -> ResourceSet {
        let fab = self.wan.as_ref().expect("gpfs_write_egress needs a WAN fabric");
        let ss = fab.topo.site_of(src);
        ResourceSet::new(&[self.nodes[src].nic_out, fab.lan(ss), fab.wan(ss, SiteId::HOME)])
    }

    /// Home half of a remote GPFS write.
    pub fn gpfs_write_ingress(&self) -> ResourceSet {
        let fab = self.wan.as_ref().expect("gpfs_write_ingress needs a WAN fabric");
        ResourceSet::new(&[fab.lan(SiteId::HOME), self.gpfs_write])
    }

    /// Number of nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, SiteConfig};
    use crate::sim::flownet::FlowSpec;
    use crate::util::units::{gbps, MB};

    fn testbed(n: usize) -> SimTestbed {
        SimTestbed::new(&Config::with_nodes(n))
    }

    /// 2×4-node federation with a 0.2 Gb/s WAN bottleneck at site 1.
    fn federated() -> SimTestbed {
        let mut cfg = Config::with_nodes(8);
        cfg.federation.sites = vec![
            SiteConfig { nodes: 4, wan_bps: gbps(1.0), ..SiteConfig::default() },
            SiteConfig { nodes: 4, wan_bps: gbps(0.2), ..SiteConfig::default() },
        ];
        SimTestbed::new(&cfg)
    }

    #[test]
    fn gpfs_saturates_at_aggregate_cap() {
        // 64 nodes all reading from GPFS: aggregate pinned at 3.4 Gb/s.
        let mut tb = testbed(64);
        let flows: Vec<_> = (0..64)
            .map(|n| {
                let rs = tb.resources(TransferKind::GpfsRead { node: n });
                tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs))
            })
            .collect();
        let agg: f64 = flows.iter().map(|&f| tb.net.rate(f)).sum();
        assert!((agg - gbps(3.4)).abs() < 1.0, "agg={agg}");
    }

    #[test]
    fn single_gpfs_client_is_nic_bound() {
        // One client alone: NIC (1 Gb/s) binds before GPFS (3.4 Gb/s).
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::GpfsRead { node: 0 });
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - gbps(1.0)).abs() < 1.0);
    }

    #[test]
    fn local_reads_scale_linearly() {
        let mut tb = testbed(64);
        let flows: Vec<_> = (0..64)
            .map(|n| {
                let rs = tb.resources(TransferKind::LocalRead { node: n });
                tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs))
            })
            .collect();
        let agg: f64 = flows.iter().map(|&f| tb.net.rate(f)).sum();
        // 64 × 470 Mb/s ≈ 30 Gb/s — vs GPFS's fixed 3.4.
        assert!((agg - 64.0 * 470e6).abs() < 1.0, "agg={agg}");
    }

    #[test]
    fn peer_transfer_crosses_both_nics_and_disks() {
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::Peer { src: 0, dst: 1 });
        assert_eq!(rs.len(), 4);
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        // Bound by dst disk write (230 Mb/s), the tightest leg.
        assert!((tb.net.rate(f) - 230e6).abs() < 1.0);
    }

    #[test]
    fn cached_gpfs_read_bound_by_disk_write() {
        let mut tb = testbed(4);
        let rs = tb.resources(TransferKind::GpfsReadCached { node: 2 });
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - 230e6).abs() < 1.0);
    }

    #[test]
    fn resource_set_matches_vec_for_every_kind() {
        for tb in [testbed(4), federated()] {
            for kind in [
                TransferKind::GpfsRead { node: 1 },
                TransferKind::GpfsReadCached { node: 2 },
                TransferKind::GpfsWrite { node: 0 },
                TransferKind::Peer { src: 0, dst: 3 },
                TransferKind::LocalRead { node: 2 },
                TransferKind::LocalWrite { node: 1 },
            ] {
                assert_eq!(&*tb.resource_set(kind), tb.resources(kind).as_slice());
            }
        }
    }

    #[test]
    fn single_site_config_builds_no_fabric() {
        let tb = testbed(4);
        assert!(tb.wan.is_none());
        assert!(!tb.cross_site(TransferKind::Peer { src: 0, dst: 3 }));
    }

    #[test]
    fn cross_site_peer_is_wan_bound() {
        let mut tb = federated();
        // Node 1 (site 0) → node 5 (site 1): 7 legs, WAN tightest.
        let rs = tb.resources(TransferKind::Peer { src: 1, dst: 5 });
        assert_eq!(rs.len(), 7);
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - gbps(0.2)).abs() < 1.0, "WAN binds below disk write");
        // Same-site peer stays disk-write bound, with its LAN leg.
        let rs = tb.resources(TransferKind::Peer { src: 0, dst: 1 });
        assert_eq!(rs.len(), 5);
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - 230e6).abs() < 1.0);
    }

    #[test]
    fn remote_gpfs_read_traverses_wan() {
        let mut tb = federated();
        // Site 1 reading GPFS (homed at site 0): WAN (0.2 Gb/s) binds
        // below the NIC (1 Gb/s) and GPFS (3.4 Gb/s).
        let rs = tb.resources(TransferKind::GpfsRead { node: 6 });
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - gbps(0.2)).abs() < 1.0);
        // Home-site reads keep their NIC bound.
        let rs = tb.resources(TransferKind::GpfsRead { node: 0 });
        let f = tb.net.start(0.0, FlowSpec::new(100 * MB).over(&rs));
        assert!((tb.net.rate(f) - gbps(1.0)).abs() < 1.0);
    }

    #[test]
    fn cross_site_halves_union_to_the_full_path() {
        let tb = federated();
        // Peer: node 1 (site 0) → node 5 (site 1), cached at dst.
        let full = tb.resources(TransferKind::Peer { src: 1, dst: 5 });
        let mut halves = tb.peer_egress(1, SiteId(1)).to_vec();
        halves.extend_from_slice(&tb.site_ingress(5, true));
        assert_eq!(full, halves);
        // Remote GPFS read into node 6 (site 1), cached.
        let full = tb.resources(TransferKind::GpfsReadCached { node: 6 });
        let mut halves = tb.gpfs_egress(SiteId(1)).to_vec();
        halves.extend_from_slice(&tb.site_ingress(6, true));
        assert_eq!(full, halves);
        // Remote GPFS write from node 6.
        let full = tb.resources(TransferKind::GpfsWrite { node: 6 });
        let mut halves = tb.gpfs_write_egress(6).to_vec();
        halves.extend_from_slice(&tb.gpfs_write_ingress());
        assert_eq!(full, halves);
    }

    #[test]
    fn cross_site_classification() {
        let tb = federated();
        assert!(tb.cross_site(TransferKind::Peer { src: 0, dst: 5 }));
        assert!(!tb.cross_site(TransferKind::Peer { src: 4, dst: 5 }));
        assert!(tb.cross_site(TransferKind::GpfsRead { node: 5 }));
        assert!(tb.cross_site(TransferKind::GpfsWrite { node: 5 }));
        assert!(!tb.cross_site(TransferKind::GpfsRead { node: 0 }));
        assert!(!tb.cross_site(TransferKind::LocalRead { node: 5 }));
    }
}
