//! Live storage backend: real files, real gzip framing.
//!
//! Used by the end-to-end example and the live integration tests. A
//! directory tree plays the role of GPFS ("persistent storage"); each
//! executor gets a private cache directory on "local disk"; peer fetches
//! copy between cache directories (the GridFTP stand-in — same host here,
//! but the byte movement and accounting are real).
//!
//! Objects are synthetic FITS-like images: a small header plus deterministic
//! PRNG pixel data (int16), optionally gzip-wrapped (the paper's GZ
//! format — via the vendored stored-block codec in [`crate::util::gzip`],
//! so GZ runs pay a real per-fetch decode + integrity check even though
//! the offline build has no `flate2`; the simulator models the 3× size
//! ratio through catalog sizes). Content is derived from the `ObjectId`,
//! so integrity can be verified after any sequence of cache hops.

use std::fs;
use std::path::{Path, PathBuf};

use super::object::{Catalog, DataFormat, ObjectId};
use crate::error::{Error, Result};
use crate::util::gzip;
use crate::util::rng::Rng;

/// Magic prefix of the synthetic FITS-like header.
const MAGIC: &[u8; 8] = b"DDFITS01";

/// Persistent storage backed by a real directory.
pub struct LiveStore {
    root: PathBuf,
    catalog: Catalog,
    format: DataFormat,
}

impl LiveStore {
    /// Create (or reuse) a store rooted at `root`.
    pub fn create<P: AsRef<Path>>(root: P, format: DataFormat) -> Result<LiveStore> {
        fs::create_dir_all(root.as_ref())?;
        Ok(LiveStore {
            root: root.as_ref().to_path_buf(),
            catalog: Catalog::new(),
            format,
        })
    }

    /// Path of an object file.
    pub fn path_of(&self, id: ObjectId) -> PathBuf {
        let ext = match self.format {
            DataFormat::Gz => "fits.gz",
            DataFormat::Fit => "fits",
        };
        self.root.join(format!("{id}.{ext}"))
    }

    /// Store format.
    pub fn format(&self) -> DataFormat {
        self.format
    }

    /// The table of contents.
    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Generate and persist a synthetic image object of `pixels` int16
    /// values. Returns its on-disk size.
    pub fn populate(&mut self, id: ObjectId, pixels: usize) -> Result<u64> {
        let raw = synth_object_bytes(id, pixels);
        let path = self.path_of(id);
        let bytes = match self.format {
            DataFormat::Fit => {
                fs::write(&path, &raw)?;
                raw.len() as u64
            }
            DataFormat::Gz => {
                let gz = gzip::compress(&raw);
                fs::write(&path, &gz)?;
                gz.len() as u64
            }
        };
        self.catalog.insert(id, bytes);
        Ok(bytes)
    }

    /// Read an object's (decompressed) payload from persistent storage.
    pub fn read(&self, id: ObjectId) -> Result<Vec<u8>> {
        let path = self.path_of(id);
        read_object_file(&path, self.format)
    }

    /// Copy the raw on-disk object file to `dst` (a cache dir path),
    /// returning the byte count moved. This is the "fetch from persistent
    /// storage into cache" arrow — bytes move in stored format.
    pub fn fetch_to(&self, id: ObjectId, dst: &Path) -> Result<u64> {
        let src = self.path_of(id);
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let n = fs::copy(&src, dst).map_err(|e| {
            Error::UnknownObject(format!("{id} ({}): {e}", src.display()))
        })?;
        Ok(n)
    }
}

/// Deterministic synthetic object payload: header + int16 pixels.
pub fn synth_object_bytes(id: ObjectId, pixels: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + pixels * 2);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&id.0.to_le_bytes());
    let mut rng = Rng::new(id.0 ^ 0xDD_DA7A);
    let mut run = 0i16;
    for i in 0..pixels {
        // Smooth-ish data so gzip achieves a realistic (~3x) ratio like
        // real sky images, rather than incompressible white noise.
        if i % 64 == 0 {
            run = (rng.below(512) as i16) - 256;
        }
        let v = run + (rng.below(16) as i16);
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Read + (if needed) decompress an object file; verifies the magic.
pub fn read_object_file(path: &Path, format: DataFormat) -> Result<Vec<u8>> {
    let data = fs::read(path)?;
    let raw = match format {
        DataFormat::Fit => data,
        DataFormat::Gz => gzip::decompress(&data)?,
    };
    if raw.len() < 16 || &raw[..8] != MAGIC {
        return Err(Error::UnknownObject(format!(
            "corrupt object at {}",
            path.display()
        )));
    }
    Ok(raw)
}

/// Extract the int16 pixel array from a raw object payload.
pub fn pixels_of(raw: &[u8]) -> Vec<i16> {
    raw[16..]
        .chunks_exact(2)
        .map(|c| i16::from_le_bytes([c[0], c[1]]))
        .collect()
}

/// Per-executor cache directory on "local disk".
pub struct LiveCacheDir {
    root: PathBuf,
}

impl LiveCacheDir {
    /// Create the cache directory for one executor.
    pub fn create<P: AsRef<Path>>(root: P) -> Result<LiveCacheDir> {
        fs::create_dir_all(root.as_ref())?;
        Ok(LiveCacheDir {
            root: root.as_ref().to_path_buf(),
        })
    }

    /// Where object `id` lives in this cache.
    pub fn path_of(&self, id: ObjectId, format: DataFormat) -> PathBuf {
        let ext = match format {
            DataFormat::Gz => "fits.gz",
            DataFormat::Fit => "fits",
        };
        self.root.join(format!("{id}.{ext}"))
    }

    /// Remove a cached object file (eviction). Missing files are fine —
    /// eviction may race with external cleanup.
    pub fn evict(&self, id: ObjectId, format: DataFormat) {
        let _ = fs::remove_file(self.path_of(id, format));
    }

    /// Copy an object to a peer cache (the cache-to-cache arrow).
    pub fn send_to(&self, id: ObjectId, format: DataFormat, peer: &LiveCacheDir) -> Result<u64> {
        let src = self.path_of(id, format);
        let dst = peer.path_of(id, format);
        Ok(fs::copy(src, dst)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dd_live_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_fit() {
        let dir = tmpdir("fit");
        let mut store = LiveStore::create(&dir, DataFormat::Fit).unwrap();
        let id = ObjectId(7);
        let n = store.populate(id, 1000).unwrap();
        assert_eq!(n, 16 + 2000);
        let raw = store.read(id).unwrap();
        assert_eq!(raw, synth_object_bytes(id, 1000));
        assert_eq!(pixels_of(&raw).len(), 1000);
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn roundtrip_gz_preserves_content() {
        let dir = tmpdir("gz");
        let mut store = LiveStore::create(&dir, DataFormat::Gz).unwrap();
        let id = ObjectId(42);
        let stored = store.populate(id, 10_000).unwrap();
        // Vendored gzip uses stored blocks: real framing + CRC, no size
        // reduction (18-byte header/trailer + 5 bytes per 64 KiB block).
        assert_eq!(stored, 16 + 20_000 + 18 + 5, "stored={stored}");
        let raw = store.read(id).unwrap();
        assert_eq!(raw, synth_object_bytes(id, 10_000));
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn fetch_to_cache_and_peer_copy() {
        let dir = tmpdir("fetch");
        let mut store = LiveStore::create(dir.join("gpfs"), DataFormat::Fit).unwrap();
        let id = ObjectId(3);
        store.populate(id, 100).unwrap();

        let c0 = LiveCacheDir::create(dir.join("cache0")).unwrap();
        let c1 = LiveCacheDir::create(dir.join("cache1")).unwrap();
        let moved = store
            .fetch_to(id, &c0.path_of(id, DataFormat::Fit))
            .unwrap();
        assert_eq!(moved, 216);
        let moved2 = c0.send_to(id, DataFormat::Fit, &c1).unwrap();
        assert_eq!(moved2, 216);
        let raw = read_object_file(&c1.path_of(id, DataFormat::Fit), DataFormat::Fit).unwrap();
        assert_eq!(raw, synth_object_bytes(id, 100));
        c1.evict(id, DataFormat::Fit);
        assert!(!c1.path_of(id, DataFormat::Fit).exists());
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupt_object_detected() {
        let dir = tmpdir("corrupt");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.fits");
        fs::write(&p, b"not a fits file at all").unwrap();
        assert!(read_object_file(&p, DataFormat::Fit).is_err());
        let _ = fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_object_is_error() {
        let dir = tmpdir("missing");
        let store = LiveStore::create(&dir, DataFormat::Fit).unwrap();
        assert!(store.read(ObjectId(999)).is_err());
        let _ = fs::remove_dir_all(dir);
    }
}
