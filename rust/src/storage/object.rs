//! Data-object identity and the persistent-store catalog.
//!
//! The paper's unit of data management is the *file* (558,500 of them in
//! the SDSS working set). Executors cache whole objects; the dispatcher's
//! index maps objects to executor locations. An object may exist in a
//! compressed (GZ, 2 MB) and an uncompressed (FIT, 6 MB) variant — the
//! format is part of the workload configuration, not of object identity.

use crate::util::fxhash::FxHashMap;

/// Globally unique data-object (file) identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub u64);

impl std::fmt::Display for ObjectId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "obj{}", self.0)
    }
}

/// On-disk format of the image data (§5: GZ = 2 MB compressed,
/// FIT = 6 MB uncompressed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataFormat {
    /// Gzip-compressed FITS (2 MB in SDSS DR5).
    Gz,
    /// Uncompressed FITS (6 MB).
    Fit,
}

impl DataFormat {
    /// Parse from CLI/config text.
    pub fn parse(s: &str) -> Option<DataFormat> {
        match s.to_ascii_lowercase().as_str() {
            "gz" => Some(DataFormat::Gz),
            "fit" | "fits" => Some(DataFormat::Fit),
            _ => None,
        }
    }

    /// Short label used in figures ("GZ" / "FIT").
    pub fn label(&self) -> &'static str {
        match self {
            DataFormat::Gz => "GZ",
            DataFormat::Fit => "FIT",
        }
    }
}

/// Catalog entry for one object.
#[derive(Debug, Clone, Copy)]
pub struct ObjectMeta {
    /// Size in bytes as stored on persistent storage (depends on the
    /// workload's chosen format).
    pub bytes: u64,
}

/// The persistent store's table of contents.
///
/// In sim mode this is the only representation of the store; in live mode
/// it mirrors the real directory tree.
#[derive(Debug, Default, Clone)]
pub struct Catalog {
    objects: FxHashMap<ObjectId, ObjectMeta>,
    total_bytes: u64,
}

impl Catalog {
    /// Empty catalog.
    pub fn new() -> Self {
        Catalog::default()
    }

    /// Register an object; replaces any previous entry with the same id.
    pub fn insert(&mut self, id: ObjectId, bytes: u64) {
        if let Some(old) = self.objects.insert(id, ObjectMeta { bytes }) {
            self.total_bytes -= old.bytes;
        }
        self.total_bytes += bytes;
    }

    /// Metadata for an object.
    pub fn get(&self, id: ObjectId) -> Option<ObjectMeta> {
        self.objects.get(&id).copied()
    }

    /// Size of an object; errors formatted at the caller.
    pub fn size(&self, id: ObjectId) -> Option<u64> {
        self.get(id).map(|m| m.bytes)
    }

    /// Number of objects.
    pub fn len(&self) -> usize {
        self.objects.len()
    }

    /// Whether the catalog is empty.
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    /// Total bytes across all objects.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Iterate over all object ids (unspecified order).
    pub fn ids(&self) -> impl Iterator<Item = ObjectId> + '_ {
        self.objects.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_lookup() {
        let mut c = Catalog::new();
        c.insert(ObjectId(1), 100);
        c.insert(ObjectId(2), 200);
        assert_eq!(c.size(ObjectId(1)), Some(100));
        assert_eq!(c.size(ObjectId(3)), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.total_bytes(), 300);
    }

    #[test]
    fn reinsert_replaces() {
        let mut c = Catalog::new();
        c.insert(ObjectId(1), 100);
        c.insert(ObjectId(1), 250);
        assert_eq!(c.len(), 1);
        assert_eq!(c.total_bytes(), 250);
    }

    #[test]
    fn format_parse_labels() {
        assert_eq!(DataFormat::parse("gz"), Some(DataFormat::Gz));
        assert_eq!(DataFormat::parse("FIT"), Some(DataFormat::Fit));
        assert_eq!(DataFormat::parse("nope"), None);
        assert_eq!(DataFormat::Gz.label(), "GZ");
    }
}
