//! Replica-placement policies.
//!
//! Once the [`super::ReplicationManager`] decides an object is hot enough
//! to deserve another copy, *where* that copy lands is a policy choice —
//! and per "Data Placement and Replica Selection for Improving
//! Co-location in Distributed Environments" (arXiv:1302.4168) the choice
//! matters as much as the replica count. Three variants:
//!
//! * [`PlacementPolicy::LeastLoaded`] — the executor caching the fewest
//!   objects takes the copy: replicas gravitate toward free cache space,
//!   spreading eviction pressure evenly.
//! * [`PlacementPolicy::HashSpread`] — a deterministic hash of
//!   (object, replica ordinal) picks the destination: copies of one
//!   object land on uncorrelated executors, so no node becomes the
//!   second home of *every* hot object.
//! * [`PlacementPolicy::CoLocate`] — the copy goes to the executor whose
//!   recent tasks most wanted the object without holding it (the demand
//!   signal the manager tracks per executor): data moves *toward* the
//!   compute that keeps asking for it, maximizing future local hits.
//!
//! All three are pure functions of (object, candidates, index state,
//! demand state), so replica placement — like dispatch — replays
//! identically run over run and is index-backend-invariant.

use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::storage::object::ObjectId;

/// Replica destination selector (config / CLI `--replication <policy>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Fewest cached objects wins (ties to the lower id) — the default.
    #[default]
    LeastLoaded,
    /// Deterministic hash of (object, replica ordinal) over the
    /// candidates — decorrelates the replica sets of different objects.
    HashSpread,
    /// Strongest recent unmet demand wins; falls back to least-loaded
    /// when no executor has asked for the object yet.
    CoLocate,
}

impl PlacementPolicy {
    /// Parse from config/CLI text.
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "least-loaded" => Some(PlacementPolicy::LeastLoaded),
            "hash-spread" | "hash" => Some(PlacementPolicy::HashSpread),
            "co-locate" | "colocate" | "co-location" => Some(PlacementPolicy::CoLocate),
            _ => None,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::LeastLoaded => "least-loaded",
            PlacementPolicy::HashSpread => "hash-spread",
            PlacementPolicy::CoLocate => "co-locate",
        }
    }

    /// Pick the destination for the next replica of `obj`.
    ///
    /// `candidates` is the sorted, non-empty set of registered executors
    /// that neither hold the object nor have a staging transfer of it in
    /// flight; `ordinal` is the replica number being created (current
    /// holders + in-flight copies); `wanters` is the manager's decayed
    /// per-executor unmet-demand weights for `obj`.
    pub fn choose(
        &self,
        obj: ObjectId,
        candidates: &[ExecutorId],
        ordinal: usize,
        index: &dyn DataIndex,
        wanters: &[(ExecutorId, f64)],
    ) -> ExecutorId {
        debug_assert!(!candidates.is_empty());
        match self {
            PlacementPolicy::LeastLoaded => least_loaded(candidates, index),
            PlacementPolicy::HashSpread => {
                let h = splitmix64(obj.0 ^ ((ordinal as u64) << 32) ^ 0x9E37_79B9_7F4A_7C15);
                candidates[(h % candidates.len() as u64) as usize]
            }
            PlacementPolicy::CoLocate => {
                let mut best: Option<(f64, ExecutorId)> = None;
                for &e in candidates {
                    let w = wanters
                        .iter()
                        .find(|(we, _)| *we == e)
                        .map(|(_, w)| *w)
                        .unwrap_or(0.0);
                    if w <= 0.0 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some((bw, be)) => w > bw || (w == bw && e < be),
                    };
                    if better {
                        best = Some((w, e));
                    }
                }
                match best {
                    Some((_, e)) => e,
                    None => least_loaded(candidates, index),
                }
            }
        }
    }
}

fn least_loaded(candidates: &[ExecutorId], index: &dyn DataIndex) -> ExecutorId {
    let mut best = candidates[0];
    let mut best_load = index.objects_of(best).len();
    for &e in &candidates[1..] {
        let load = index.objects_of(e).len();
        if load < best_load {
            best = e;
            best_load = load;
        }
    }
    best
}

/// SplitMix64 finalizer — a tiny, well-mixed stateless hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::central::CentralIndex;

    #[test]
    fn parse_and_label() {
        assert_eq!(
            PlacementPolicy::parse("least-loaded"),
            Some(PlacementPolicy::LeastLoaded)
        );
        assert_eq!(
            PlacementPolicy::parse("hash_spread"),
            Some(PlacementPolicy::HashSpread)
        );
        assert_eq!(
            PlacementPolicy::parse("Co-Locate"),
            Some(PlacementPolicy::CoLocate)
        );
        assert_eq!(PlacementPolicy::parse("random"), None);
        assert_eq!(PlacementPolicy::CoLocate.label(), "co-locate");
    }

    #[test]
    fn least_loaded_prefers_emptier_executor() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(3), 1);
        // Executor 2 caches nothing at all.
        let pick =
            PlacementPolicy::LeastLoaded.choose(ObjectId(9), &[0, 1, 2], 1, &idx, &[]);
        assert_eq!(pick, 2);
        // Ties go to the lower id (0 and 1 both hold one object).
        idx.insert(ObjectId(4), 2);
        let pick =
            PlacementPolicy::LeastLoaded.choose(ObjectId(9), &[0, 1, 2], 1, &idx, &[]);
        assert_eq!(pick, 1, "1 holds one object, 0 holds two");
    }

    #[test]
    fn hash_spread_is_deterministic_and_varies_by_ordinal() {
        let idx = CentralIndex::new();
        let cands = [0, 1, 2, 3, 4, 5, 6, 7];
        let a = PlacementPolicy::HashSpread.choose(ObjectId(5), &cands, 1, &idx, &[]);
        let b = PlacementPolicy::HashSpread.choose(ObjectId(5), &cands, 1, &idx, &[]);
        assert_eq!(a, b, "same inputs, same pick");
        // Different ordinals (or objects) must not all collapse onto one
        // destination.
        let picks: std::collections::BTreeSet<ExecutorId> = (1..16)
            .map(|ord| PlacementPolicy::HashSpread.choose(ObjectId(5), &cands, ord, &idx, &[]))
            .collect();
        assert!(picks.len() > 2, "hash spread degenerated: {picks:?}");
    }

    #[test]
    fn co_locate_follows_demand_and_falls_back() {
        let idx = CentralIndex::new();
        let wanters = [(3usize, 1.5), (5usize, 4.0)];
        let pick = PlacementPolicy::CoLocate.choose(ObjectId(1), &[1, 3, 5], 1, &idx, &wanters);
        assert_eq!(pick, 5, "strongest wanter wins");
        // Wanter not in the candidate set: next-best candidate wanter.
        let pick = PlacementPolicy::CoLocate.choose(ObjectId(1), &[1, 3], 1, &idx, &wanters);
        assert_eq!(pick, 3);
        // No wanters at all: least-loaded fallback (empty index: ties to
        // the first candidate).
        let pick = PlacementPolicy::CoLocate.choose(ObjectId(1), &[1, 3], 1, &idx, &[]);
        assert_eq!(pick, 1);
    }
}
