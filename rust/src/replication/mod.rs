//! Demand-driven replication — "data diffusion" proper.
//!
//! The paper's namesake mechanism "replicates data in response to demand,
//! and schedules computations close to data". The cache-location index
//! records where objects *happen* to land; this module is what actively
//! creates additional copies of the objects that demand keeps asking for
//! (the scheduler half of the companion paper, arXiv:0808.3535, *Data
//! Diffusion: Dynamic Resource Provision and Data-Aware Scheduling*).
//!
//! ## How it works
//!
//! [`ReplicationManager`] is owned by [`crate::coordinator::FalkonCore`]
//! and fed three demand signals from the dispatch path:
//!
//! * **location-hint lookups** — every data-aware dispatch resolves each
//!   input's locations ([`ReplicationManager::note_lookup`]);
//! * **remote placements** — a task dispatched to an executor that does
//!   not hold an input ([`ReplicationManager::note_remote_placement`]) —
//!   unmet demand, attributed to that executor;
//! * **peer fetches** — an executor actually pulled the object from a
//!   peer cache ([`ReplicationManager::note_peer_fetch`]).
//!
//! The drivers call [`FalkonCore::poll_replication`] periodically (a
//! `ReplTick` event in the simulator, wall-clock in the live cluster).
//! Each evaluation folds the accumulated counts into a per-object EWMA;
//! when an object's smoothed demand crosses `demand_threshold` and it has
//! fewer than `max_replicas` copies (in-flight stages included), the
//! manager emits one [`ReplicaDirective`] — *copy object X from holder S
//! to executor D* — with D chosen by the configured
//! [`PlacementPolicy`]. The driver executes the copy off the task
//! critical path (the simulator charges it as a peer transfer; the live
//! cluster does a real file copy between cache directories) and reports
//! back through [`FalkonCore::replication_staged`].
//!
//! When demand decays the EWMA falls below the threshold and the manager
//! stops re-creating copies; normal cache eviction reclaims the space
//! (replicas are ordinary cache entries — no pinning). With
//! `release_threshold > 0` the manager goes further: once an object's
//! EWMA falls below that threshold (and no executor still shows unmet
//! demand for it), it emits [`ReplicaDirective::Drop`] — *actively
//! evict the k-th copy* — one copy per round down to a single holder,
//! so small caches get their space back ahead of eviction pressure.
//! Stage and Drop for the same object never overlap.
//!
//! Staging directives carry a `prestage` marker so the driver can class
//! the transfer on the metered plane ([`crate::transfer`]): join warm-up
//! copies ride the lowest priority (`Prestage`), demand-driven growth
//! rides `Staging`, and both yield to foreground fetches under the
//! admission budget.
//!
//! ## Re-replication on join
//!
//! A newly provisioned executor starts cold — the post-churn hit-ratio
//! dip in the DRP timeline. [`ReplicationManager::executor_joined`]
//! queues the joiner; the next evaluation pre-stages the `prestage_top_k`
//! hottest objects onto it (subject to the same `max_replicas` cap), so
//! the pool's locality recovers in one staging round instead of one
//! cold miss per (executor, object) pair.
//!
//! [`FalkonCore::poll_replication`]: crate::coordinator::FalkonCore::poll_replication
//! [`FalkonCore::replication_staged`]: crate::coordinator::FalkonCore::replication_staged

pub mod policy;

pub use policy::PlacementPolicy;

use crate::config::ReplicationConfig;
use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::storage::object::ObjectId;
use crate::util::fxhash::FxHashMap;

/// An order for the driver's replica plumbing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaDirective {
    /// Copy `obj` from `src`'s cache into `dst`'s cache. The driver
    /// charges/performs the transfer (classed `Staging`, or `Prestage`
    /// when `prestage` is set — a join warm-up) and reports completion
    /// or abandonment via
    /// [`crate::coordinator::FalkonCore::replication_staged`].
    Stage {
        /// Object to replicate.
        obj: ObjectId,
        /// A current holder to copy from.
        src: ExecutorId,
        /// Destination executor (never a current holder).
        dst: ExecutorId,
        /// Join-time warm-up (lowest transfer priority) rather than
        /// demand-driven growth.
        prestage: bool,
    },
    /// Demand decayed below the release threshold: actively evict the
    /// copy on `victim` (never the last one) instead of waiting for
    /// cache pressure, and report via
    /// [`crate::coordinator::FalkonCore::replication_dropped`].
    Drop {
        /// Object whose replica set is shrinking.
        obj: ObjectId,
        /// Holder whose copy is released.
        victim: ExecutorId,
    },
}

/// Per-object demand state.
#[derive(Debug, Default, Clone)]
struct Demand {
    /// Smoothed per-evaluation demand (EWMA of `accum`).
    ewma: f64,
    /// Raw signal count since the last evaluation.
    accum: f64,
    /// Decayed unmet-demand weight per executor that wanted the object
    /// without holding it (drives [`PlacementPolicy::CoLocate`]).
    wanters: Vec<(ExecutorId, f64)>,
}

/// Observes demand, decides replication, emits placement directives.
#[derive(Debug)]
pub struct ReplicationManager {
    cfg: ReplicationConfig,
    demand: FxHashMap<ObjectId, Demand>,
    /// Directives issued but not yet confirmed staged by the driver.
    inflight: Vec<(ObjectId, ExecutorId)>,
    /// Drop directives issued but not yet confirmed by the driver.
    dropping: Vec<(ObjectId, ExecutorId)>,
    /// Executors that joined since the last evaluation (pre-stage queue).
    pending_joins: Vec<ExecutorId>,
    /// Rotates the source choice across holders so one holder's NIC does
    /// not serve every staging transfer.
    src_seq: usize,
    /// Lifetime directives issued (diagnostics).
    issued: u64,
}

impl ReplicationManager {
    /// New manager with the given configuration.
    pub fn new(cfg: ReplicationConfig) -> Self {
        ReplicationManager {
            cfg,
            demand: FxHashMap::default(),
            inflight: Vec::new(),
            dropping: Vec::new(),
            pending_joins: Vec::new(),
            src_seq: 0,
            issued: 0,
        }
    }

    /// A data-aware dispatch resolved the locations of `obj`.
    pub fn note_lookup(&mut self, obj: ObjectId) {
        self.demand.entry(obj).or_default().accum += 1.0;
    }

    /// A task needing `obj` was dispatched to `exec`, which does not hold
    /// it — unmet demand at that executor.
    pub fn note_remote_placement(&mut self, obj: ObjectId, exec: ExecutorId) {
        Self::bump_wanter(self.demand.entry(obj).or_default(), exec);
    }

    /// Executor `dst` fetched `obj` from a peer cache.
    pub fn note_peer_fetch(&mut self, obj: ObjectId, dst: ExecutorId) {
        let d = self.demand.entry(obj).or_default();
        d.accum += 1.0;
        Self::bump_wanter(d, dst);
    }

    fn bump_wanter(d: &mut Demand, exec: ExecutorId) {
        match d.wanters.iter_mut().find(|(e, _)| *e == exec) {
            Some((_, w)) => *w += 1.0,
            None => d.wanters.push((exec, 1.0)),
        }
    }

    /// A newly provisioned executor joined; pre-stage it at the next
    /// evaluation.
    pub fn executor_joined(&mut self, exec: ExecutorId) {
        if !self.pending_joins.contains(&exec) {
            self.pending_joins.push(exec);
        }
    }

    /// An executor left: forget its unmet demand and any staging
    /// transfers or pending drops targeting it (the driver abandons
    /// those).
    pub fn executor_dropped(&mut self, exec: ExecutorId) {
        self.pending_joins.retain(|&e| e != exec);
        self.inflight.retain(|&(_, d)| d != exec);
        self.dropping.retain(|&(_, v)| v != exec);
        for d in self.demand.values_mut() {
            d.wanters.retain(|&(e, _)| e != exec);
        }
    }

    /// The driver finished (or abandoned) the staging transfer behind a
    /// directive; the slot is free for future replication.
    pub fn on_staged(&mut self, obj: ObjectId, dst: ExecutorId) {
        if let Some(pos) = self.inflight.iter().position(|&(o, d)| o == obj && d == dst) {
            self.inflight.swap_remove(pos);
        }
    }

    /// The driver executed (or abandoned) a drop directive; the object
    /// is eligible for future teardown or re-replication again.
    pub fn on_drop_done(&mut self, obj: ObjectId, victim: ExecutorId) {
        if let Some(pos) = self
            .dropping
            .iter()
            .position(|&(o, v)| o == obj && v == victim)
        {
            self.dropping.swap_remove(pos);
        }
    }

    /// Smoothed demand for `obj` (0.0 if never seen).
    pub fn demand_of(&self, obj: ObjectId) -> f64 {
        self.demand.get(&obj).map(|d| d.ewma).unwrap_or(0.0)
    }

    /// Directives issued but not yet confirmed staged.
    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }

    /// Lifetime directives issued.
    pub fn directives_issued(&self) -> u64 {
        self.issued
    }

    /// One evaluation round: decay demand, pre-stage pending joiners,
    /// replicate hot objects. `executors` is the sorted set of currently
    /// registered executors; `index` is the live cache-location index.
    ///
    /// Every returned directive satisfies: `src` holds the object, `dst`
    /// is registered, `dst` neither holds it nor has a stage in flight,
    /// and holders + in-flight stages stay ≤ `max_replicas`.
    pub fn evaluate(
        &mut self,
        index: &dyn DataIndex,
        executors: &[ExecutorId],
    ) -> Vec<ReplicaDirective> {
        let alpha = self.cfg.ewma_alpha.clamp(0.0, 1.0);
        for d in self.demand.values_mut() {
            d.ewma = (1.0 - alpha) * d.ewma + alpha * d.accum;
            d.accum = 0.0;
            for w in &mut d.wanters {
                w.1 *= 1.0 - alpha;
            }
            d.wanters.retain(|&(_, w)| w >= 0.05);
        }
        // With teardown enabled, a fully decayed object stays tracked
        // while it still has copies to release (otherwise the purge would
        // strand its extra replicas until cache pressure evicts them).
        let teardown = self.cfg.release_threshold > 0.0;
        self.demand.retain(|o, d| {
            d.ewma >= 1e-3
                || !d.wanters.is_empty()
                || (teardown && index.locations(*o).len() > 1)
        });

        // Replica teardown on decay: when an object's smoothed demand has
        // fallen below the release threshold (and nothing still wants it
        // remotely), actively release the k-th copy — one per object per
        // round, never the last copy, never while a staging transfer or
        // another drop of the same object is in flight. The victim is the
        // highest-id holder: deterministic on any backend (locations are
        // the placement contract), and biased away from the lowest-id
        // holder the earliest organic copy usually landed on.
        let mut drops: Vec<ReplicaDirective> = Vec::new();
        if teardown {
            // Clamp under the growth threshold (config files validate
            // this; programmatic configs are clamped here) so no demand
            // level is ever simultaneously a stage and a drop candidate —
            // that would re-ship the same object's bytes every round.
            let release = self
                .cfg
                .release_threshold
                .min(self.cfg.demand_threshold);
            let mut cold: Vec<ObjectId> = self
                .demand
                .iter()
                .filter(|(_, d)| d.ewma < release && d.wanters.is_empty())
                .map(|(&o, _)| o)
                .collect();
            // FxHashMap iteration order must never leak into directives.
            cold.sort_unstable();
            for obj in cold {
                if self.inflight_for(obj) > 0
                    || self.dropping.iter().any(|&(o, _)| o == obj)
                {
                    continue;
                }
                let holders = index.locations(obj);
                if holders.len() <= 1 {
                    continue;
                }
                let victim = *holders.last().unwrap();
                self.dropping.push((obj, victim));
                drops.push(ReplicaDirective::Drop { obj, victim });
            }
        }

        // Hottest first; ties to the lower object id (determinism —
        // FxHashMap iteration order must never leak into placement).
        let mut hot: Vec<(ObjectId, f64)> =
            self.demand.iter().map(|(&o, d)| (o, d.ewma)).collect();
        hot.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));

        let mut dirs: Vec<ReplicaDirective> = Vec::new();
        let budget = self.cfg.max_inflight.saturating_sub(self.inflight.len());

        // Re-replication on join: pre-stage the hottest objects onto each
        // joiner before demand-driven growth takes its turn. A joiner
        // that gets nothing only because the staging budget ran dry is
        // re-queued for the next round — budget pressure must delay the
        // prestage, never silently skip it.
        let joins = std::mem::take(&mut self.pending_joins);
        let mut deferred: Vec<ExecutorId> = Vec::new();
        for e in joins {
            if executors.binary_search(&e).is_err() {
                continue; // joined and left between evaluations
            }
            if dirs.len() >= budget {
                deferred.push(e);
                continue;
            }
            let mut staged = 0usize;
            for &(obj, _) in &hot {
                if staged >= self.cfg.prestage_top_k || dirs.len() >= budget {
                    break;
                }
                if let Some(d) = self.try_stage(obj, e, index, true) {
                    dirs.push(d);
                    staged += 1;
                }
            }
            if staged == 0 && dirs.len() >= budget {
                deferred.push(e);
            }
        }
        self.pending_joins = deferred;

        // Demand-driven growth: one new copy per hot object per round, so
        // replica sets grow while demand persists and freeze when it
        // decays (eviction then reclaims the space).
        for &(obj, ewma) in &hot {
            if dirs.len() >= budget {
                break;
            }
            if ewma < self.cfg.demand_threshold {
                break; // sorted: everything after is colder
            }
            if let Some(dst) = self.choose_dst(obj, index, executors) {
                if let Some(d) = self.try_stage(obj, dst, index, false) {
                    dirs.push(d);
                }
            }
        }
        // Drops first: they free cache space before new copies arrive and
        // are near-free control actions (no transfer behind them).
        self.issued += (drops.len() + dirs.len()) as u64;
        drops.extend(dirs);
        drops
    }

    /// Policy choice of the destination for the next replica of `obj`
    /// among registered non-holders without a stage in flight.
    fn choose_dst(
        &self,
        obj: ObjectId,
        index: &dyn DataIndex,
        executors: &[ExecutorId],
    ) -> Option<ExecutorId> {
        let holders = index.locations(obj);
        let inflight = self.inflight_for(obj);
        let candidates: Vec<ExecutorId> = executors
            .iter()
            .copied()
            .filter(|e| holders.binary_search(e).is_err())
            .filter(|e| !self.inflight.iter().any(|&(o, d)| o == obj && d == *e))
            .collect();
        if candidates.is_empty() {
            return None;
        }
        let wanters: &[(ExecutorId, f64)] = self
            .demand
            .get(&obj)
            .map(|d| d.wanters.as_slice())
            .unwrap_or(&[]);
        Some(self.cfg.policy.choose(
            obj,
            &candidates,
            holders.len() + inflight,
            index,
            wanters,
        ))
    }

    fn inflight_for(&self, obj: ObjectId) -> usize {
        self.inflight.iter().filter(|&&(o, _)| o == obj).count()
    }

    /// Issue a directive staging `obj` to `dst` if every precondition
    /// holds (object has a holder, dst is not one, cap not exceeded, no
    /// duplicate in flight, no teardown of the same object pending).
    fn try_stage(
        &mut self,
        obj: ObjectId,
        dst: ExecutorId,
        index: &dyn DataIndex,
        prestage: bool,
    ) -> Option<ReplicaDirective> {
        let holders = index.locations(obj);
        if holders.is_empty() || holders.binary_search(&dst).is_ok() {
            return None;
        }
        if self.inflight.iter().any(|&(o, d)| o == obj && d == dst) {
            return None;
        }
        if self.dropping.iter().any(|&(o, _)| o == obj) {
            return None; // growing and shrinking at once is contradictory
        }
        if holders.len() + self.inflight_for(obj) >= self.cfg.max_replicas.max(1) {
            return None;
        }
        let src = holders[self.src_seq % holders.len()];
        self.src_seq = self.src_seq.wrapping_add(1);
        self.inflight.push((obj, dst));
        Some(ReplicaDirective::Stage {
            obj,
            src,
            dst,
            prestage,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::central::CentralIndex;

    fn cfg() -> ReplicationConfig {
        ReplicationConfig {
            enabled: true,
            max_replicas: 3,
            demand_threshold: 1.0,
            ewma_alpha: 0.5,
            prestage_top_k: 2,
            max_inflight: 8,
            ..ReplicationConfig::default()
        }
    }

    fn idx_with(entries: &[(u64, usize)]) -> CentralIndex {
        let mut idx = CentralIndex::new();
        for &(o, e) in entries {
            idx.insert(ObjectId(o), e);
        }
        idx
    }

    /// Destructure a directive the test expects to be a Stage.
    fn stage(d: &ReplicaDirective) -> (ObjectId, ExecutorId, ExecutorId, bool) {
        match *d {
            ReplicaDirective::Stage {
                obj,
                src,
                dst,
                prestage,
            } => (obj, src, dst, prestage),
            other => panic!("expected Stage, got {other:?}"),
        }
    }

    #[test]
    fn cold_objects_are_not_replicated() {
        let mut m = ReplicationManager::new(cfg());
        let idx = idx_with(&[(1, 0)]);
        // One lookup is below the sustained threshold after smoothing.
        m.note_lookup(ObjectId(1));
        let dirs = m.evaluate(&idx, &[0, 1, 2]);
        assert!(dirs.is_empty(), "ewma 0.5 < threshold 1.0: {dirs:?}");
    }

    #[test]
    fn hot_object_gets_one_replica_per_round_up_to_cap() {
        let mut m = ReplicationManager::new(cfg());
        let mut idx = idx_with(&[(1, 0)]);
        let all = [0usize, 1, 2, 3];
        for round in 0..4 {
            for _ in 0..8 {
                m.note_lookup(ObjectId(1));
            }
            let room = idx.locations(ObjectId(1)).len() + m.inflight_len() < 3;
            let dirs = m.evaluate(&idx, &all);
            if room {
                assert_eq!(dirs.len(), 1, "round {round}: one copy per round");
            } else {
                assert!(dirs.is_empty(), "round {round}: cap reached");
            }
            for d in dirs {
                let (obj, src, dst, prestage) = stage(&d);
                assert_eq!(obj, ObjectId(1));
                assert!(!prestage, "demand growth, not a join warm-up");
                assert!(idx.locations(obj).binary_search(&src).is_ok());
                assert!(idx.locations(obj).binary_search(&dst).is_err());
                // Driver stages it.
                idx.insert(obj, dst);
                m.on_staged(obj, dst);
            }
            assert!(
                idx.locations(ObjectId(1)).len() <= 3,
                "max_replicas exceeded"
            );
        }
        assert_eq!(idx.locations(ObjectId(1)).len(), 3);
    }

    #[test]
    fn inflight_counts_toward_the_cap_and_deduplicates() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            max_replicas: 2,
            ..cfg()
        });
        let idx = idx_with(&[(1, 0)]);
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        let dirs = m.evaluate(&idx, &[0, 1, 2]);
        assert_eq!(dirs.len(), 1);
        let (obj, _, dst, _) = stage(&dirs[0]);
        // Directive not yet staged: holders(1) + inflight(1) == cap.
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        assert!(m.evaluate(&idx, &[0, 1, 2]).is_empty());
        m.on_staged(obj, dst);
        assert_eq!(m.inflight_len(), 0);
    }

    #[test]
    fn demand_decay_backs_off() {
        let mut m = ReplicationManager::new(cfg());
        let idx = idx_with(&[(1, 0), (1, 1)]);
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        assert_eq!(m.evaluate(&idx, &[0, 1, 2]).len(), 1);
        m.on_staged(ObjectId(1), 2);
        // No new demand: the EWMA halves each round and drops below the
        // threshold, so no further copies are requested.
        let mut quiet = 0;
        for _ in 0..6 {
            if m.evaluate(&idx, &[0, 1, 2]).is_empty() {
                quiet += 1;
            }
        }
        assert!(quiet >= 5, "decayed demand kept replicating");
        assert!(m.demand_of(ObjectId(1)) < 1.0);
    }

    #[test]
    fn joiner_is_prestaged_with_hottest_objects() {
        let mut m = ReplicationManager::new(cfg());
        let idx = idx_with(&[(1, 0), (2, 0), (3, 0)]);
        // Heat objects 1 (hottest) and 2; object 3 stays cold.
        for _ in 0..9 {
            m.note_lookup(ObjectId(1));
        }
        for _ in 0..4 {
            m.note_lookup(ObjectId(2));
        }
        let _ = m.evaluate(&idx, &[0]);
        m.executor_joined(7);
        let dirs = m.evaluate(&idx, &[0, 7]);
        // prestage_top_k = 2: the two hottest objects land on the joiner
        // (demand-driven growth may add more, but the joiner directives
        // come first), classed as prestage traffic.
        assert!(dirs.len() >= 2, "{dirs:?}");
        assert_eq!(
            dirs[0],
            ReplicaDirective::Stage {
                obj: ObjectId(1),
                src: 0,
                dst: 7,
                prestage: true
            }
        );
        let (obj, _, dst, prestage) = stage(&dirs[1]);
        assert_eq!(obj, ObjectId(2));
        assert_eq!(dst, 7);
        assert!(prestage);
    }

    #[test]
    fn joiner_prestage_defers_under_budget_pressure() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            max_inflight: 1,
            max_replicas: 8,
            ..cfg()
        });
        let mut idx = idx_with(&[(1, 0)]);
        for _ in 0..9 {
            m.note_lookup(ObjectId(1));
        }
        // Demand replication fills the whole staging budget...
        let dirs = m.evaluate(&idx, &[0, 1]);
        assert_eq!(dirs.len(), 1);
        // ...then an executor joins while the budget is exhausted: its
        // prestage must be deferred, not dropped.
        m.executor_joined(7);
        assert!(m.evaluate(&idx, &[0, 1, 7]).is_empty());
        let (obj, _, dst, _) = stage(&dirs[0]);
        idx.insert(obj, dst);
        m.on_staged(obj, dst);
        let dirs = m.evaluate(&idx, &[0, 1, 7]);
        assert_eq!(dirs.len(), 1, "deferred joiner prestaged next round");
        assert_eq!(stage(&dirs[0]).2, 7);
    }

    #[test]
    fn dropped_executor_is_forgotten() {
        let mut m = ReplicationManager::new(cfg());
        let idx = idx_with(&[(1, 0)]);
        for _ in 0..8 {
            m.note_peer_fetch(ObjectId(1), 2);
        }
        let dirs = m.evaluate(&idx, &[0, 1, 2]);
        assert_eq!(dirs.len(), 1);
        m.executor_dropped(stage(&dirs[0]).2);
        assert_eq!(m.inflight_len(), 0, "in-flight to the dead dst cleared");
        m.executor_joined(5);
        m.executor_dropped(5);
        let dirs = m.evaluate(&idx, &[0, 1, 2]);
        assert!(
            dirs.iter().all(|d| stage(d).2 != 5),
            "no prestage to a ghost"
        );
    }

    #[test]
    fn co_locate_places_toward_the_asking_executor() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            policy: PlacementPolicy::CoLocate,
            ..cfg()
        });
        let idx = idx_with(&[(1, 0)]);
        for _ in 0..8 {
            m.note_peer_fetch(ObjectId(1), 4);
        }
        let dirs = m.evaluate(&idx, &[0, 2, 4, 6]);
        assert_eq!(dirs.len(), 1);
        assert_eq!(stage(&dirs[0]).2, 4, "replica follows the unmet demand");
    }

    #[test]
    fn decayed_demand_tears_replicas_down_to_one_copy() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            release_threshold: 0.5,
            ..cfg()
        });
        let mut idx = idx_with(&[(1, 0), (1, 1), (1, 2)]);
        // Hot: well above the release threshold — no drops.
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        let dirs = m.evaluate(&idx, &[0, 1, 2]);
        assert!(
            dirs.iter()
                .all(|d| !matches!(d, ReplicaDirective::Drop { .. })),
            "hot object must not be torn down: {dirs:?}"
        );
        // No new demand: the EWMA decays below 0.5 and drops begin, one
        // copy per round, highest-id holder first, never the last copy.
        let mut dropped = Vec::new();
        for _ in 0..8 {
            for d in m.evaluate(&idx, &[0, 1, 2]) {
                if let ReplicaDirective::Drop { obj, victim } = d {
                    assert_eq!(obj, ObjectId(1));
                    assert!(idx.locations(obj).binary_search(&victim).is_ok());
                    assert!(idx.locations(obj).len() > 1, "never the last copy");
                    idx.remove(obj, victim);
                    m.on_drop_done(obj, victim);
                    dropped.push(victim);
                }
            }
        }
        assert_eq!(dropped, vec![2, 1], "k-th copy first, down to one");
        assert_eq!(idx.locations(ObjectId(1)), &[0]);
    }

    #[test]
    fn drop_waits_for_driver_confirmation_and_never_overlaps_staging() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            release_threshold: 0.5,
            ..cfg()
        });
        let idx = idx_with(&[(1, 0), (1, 1)]);
        m.note_lookup(ObjectId(1)); // ewma 0.5 → decays under 0.5 next round
        let _ = m.evaluate(&idx, &[0, 1]);
        let dirs = m.evaluate(&idx, &[0, 1]);
        assert_eq!(
            dirs,
            vec![ReplicaDirective::Drop {
                obj: ObjectId(1),
                victim: 1
            }]
        );
        // Unconfirmed: no duplicate drop, and no staging of the same
        // object while the teardown is pending.
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        let dirs = m.evaluate(&idx, &[0, 1]);
        assert!(dirs.is_empty(), "pending drop blocks both drop and stage: {dirs:?}");
        m.on_drop_done(ObjectId(1), 1);
        // Confirmed and demand is hot again: staging resumes.
        for _ in 0..8 {
            m.note_lookup(ObjectId(1));
        }
        let idx = idx_with(&[(1, 0)]);
        let dirs = m.evaluate(&idx, &[0, 1]);
        assert_eq!(dirs.len(), 1);
        assert!(matches!(dirs[0], ReplicaDirective::Stage { .. }));
    }

    #[test]
    fn teardown_skips_objects_with_live_unmet_demand() {
        let mut m = ReplicationManager::new(ReplicationConfig {
            release_threshold: 0.8,
            ewma_alpha: 0.1, // slow: wanter weight stays over the floor
            ..cfg()
        });
        let idx = idx_with(&[(1, 0), (1, 1)]);
        // Low lookup volume (ewma stays under 0.8) but executor 4 still
        // shows unmet demand — the copy it may soon receive must survive.
        m.note_peer_fetch(ObjectId(1), 4);
        let dirs = m.evaluate(&idx, &[0, 1, 4]);
        assert!(
            dirs.iter()
                .all(|d| !matches!(d, ReplicaDirective::Drop { .. })),
            "unmet demand must block teardown: {dirs:?}"
        );
    }
}
