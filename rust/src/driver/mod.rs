//! Execution drivers.
//!
//! * [`sim`] — replays a workload through [`crate::coordinator::ShardedCore`]
//!   over the simulated testbed (discrete events + fair-share flows).
//!   All figure benches use this driver at paper scale (64 nodes / 128
//!   CPUs / 100K tasks).
//! * [`live`] — real executor threads, real files on disk, real gzip and
//!   real PJRT stacking compute. Used by the end-to-end example and
//!   integration tests.
//!
//! Both drivers run the *same* dispatcher core, cache implementation and
//! pluggable index — the substitution (DESIGN.md §3) swaps only the I/O
//! substrate — and both run the *same* dynamic resource provisioner
//! (§3.1) when `provisioner.enabled` is set: the sim through
//! `ProvisionTick`/`AllocReady` events, the live cluster on wall-clock
//! time with real threads spawned and reaped mid-run.

pub mod live;
pub mod sim;

pub use sim::{SimDriver, SimOutcome, SimWorkloadSpec};
