//! Execution drivers.
//!
//! * [`sim`] — replays a workload through [`crate::coordinator::FalkonCore`]
//!   over the simulated testbed (discrete events + fair-share flows).
//!   All figure benches use this driver at paper scale (64 nodes / 128
//!   CPUs / 100K tasks).
//! * [`live`] — real executor threads, real files on disk, real gzip and
//!   real PJRT stacking compute. Used by the end-to-end example and
//!   integration tests.
//!
//! Both drivers run the *same* dispatcher core, cache implementation and
//! central index — the substitution (DESIGN.md §3) swaps only the I/O
//! substrate.

pub mod live;
pub mod sim;

pub use sim::{SimDriver, SimOutcome, SimWorkloadSpec};
