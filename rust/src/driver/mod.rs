//! Execution drivers.
//!
//! * [`sim`] — replays a workload through [`crate::federation::FedCore`]
//!   (per-site [`crate::coordinator::ShardedCore`]s) over the simulated
//!   testbed (discrete events + fair-share flows, WAN links between
//!   sites). All figure benches use this driver at paper scale (64
//!   nodes / 128 CPUs / 100K tasks).
//! * [`live`] — real executor threads, real files on disk, real gzip and
//!   real PJRT stacking compute. Used by the end-to-end example and
//!   integration tests.
//!
//! Both drivers run the *same* dispatcher core, cache implementation and
//! pluggable index — the substitution (DESIGN.md §3) swaps only the I/O
//! substrate — and both run the *same* dynamic resource provisioner
//! (§3.1) when `provisioner.enabled` is set: the sim through
//! `ProvisionTick`/`AllocReady` events, the live cluster on wall-clock
//! time with real threads spawned and reaped mid-run.
//!
//! Both produce the same [`RunOutcome`] through the common [`Driver`]
//! trait, so figures and integration tests consume one summary shape
//! regardless of substrate.

pub mod live;
pub mod sim;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::task::TaskId;

pub use live::{LiveCluster, LiveDriver};
pub use sim::{SimDriver, SimWorkloadSpec};

/// What one run produced — the single summary shape shared by the
/// simulated and live drivers.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Experiment metrics (bytes by source, hit ratios, latencies).
    pub metrics: Metrics,
    /// Makespan (first dispatch → last completion), seconds. Simulated
    /// time on the sim driver, wall-clock on the live cluster.
    pub makespan_s: f64,
    /// DES events processed (sim-engine throughput diagnostics; 0 on
    /// the live driver — there is no event loop to count).
    pub events: u64,
    /// Wall-clock seconds the run itself took.
    pub wall_s: f64,
    /// Stacked-image checksums per task (first 8 tasks) for end-to-end
    /// verification against the reference; empty on the simulator.
    pub sample_checksums: Vec<(TaskId, f64)>,
}

impl RunOutcome {
    /// Time per task per CPU — the paper's normalized §5 metric ("time
    /// per stack per CPU": with perfect scalability it stays constant as
    /// CPUs grow).
    pub fn time_per_task_per_cpu(&self, cpus: usize) -> f64 {
        if self.metrics.tasks_done == 0 {
            return f64::NAN;
        }
        self.makespan_s * cpus as f64 / self.metrics.tasks_done as f64
    }
}

/// The common face of an execution driver: consume it, run the workload
/// to completion, summarize. The simulator is infallible (any bug is a
/// panic); the live cluster surfaces real I/O and runtime errors.
pub trait Driver {
    /// Run the workload to completion.
    fn run(self) -> crate::error::Result<RunOutcome>;
}
