//! Simulated execution driver.
//!
//! Replays a workload through the dispatcher core over the simulated
//! testbed. Every §4/§5 figure bench is a [`SimDriver`] run with the
//! right [`SimWorkloadSpec`]; the contention physics (GPFS saturation,
//! NIC limits, metadata queueing, linear local-disk scaling) come from
//! [`crate::storage::testbed::SimTestbed`].
//!
//! ## Task lifecycle (one executor CPU)
//!
//! ```text
//! dispatch ─▸ dispatcher-service + net latency ─▸ [wrapper pre-ops]
//!   ─▸ per input: own-cache? local-read-flow
//!               : peer-hint?  cache-to-cache flow  (then cache insert)
//!               : GPFS        meta-open, GPFS flow (then decompress if GZ,
//!                                                   cache insert if caching)
//!   ─▸ compute delay ─▸ [output write flow] ─▸ [wrapper post-op]
//!   ─▸ report completion + cache events to the dispatcher
//! ```
//!
//! Cache-content changes are reported to the central index **at task
//! completion** ("loosely coherent", §3.2.1) — the index can briefly lag
//! the caches, which is exactly why measured hit ratios land slightly
//! under ideal in Fig 10.
//!
//! ## Elastic pools
//!
//! With `provisioner.enabled` the executor pool is **not** registered up
//! front: the run starts at `min_executors` and two extra event kinds
//! drive §3.1's dynamic resource provisioning — `ProvisionTick` (every
//! `poll_interval_s`: feed the wait-queue high-water mark to the
//! [`Provisioner`], mark quiescent executors idle, execute the returned
//! allocate/release actions) and `AllocReady` (the [`ClusterProvider`]'s
//! allocation latency elapsed: the granted nodes register with the core
//! *and* the index backend — Chord rebuilds its finger tables — and start
//! taking work). A release deregisters the executor, purges its cache
//! contents from the index (so no future hint targets it), requeues any
//! tasks parked on it, and resets its node-local cache: a later re-join
//! of the same node id starts cold, exactly like a fresh lease.
//!
//! ## Demand-driven replication and the metered transfer plane
//!
//! Every byte movement starts through the
//! [`SimTransferPlane`] (which owns the wired testbed), class-tagged
//! per [`crate::transfer`]: task I/O is `Foreground`, replication
//! staging is `Staging`, join warm-up is `Prestage`. With
//! `replication.enabled` a periodic `ReplTick` event polls the
//! coordinator's [`crate::replication::ReplicationManager`]; each
//! staging directive is *offered* to the plane — admitted, it becomes a
//! `Replica`-tagged peer-bandwidth flow (source disk + both NICs +
//! destination disk, exactly like a cache-to-cache task fetch, so
//! admitted staging still contends with foreground traffic instead of
//! being free) carrying its class's fair-share weight (unit under the
//! binary share policy; `transfer.class_weights` under the weighted
//! one, so an in-flight staging flow concedes most of a contended link
//! to foreground fetches); over the source's `staging_budget` it
//! defers, and flow completions / later ticks pump re-admission as the
//! source drains. [`crate::replication::ReplicaDirective::Drop`] directives
//! (replica teardown on demand decay) are executed immediately — an
//! eviction is local metadata work, not a transfer. On staging
//! completion the object enters the destination cache and the index —
//! through the same `apply_cache_events` path as any other insert, so
//! no index location ever lacks a backing cache entry. Stale location
//! hints (§3.2.2: every hinted copy moved or was evicted since
//! dispatch) make the executor *re-resolve* against the index, charged
//! via [`crate::index::DataIndex::lookup_cost`] like a dispatch-side
//! lookup — which is also how an executor discovers replicas staged
//! after its task was dispatched.
//!
//! ## Multi-site runs (parallel federation)
//!
//! With more than one `[[site]]` table the run decomposes into one
//! site-local world per federation site, executed in parallel on the
//! conservative-lookahead engine ([`crate::sim::parallel`]). Each
//! world owns its site's executors, caches, dispatch core, and
//! resources; everything cross-site — task routing, the shared
//! directory, GPFS and metadata access from non-home sites, WAN data
//! transfers — travels as timestamped inter-site messages (see the
//! `fedsim` submodule for the protocol and the deterministic merge).
//! Single-site runs stay on the serial [`Engine`] below, bit-for-bit.

mod fedsim;

use crate::cache::store::{CacheEvent, DataCache};
use crate::config::Config;
use crate::coordinator::core::DispatchOrder;
use crate::coordinator::metrics::{ByteSource, Metrics};
use crate::coordinator::task::{Task, TaskId, TaskKind};
use crate::federation::{FedCore, SiteId};
use crate::index::central::ExecutorId;
use crate::provisioner::{ClusterProvider, ProvisionAction, Provisioner};
use crate::replication::ReplicaDirective;
use crate::scheduler::decision::LocationHints;
use crate::sim::engine::{Engine, EventQueue, World};
use crate::sim::flownet::FlowId;
use crate::sim::server::FifoServer;
use crate::transfer::sim::SimTransferPlane;
use crate::transfer::{Admission, TransferClass, TransferPlane, TransferRequest};
use crate::util::fxhash::{FxHashMap, FxHashSet};
use crate::storage::object::{Catalog, DataFormat, ObjectId};
use crate::storage::testbed::{SimTestbed, TransferKind};

/// Dispatcher service rate (tasks/s) — §3.1: Falkon dispatches at
/// ~3800 tasks/s on the paper's service host.
const DISPATCH_RATE: f64 = 3800.0;

/// Workload description for a simulated run.
#[derive(Debug, Clone)]
pub struct SimWorkloadSpec {
    /// (arrival time, task) pairs; arrival times need not be sorted.
    pub tasks: Vec<(f64, Task)>,
    /// Data diffusion on (caching + peer fetches) or off (every access
    /// goes to persistent storage — configurations (3)/(4) and the §5
    /// GPFS baseline).
    pub caching: bool,
    /// Stored data format: GZ pays decompression on GPFS fetches and
    /// expands in cache; FIT moves more bytes but computes directly.
    pub format: DataFormat,
    /// Cached (uncompressed) size = stored size × expansion. 1.0 for
    /// already-uncompressed data; 3.0 for SDSS GZ (2 MB → 6 MB).
    pub expansion: f64,
    /// Pre-warm: (executor, object) pairs resident in caches before the
    /// clock starts (the 100%-locality micro-benchmarks).
    pub prewarm: Vec<(ExecutorId, ObjectId)>,
}

impl SimWorkloadSpec {
    /// A plain uncompressed workload with caching on.
    pub fn new(tasks: Vec<(f64, Task)>) -> Self {
        SimWorkloadSpec {
            tasks,
            caching: true,
            format: DataFormat::Fit,
            expansion: 1.0,
            prewarm: Vec::new(),
        }
    }
}

pub use super::{Driver, RunOutcome};

/// Events of the simulation world.
#[derive(Debug)]
enum Ev {
    /// Task with this index arrives at the dispatcher.
    Arrive(u32),
    /// Run one dispatcher shard's dispatch loop (a completion wake-up:
    /// only the shard owning the freed executor needs to re-decide).
    Dispatch(u32),
    /// A dispatched task reaches its executor (run id).
    AtExecutor(u64),
    /// Generic continuation after a timed phase (run id).
    Step(u64),
    /// Flow-completion check (validity-stamped with a version).
    FlowCheck(u64),
    /// Periodic provisioner evaluation for one site's pool (elastic
    /// pools only; each site churns independently).
    ProvisionTick(u32),
    /// A cluster allocation finished its latency; nodes come up.
    AllocReady(u64),
    /// Periodic replication evaluation (replication.enabled only).
    ReplTick,
    /// An inter-site message arrived from the given sender site
    /// (multi-site runs on the parallel engine only).
    Msg(u32, fedsim::SiteMsg),
    /// The home metadata server finished an operation performed on
    /// behalf of another site (remote-op id).
    MetaStep(u64),
}

/// Why a flow was started (continuation tag).
#[derive(Debug, Clone, Copy)]
enum FlowPurpose {
    FetchLocal,
    FetchPeer,
    FetchGpfs,
    WriteLocal,
    WriteGpfs,
    /// Sender half of a GPFS output write from a non-home federation
    /// site: on completion the bytes are handed to the home site over
    /// the inter-site channel (metadata create + home legs there).
    WriteGpfsWan,
}

/// Who owns a flow: a running task's pipeline phase, or a background
/// replication staging transfer (no task attached).
#[derive(Debug, Clone, Copy)]
enum FlowTag {
    /// Task flow: (run id, phase purpose).
    Run(u64, FlowPurpose),
    /// Replication staging: object headed for an executor's cache.
    Replica { obj: ObjectId, dst: ExecutorId },
    /// A leg served on behalf of *another* site (remote-op id): a peer
    /// egress toward a requesting site, or a home-side GPFS leg.
    Remote(u64),
}

/// Bookkeeping for one in-flight flow: the owner tag plus what the
/// per-class metrics need at completion (class, bytes, start time — a
/// flow's span divided into its bytes is the achieved rate, which is
/// where weighted shares become visible).
#[derive(Debug, Clone, Copy)]
struct FlowInfo {
    tag: FlowTag,
    class: TransferClass,
    bytes: u64,
    t_start: f64,
}

/// Per-task pipeline phase. `Step(rid)` events drive transitions; flow
/// completions are delivered separately through [`SimWorld::flow_done`].
#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// Waiting for wrapper pre-ops (or skipping them).
    Start,
    /// Resolving the next input.
    Fetch,
    /// Waiting for the GPFS metadata open of the current input.
    GpfsOpen,
    /// Stale hints: the executor-side index re-resolution (charged at
    /// the backend's lookup cost) is in flight for the current input.
    Refetch,
    /// A data flow is in flight for the current input / output.
    AwaitFlow,
    /// CPU decompression of the just-fetched GZ input.
    Decompress,
    /// Compute finished; decide how (whether) to write the output.
    OutputStart,
    /// Waiting for the GPFS metadata create before the output write.
    OutputOpen,
    /// Waiting for the wrapper post-op.
    WrapperPost,
}

struct Running {
    task: Task,
    exec: ExecutorId,
    hints: LocationHints,
    t_submit: f64,
    t_dispatch: f64,
    next_input: usize,
    phase: Phase,
    /// Fresh peer found by a stale-hint re-resolution (Refetch phase).
    refetch_src: Option<ExecutorId>,
    /// Cache updates buffered until completion (loose coherence).
    events: Vec<CacheEvent>,
}

/// Slab of in-flight runs, keyed by run id = `generation << 32 | slot`.
/// The dispatch hot path touches this on every event; a `Vec` index
/// replaces the hash on every lookup, and the per-slot generation
/// guard makes a recycled slot unable to satisfy a stale id.
struct RunTable {
    slots: Vec<(u32, Option<Running>)>,
    free: Vec<u32>,
    len: usize,
}

impl RunTable {
    fn new() -> RunTable {
        RunTable {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    #[inline]
    fn split(rid: u64) -> (u32, usize) {
        ((rid >> 32) as u32, (rid & 0xFFFF_FFFF) as usize)
    }

    /// Insert a run, returning its id. Slots are reused LIFO, so id
    /// assignment is deterministic for a deterministic event order.
    fn insert(&mut self, run: Running) -> u64 {
        self.len += 1;
        match self.free.pop() {
            Some(slot) => {
                let e = &mut self.slots[slot as usize];
                e.1 = Some(run);
                ((e.0 as u64) << 32) | slot as u64
            }
            None => {
                let slot = self.slots.len() as u32;
                self.slots.push((0, Some(run)));
                slot as u64
            }
        }
    }

    fn get(&self, rid: u64) -> Option<&Running> {
        let (gen, slot) = Self::split(rid);
        match self.slots.get(slot) {
            Some((g, run)) if *g == gen => run.as_ref(),
            _ => None,
        }
    }

    fn get_mut(&mut self, rid: u64) -> Option<&mut Running> {
        let (gen, slot) = Self::split(rid);
        match self.slots.get_mut(slot) {
            Some((g, run)) if *g == gen => run.as_mut(),
            _ => None,
        }
    }

    fn remove(&mut self, rid: u64) -> Option<Running> {
        let (gen, slot) = Self::split(rid);
        match self.slots.get_mut(slot) {
            Some((g, run)) if *g == gen && run.is_some() => {
                *g = g.wrapping_add(1);
                self.free.push(slot as u32);
                self.len -= 1;
                run.take()
            }
            _ => None,
        }
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Elastic-pool state for one site (present only when
/// `provisioner.enabled`; one entry per federation site, so every site
/// grows and shrinks against its own demand).
struct ProvisionState {
    /// The federation site this pool serves (a legacy multi-site world
    /// holds one entry per site; a federated site world holds only its
    /// own — ticks find their pool by site, not by index).
    site: u32,
    drp: Provisioner,
    /// Owns this site's slice of global node ids.
    cluster: ClusterProvider,
    /// Evaluation interval, seconds.
    interval_s: f64,
    /// Task slots per executor (cpus × tasks_per_cpu).
    capacity: usize,
    /// In-flight allocation grants, keyed by the `AllocReady` event id
    /// (ids are unique across sites — see `SimWorld::next_alloc_id`).
    pending_allocs: FxHashMap<u64, Vec<usize>>,
    /// Time of the previous evaluation (for executor-second integrals).
    last_tick: f64,
}

struct SimWorld {
    cfg: Config,
    caching: bool,
    format: DataFormat,
    expansion: f64,
    core: FedCore,
    /// The metered transfer plane: owns the wired testbed; every byte
    /// movement starts through it class-tagged, and background staging is
    /// admission-controlled against source egress utilization.
    plane: SimTransferPlane,
    caches: Vec<DataCache>,
    metrics: Metrics,
    dispatch_server: FifoServer,
    pending_tasks: Vec<Option<Task>>,
    runs: RunTable,
    flow_map: FxHashMap<FlowId, FlowInfo>,
    flow_version: u64,
    /// Per-executor sets of objects whose cache entry was created by
    /// replication staging — local hits on these count as
    /// `replica_hits`. Indexed by executor id (hot path: no pair hash).
    staged_replicas: Vec<FxHashSet<ObjectId>>,
    submit_times: FxHashMap<TaskId, f64>,
    first_dispatch: Option<f64>,
    total_tasks: u64,
    /// One elastic pool per site; empty for static pools.
    provs: Vec<ProvisionState>,
    /// Allocation-grant id source, shared by every site's pool.
    next_alloc_id: u64,
    /// Recycled per-run cache-event vectors: at 10⁵ executors the
    /// dispatch hot path must not allocate one per task.
    events_pool: Vec<Vec<CacheEvent>>,
    /// Federation-site scope: present iff this world is one site of a
    /// multi-site run on the parallel engine (`None` on the serial
    /// single-site path — every fed hook below then compiles away to a
    /// branch on this option).
    fed: Option<fedsim::FedScope>,
}

impl SimWorld {
    /// A fresh (cold) node-local cache for executor `e`.
    fn fresh_cache(cfg: &Config, e: ExecutorId) -> DataCache {
        DataCache::new(
            cfg.cache.capacity_bytes,
            cfg.cache.policy,
            cfg.seed ^ (e as u64).wrapping_mul(0x9E37_79B9),
        )
    }

    /// Handle one provisioner evaluation round for one site's pool.
    fn provision_tick(&mut self, now: f64, site: u32, q: &mut EventQueue<Ev>) {
        let mut provs = std::mem::take(&mut self.provs);
        let Some(prov) = provs.iter_mut().find(|p| p.site == site) else {
            self.provs = provs;
            return;
        };
        let sid = SiteId(site);
        let dt = (now - prov.last_tick).max(0.0);
        prov.last_tick = now;

        // Demand: this site's queue high-water mark since the last tick
        // (a burst that arrived and drained in between still registers).
        let queued_now = self.core.site_queue_len(sid);
        let demand = self.core.site_take_queue_peak(sid).max(queued_now);

        // Idle bookkeeping: an executor is a release candidate only while
        // every one of its slots is free.
        let quiescent = self.core.site(sid).quiescent_executors();
        for &e in self.core.site(sid).executors() {
            if quiescent.binary_search(&e).is_ok() {
                prov.drp.note_idle(e, now);
            } else {
                prov.drp.note_busy(e);
            }
        }
        self.metrics.idle_exec_s += quiescent.len() as f64 * dt;
        self.metrics.alloc_wait_s += prov.drp.pending() as f64 * dt;

        for action in prov.drp.evaluate(demand, now) {
            match action {
                ProvisionAction::Allocate { count } => {
                    self.metrics.alloc_requests += 1;
                    let grant = prov.cluster.allocate(now, count);
                    if grant.nodes.len() < count {
                        prov.drp.cancel_pending(count - grant.nodes.len());
                    }
                    if !grant.nodes.is_empty() {
                        let id = self.next_alloc_id;
                        self.next_alloc_id += 1;
                        prov.pending_allocs.insert(id, grant.nodes);
                        q.at(grant.ready_at, Ev::AllocReady(id));
                    }
                }
                ProvisionAction::Release { executors } => {
                    for e in executors {
                        // The provisioner only nominates executors it saw
                        // quiescent this round, but re-check with the core
                        // before tearing anything down.
                        if quiescent.binary_search(&e).is_err() {
                            continue;
                        }
                        // Deregistration purges the index and requeues
                        // parked tasks; the node cache dies with the lease.
                        let _orphans = self.core.deregister_executor(e);
                        // Deferred staging transfers touching the released
                        // executor are cancelled; free the replication
                        // manager's in-flight slots.
                        for req in self.plane.executor_released(e) {
                            self.core.replication_staged(req.obj, req.dst);
                        }
                        self.caches[e] = SimWorld::fresh_cache(&self.cfg, e);
                        self.staged_replicas[e].clear();
                        prov.cluster.release(e);
                        prov.drp.on_released(e);
                        self.metrics.executors_released += 1;
                        fedsim::note_executor_dropped(self, now, e);
                    }
                }
            }
        }
        // Membership changed (or may have): harvest the index backend's
        // control-plane bill (Chord stabilization; zero on central) and
        // the transfer plane's deferral count, so the pool sample that
        // follows sees current totals.
        let ct = self.core.take_index_control();
        self.metrics.add_control_traffic(ct);
        self.metrics.staging_deferred = self.plane.stats().deferred;
        let site_pending = prov.drp.pending();
        let interval_s = prov.interval_s;
        let multi = self.core.site_count() > 1;
        if multi {
            // Per-site pool timeline (the combined sample below keeps the
            // legacy figure inputs working).
            self.metrics.sample_site_pool(
                site as usize,
                now,
                self.core.site(sid).executor_count(),
                site_pending,
                queued_now,
            );
        }
        let total_pending: usize = provs.iter().map(|p| p.drp.pending()).sum();
        let total_queued = if multi { self.core.queue_len() } else { queued_now };
        let replicas = self.core.replica_location_entries();
        self.metrics.sample_pool(
            now,
            self.core.executor_count(),
            total_pending,
            total_queued,
            replicas,
        );
        // Keep evaluating while work (or an allocation) is outstanding.
        // A federated site cannot see the global task count, so it
        // ticks until the home site declares the run quiesced.
        let live = match &self.fed {
            Some(fed) => !fed.quiesced || site_pending > 0,
            None => self.metrics.tasks_done < self.total_tasks || site_pending > 0,
        };
        if live {
            q.after(interval_s, Ev::ProvisionTick(site));
        }
        self.provs = provs;
        fedsim::report_load(self, now);
        // A release may have requeued parked tasks onto live executors.
        let orders = self.core.try_dispatch();
        self.execute_orders(now, orders, q);
    }

    /// A cluster grant completed: the nodes register and take work.
    fn alloc_ready(&mut self, now: f64, id: u64, q: &mut EventQueue<Ev>) {
        let mut provs = std::mem::take(&mut self.provs);
        if let Some(prov) = provs
            .iter_mut()
            .find(|p| p.pending_allocs.contains_key(&id))
        {
            if let Some(nodes) = prov.pending_allocs.remove(&id) {
                let n = nodes.len();
                for e in nodes {
                    self.core.register_executor_with(e, prov.capacity);
                    self.caches[e] = SimWorld::fresh_cache(&self.cfg, e);
                }
                prov.drp.on_allocated(n);
                self.metrics.executors_joined += n as u64;
                self.metrics.peak_executors =
                    self.metrics.peak_executors.max(self.core.executor_count());
            }
        }
        self.provs = provs;
        fedsim::report_load(self, now);
        let orders = self.core.try_dispatch();
        self.execute_orders(now, orders, q);
    }

    /// One replication evaluation round: poll the manager, execute drop
    /// directives immediately (a release is local metadata work, not a
    /// transfer), and offer each staging directive to the transfer plane
    /// — admitted stagings become background peer-bandwidth flows,
    /// over-budget ones defer until their source drains.
    fn repl_tick(&mut self, now: f64, q: &mut EventQueue<Ev>) {
        for d in self.core.poll_replication() {
            match d {
                ReplicaDirective::Stage {
                    obj,
                    src,
                    dst,
                    prestage,
                } => {
                    let class = if prestage {
                        TransferClass::Prestage
                    } else {
                        TransferClass::Staging
                    };
                    let req = TransferRequest {
                        class,
                        obj,
                        src,
                        dst,
                        bytes: self.cached_size(obj),
                    };
                    match self.plane.submit(req) {
                        Admission::Start => self.launch_stage(now, req, q),
                        // Deferral is counted by the plane itself
                        // (stats().deferred) and synced into the metrics
                        // at harvest points — one source of truth.
                        Admission::Defer => {}
                    }
                }
                ReplicaDirective::Drop { obj, victim } => self.execute_drop(now, obj, victim),
            }
        }
        // Deferred stagings whose source drained since the last round.
        self.pump_admissions(now, q);
        // Keep evaluating while the workload is live; staging flows
        // already in flight drain through the flow network regardless.
        // (Federated sites tick until the home site declares quiesce.)
        let live = match &self.fed {
            Some(fed) => !fed.quiesced,
            None => self.metrics.tasks_done < self.total_tasks,
        };
        if live {
            q.after(self.cfg.replication.evaluate_interval_s.max(1e-3), Ev::ReplTick);
        }
    }

    /// Start an admitted staging transfer, re-validating against the
    /// current world: the index may lag the caches (loose coherence) and
    /// the pool may have churned since the directive (or its deferral) —
    /// stage only from a source whose cache really holds the object, to
    /// a registered destination that does not.
    fn launch_stage(&mut self, now: f64, req: TransferRequest, q: &mut EventQueue<Ev>) {
        let TransferRequest {
            class,
            obj,
            src,
            dst,
            bytes,
        } = req;
        let src_ok = src < self.caches.len() && self.caches[src].contains(obj);
        let dst_ok = dst < self.caches.len()
            && self.core.executors().binary_search(&dst).is_ok()
            && !self.caches[dst].contains(obj);
        if !self.caching || !src_ok || !dst_ok {
            self.core.replication_staged(obj, dst); // abandoned
            return;
        }
        self.start_flow(
            now,
            FlowTag::Replica { obj, dst },
            class,
            TransferKind::Peer { src, dst },
            bytes,
            q,
        );
    }

    /// Re-admit deferred staging transfers whose source has drained
    /// under the budget. Called whenever load may have dropped: after
    /// flow completions and on every replication tick.
    fn pump_admissions(&mut self, now: f64, q: &mut EventQueue<Ev>) {
        if self.plane.deferred_len() == 0 {
            return;
        }
        for req in self.plane.readmit() {
            self.launch_stage(now, req, q);
        }
    }

    /// Execute a replica-teardown directive: evict the victim's copy now
    /// (freeing cache space ahead of pressure eviction), unless the world
    /// moved on — the copy is gone, the lease ended, or the index no
    /// longer records a second copy to fall back on.
    fn execute_drop(&mut self, now: f64, obj: ObjectId, victim: ExecutorId) {
        let droppable = victim < self.caches.len()
            && self.core.executors().binary_search(&victim).is_ok()
            && self.caches[victim].contains(obj)
            && self.core.locations_for(victim, obj).len() > 1;
        if droppable && self.caches[victim].remove(obj) {
            self.staged_replicas[victim].remove(&obj);
            self.core
                .apply_cache_events(victim, &[CacheEvent::Evicted(obj)]);
            fedsim::digest(self, now, victim, &[CacheEvent::Evicted(obj)]);
            self.metrics.replicas_dropped += 1;
        }
        self.core.replication_dropped(obj, victim);
    }

    /// A replication staging flow completed: the copy enters the
    /// destination cache and the index (same path as any cache insert).
    fn replica_staged(&mut self, now: f64, obj: ObjectId, dst: ExecutorId) {
        self.core.replication_staged(obj, dst);
        let bytes = self.cached_size(obj);
        // The transfer happened whether or not the copy is still wanted:
        // account it as cache-to-cache traffic.
        self.metrics.add_bytes(ByteSource::CacheToCache, bytes);
        self.metrics.replica_bytes_staged += bytes;
        if !self.caching
            || dst >= self.caches.len()
            || self.core.executors().binary_search(&dst).is_err()
        {
            return; // destination lease ended while the copy was in flight
        }
        let events = self.caches[dst].insert(obj, bytes);
        let created = events
            .iter()
            .any(|e| matches!(e, CacheEvent::Inserted(o) if *o == obj));
        if !created {
            return; // already resident (an organic copy won the race)
        }
        for ev in &events {
            if let CacheEvent::Evicted(v) = ev {
                self.staged_replicas[dst].remove(v);
            }
        }
        self.core.apply_cache_events(dst, &events);
        fedsim::digest(self, now, dst, &events);
        self.staged_replicas[dst].insert(obj);
        self.metrics.replicas_created += 1;
    }

    /// Cached (post-expansion) size of an object.
    fn cached_size(&self, obj: ObjectId) -> u64 {
        let stored = self.core.catalog().size(obj).unwrap_or(1);
        (stored as f64 * self.expansion).ceil() as u64
    }

    fn stored_size(&self, obj: ObjectId) -> u64 {
        self.core.catalog().size(obj).unwrap_or(1)
    }

    /// The local open constant expressed as equivalent disk-read bytes at
    /// the configured rate, so small cached files still cost ~open_s.
    fn local_open_equiv_bytes(&self) -> u64 {
        (self.cfg.local_disk.open_s * self.cfg.local_disk.read_bps / 8.0) as u64
    }

    /// Start a class-tagged flow through the transfer plane and refresh
    /// the completion check.
    fn start_flow(
        &mut self,
        now: f64,
        tag: FlowTag,
        class: TransferClass,
        kind: TransferKind,
        bytes: u64,
        q: &mut EventQueue<Ev>,
    ) {
        if self.plane.testbed.cross_site(kind) {
            self.metrics.wan_bytes += bytes;
        }
        let fid = self.plane.start(now, class, kind, bytes);
        self.flow_map.insert(
            fid,
            FlowInfo {
                tag,
                class,
                bytes,
                t_start: now,
            },
        );
        self.reschedule_flow_check(now, q);
    }

    /// Start a class-tagged flow over an explicit resource set — the
    /// per-site *half* of a cross-site transfer (see the `SimTestbed`
    /// egress/ingress leg builders). Only the sender's half carries the
    /// WAN leg, so only `wan` halves meter cross-site bytes.
    #[allow(clippy::too_many_arguments)]
    fn start_flow_over(
        &mut self,
        now: f64,
        tag: FlowTag,
        class: TransferClass,
        rs: &crate::storage::testbed::ResourceSet,
        bytes: u64,
        wan: bool,
        q: &mut EventQueue<Ev>,
    ) {
        if wan {
            self.metrics.wan_bytes += bytes;
        }
        let fid = self.plane.start_over(now, class, rs, bytes);
        self.flow_map.insert(
            fid,
            FlowInfo {
                tag,
                class,
                bytes,
                t_start: now,
            },
        );
        self.reschedule_flow_check(now, q);
    }

    fn reschedule_flow_check(&mut self, now: f64, q: &mut EventQueue<Ev>) {
        self.flow_version += 1;
        if let Some((t, _)) = self.plane.testbed.net.next_completion(now) {
            q.at(t, Ev::FlowCheck(self.flow_version));
        }
    }

    /// Handle flow completions that are due at `now`.
    fn flow_check(&mut self, now: f64, version: u64, q: &mut EventQueue<Ev>) {
        if version != self.flow_version {
            return; // stale check; a newer one is scheduled
        }
        self.plane.testbed.net.advance_to(now);
        loop {
            match self.plane.testbed.net.next_completion(now) {
                Some((t, fid)) if t <= now + 1e-9 => {
                    self.plane.testbed.net.remove_flow(now, fid);
                    if let Some(info) = self.flow_map.remove(&fid) {
                        self.metrics
                            .note_class_transfer(info.class, info.bytes, now - info.t_start);
                        match info.tag {
                            FlowTag::Run(rid, purpose) => self.flow_done(now, rid, purpose, q),
                            FlowTag::Replica { obj, dst } => self.replica_staged(now, obj, dst),
                            FlowTag::Remote(xid) => fedsim::remote_flow_done(self, now, xid),
                        }
                    }
                }
                _ => break,
            }
        }
        // Completions freed egress bandwidth: deferred stagings whose
        // source dropped under budget can start now.
        self.pump_admissions(now, q);
        self.reschedule_flow_check(now, q);
    }

    /// Process the dispatch orders produced by the core.
    fn execute_orders(&mut self, now: f64, orders: Vec<DispatchOrder>, q: &mut EventQueue<Ev>) {
        for order in orders {
            if self.first_dispatch.is_none() {
                self.first_dispatch = Some(now);
                self.metrics.t_start = now;
            }
            self.metrics.tasks_dispatched += 1;
            self.metrics.add_index_cost(order.cost);
            // The dispatcher is a serial service (§3.1: ~3800 tasks/s)
            // that first resolves locations through the configured index
            // (free on the central backend, routed hops on chord), then
            // the 1–2 ms network hop to the executor. Index latency is
            // part of the serial service time — back-to-back dispatches
            // queue behind each other's lookups, which is exactly how a
            // distributed index erodes dispatcher throughput (§3.2.3).
            let t_out = self
                .dispatch_server
                .submit_secs(now, 1.0 / DISPATCH_RATE + order.cost.latency_s);
            let rid = self.runs.insert(Running {
                t_submit: self.submit_times.remove(&order.task.id).unwrap_or(now),
                t_dispatch: now,
                task: order.task,
                exec: order.executor,
                hints: order.hints,
                next_input: 0,
                phase: Phase::Start,
                refetch_src: None,
                events: self.events_pool.pop().unwrap_or_default(),
            });
            q.at(t_out + self.cfg.testbed.net_latency_s, Ev::AtExecutor(rid));
        }
    }

    /// A timed phase for run `rid` elapsed: advance its state machine.
    fn step(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        let Some(run) = self.runs.get(rid) else {
            return;
        };
        match run.phase {
            Phase::Start => {
                if self.cfg.scheduler.wrapper {
                    // mkdir + symlink on persistent storage before work.
                    let pre = self.cfg.shared_fs.meta_ops_wrapper.saturating_sub(1).max(1);
                    let secs = pre as f64 * self.cfg.shared_fs.wrapper_op_s;
                    self.runs.get_mut(rid).unwrap().phase = Phase::Fetch;
                    if self.fed_remote() {
                        // The sandbox directory lives on the home
                        // site's shared FS: the ops round-trip the WAN.
                        fedsim::meta_request(self, now, rid, 0, secs, fedsim::MetaThen::Ack);
                    } else {
                        let done = self.plane.testbed.metadata.submit_secs(now, secs);
                        q.at(done, Ev::Step(rid));
                    }
                } else {
                    self.runs.get_mut(rid).unwrap().phase = Phase::Fetch;
                    self.step(now, rid, q);
                }
            }
            Phase::Fetch => self.fetch_next_input(now, rid, q),
            Phase::GpfsOpen => {
                // Metadata open done; start the GPFS data transfer.
                let run = self.runs.get_mut(rid).unwrap();
                let obj = run.task.inputs[run.next_input];
                let node = run.exec;
                run.phase = Phase::AwaitFlow;
                let bytes = self.stored_size(obj);
                let kind = if self.caching {
                    TransferKind::GpfsReadCached { node }
                } else {
                    TransferKind::GpfsRead { node }
                };
                self.start_flow(
                    now,
                    FlowTag::Run(rid, FlowPurpose::FetchGpfs),
                    TransferClass::Foreground,
                    kind,
                    bytes,
                    q,
                );
            }
            Phase::Refetch => {
                // The executor-side re-resolution paid its lookup cost;
                // fetch from the fresh copy it found (re-validated — the
                // copy may have been evicted during the lookup) or fall
                // through to persistent storage.
                let run = self.runs.get_mut(rid).unwrap();
                let obj = run.task.inputs[run.next_input];
                let exec = run.exec;
                let src = run.refetch_src.take();
                let src = src.filter(|&p| p < self.caches.len() && self.caches[p].contains(obj));
                match src {
                    Some(src) => {
                        self.core.note_peer_fetch(obj, exec);
                        let bytes = self.cached_size(obj);
                        self.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
                        self.start_flow(
                            now,
                            FlowTag::Run(rid, FlowPurpose::FetchPeer),
                            TransferClass::Foreground,
                            TransferKind::Peer { src, dst: exec },
                            bytes,
                            q,
                        );
                    }
                    None => self.gpfs_open_input(now, rid, q),
                }
            }
            Phase::AwaitFlow => {
                debug_assert!(false, "AwaitFlow must resolve via flow_done");
            }
            Phase::Decompress => {
                // CPU decompression finished: object (now uncompressed)
                // enters the cache and the fetch loop continues.
                self.finish_input_fetch(now, rid, ByteSource::Gpfs, q);
            }
            Phase::OutputStart => {
                let run = self.runs.get(rid).unwrap();
                let bytes = run.task.output_bytes;
                let node = run.exec;
                if bytes == 0 {
                    self.runs.get_mut(rid).unwrap().phase = Phase::WrapperPost;
                    self.step(now, rid, q);
                } else if self.caching {
                    // Diffused outputs land on local disk.
                    self.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
                    self.start_flow(
                        now,
                        FlowTag::Run(rid, FlowPurpose::WriteLocal),
                        TransferClass::Foreground,
                        TransferKind::LocalWrite { node },
                        bytes,
                        q,
                    );
                } else if self.fed_remote() {
                    // GPFS output from a non-home site: push the bytes
                    // toward the home file system — sender-side legs
                    // here; the metadata create and the home-side legs
                    // happen at the home site when the data arrives.
                    self.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
                    let rs = self.plane.testbed.gpfs_write_egress(node);
                    self.start_flow_over(
                        now,
                        FlowTag::Run(rid, FlowPurpose::WriteGpfsWan),
                        TransferClass::Foreground,
                        &rs,
                        bytes,
                        true,
                        q,
                    );
                } else {
                    // GPFS output: metadata create, then the data flow.
                    let done = self
                        .plane
                        .testbed
                        .metadata
                        .submit(now, self.cfg.shared_fs.meta_ops_open);
                    self.runs.get_mut(rid).unwrap().phase = Phase::OutputOpen;
                    q.at(done, Ev::Step(rid));
                }
            }
            Phase::OutputOpen => {
                // Output create done; start the GPFS write flow.
                let run = self.runs.get_mut(rid).unwrap();
                let bytes = run.task.output_bytes;
                let node = run.exec;
                run.phase = Phase::AwaitFlow;
                self.start_flow(
                    now,
                    FlowTag::Run(rid, FlowPurpose::WriteGpfs),
                    TransferClass::Foreground,
                    TransferKind::GpfsWrite { node },
                    bytes,
                    q,
                );
            }
            Phase::WrapperPost => self.complete_run(now, rid, q),
        }
    }

    /// Resolve the next input of run `rid`, or move on to compute.
    fn fetch_next_input(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        let run = self.runs.get(rid).unwrap();
        if run.next_input >= run.task.inputs.len() {
            return self.start_compute(now, rid, q);
        }
        let obj = run.task.inputs[run.next_input];
        let exec = run.exec;

        if self.caching && self.caches[exec].access(obj) {
            // Own cache: local disk read of the (uncompressed) object.
            // (The sub-millisecond local-FS open constant is charged as
            // part of the flow; it is negligible against transfer times
            // and — unlike GPFS opens — contends with nothing.)
            if self.staged_replicas.contains(&(exec, obj)) {
                self.metrics.replica_hits += 1;
            }
            let bytes = self.cached_size(obj) + self.local_open_equiv_bytes();
            self.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
            self.start_flow(
                now,
                FlowTag::Run(rid, FlowPurpose::FetchLocal),
                TransferClass::Foreground,
                TransferKind::LocalRead { node: exec },
                bytes,
                q,
            );
            return;
        }

        if self.caching {
            // Peer hint: the first hinted executor that still holds it
            // (hints are ranked by the scheduler, so replicas share the
            // peer-fetch load).
            let peer = run
                .hints
                .get(&obj)
                .and_then(|locs| {
                    locs.iter()
                        .find(|&&p| p != exec && p < self.caches.len() && self.caches[p].contains(obj))
                })
                .copied();
            if let Some(src) = peer {
                self.core.note_peer_fetch(obj, exec);
                let bytes = self.cached_size(obj);
                self.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
                self.start_flow(
                    now,
                    FlowTag::Run(rid, FlowPurpose::FetchPeer),
                    TransferClass::Foreground,
                    TransferKind::Peer { src, dst: exec },
                    bytes,
                    q,
                );
                return;
            }
            // Every hinted copy is gone (§3.2.2: hints went stale): the
            // executor re-resolves against the index, paying the same
            // routed lookup a dispatch-side resolution pays — and may
            // discover a replica staged after dispatch.
            let hinted = run
                .hints
                .get(&obj)
                .is_some_and(|locs| locs.iter().any(|&p| p != exec));
            if hinted {
                let cost = self.core.lookup_cost_for(exec, obj);
                self.metrics.add_index_cost(cost);
                let rot = run.task.id.0 as usize;
                let fresh = {
                    let locs = self.core.locations_for(exec, obj);
                    if locs.is_empty() {
                        None
                    } else {
                        (0..locs.len())
                            .map(|i| locs[(i + rot) % locs.len()])
                            .find(|&p| {
                                p != exec && p < self.caches.len() && self.caches[p].contains(obj)
                            })
                    }
                };
                let run = self.runs.get_mut(rid).unwrap();
                run.refetch_src = fresh;
                run.phase = Phase::Refetch;
                q.after(cost.latency_s, Ev::Step(rid));
                return;
            }
            // Federation ship-data: nothing local and no hints — ask the
            // global directory whether a peer *site* holds a cached copy
            // before falling back to persistent storage (itself a WAN
            // hop away from every non-home site). On the serial legacy
            // path a hit re-enters the Refetch machinery; on the
            // parallel engine the directory and the holder's cache are
            // other sites' state, so both the lookup and the transfer
            // go through the inter-site channel (the holder site
            // re-validates its own cache and fails the request back to
            // GPFS if the copy evaporated in flight).
            if self.fed.is_some() {
                if fedsim::request_remote(self, now, rid) {
                    return;
                }
            } else if let Some((src, cost)) = self.core.remote_holder(exec, obj) {
                self.metrics.add_index_cost(cost);
                let run = self.runs.get_mut(rid).unwrap();
                run.refetch_src = Some(src);
                run.phase = Phase::Refetch;
                q.after(cost.latency_s, Ev::Step(rid));
                return;
            }
        }

        // Persistent storage: metadata open, then the data flow.
        self.gpfs_open_input(now, rid, q);
    }

    /// Open the current input on persistent storage and start its read
    /// — the shared tail of the fetch path and its stale-hint fallback.
    /// At a non-home federation site both the open and the read happen
    /// at the home site, reached through the inter-site channel.
    fn gpfs_open_input(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        if self.fed_remote() {
            let run = self.runs.get_mut(rid).unwrap();
            run.phase = Phase::AwaitFlow;
            let obj = run.task.inputs[run.next_input];
            let bytes = self.stored_size(obj);
            let ops = self.cfg.shared_fs.meta_ops_open;
            fedsim::meta_request(self, now, rid, ops, 0.0, fedsim::MetaThen::GpfsRead { bytes });
            return;
        }
        let done = self
            .plane
            .testbed
            .metadata
            .submit(now, self.cfg.shared_fs.meta_ops_open);
        self.runs.get_mut(rid).unwrap().phase = Phase::GpfsOpen;
        q.at(done, Ev::Step(rid));
    }

    /// Whether this world is a non-home site of a parallel federated
    /// run — home-site resources (GPFS, the metadata server, the
    /// directory) are then only reachable via inter-site messages.
    fn fed_remote(&self) -> bool {
        self.fed.as_ref().is_some_and(|f| f.site != 0)
    }

    /// A data flow for run `rid` completed.
    fn flow_done(&mut self, now: f64, rid: u64, purpose: FlowPurpose, q: &mut EventQueue<Ev>) {
        let run = self.runs.get(rid).unwrap();
        match purpose {
            FlowPurpose::FetchLocal => {
                let obj = run.task.inputs[run.next_input];
                let bytes = self.cached_size(obj);
                self.metrics.add_bytes(ByteSource::Local, bytes);
                self.finish_input_fetch(now, rid, ByteSource::Local, q);
            }
            FlowPurpose::FetchPeer => {
                let obj = run.task.inputs[run.next_input];
                let bytes = self.cached_size(obj);
                self.metrics.add_bytes(ByteSource::CacheToCache, bytes);
                self.finish_input_fetch(now, rid, ByteSource::CacheToCache, q);
            }
            FlowPurpose::FetchGpfs => {
                let obj = run.task.inputs[run.next_input];
                let bytes = self.stored_size(obj);
                self.metrics.add_bytes(ByteSource::Gpfs, bytes);
                if self.format == DataFormat::Gz && self.cfg.app.decompress_s > 0.0 {
                    // CPU decompression before the data is usable.
                    self.runs.get_mut(rid).unwrap().phase = Phase::Decompress;
                    q.after(self.cfg.app.decompress_s, Ev::Step(rid));
                } else {
                    self.finish_input_fetch(now, rid, ByteSource::Gpfs, q);
                }
            }
            FlowPurpose::WriteLocal => {
                let bytes = run.task.output_bytes;
                // Local outputs are still new bytes written on the node;
                // account them as local traffic.
                self.metrics.add_bytes(ByteSource::Local, bytes);
                self.runs.get_mut(rid).unwrap().phase = Phase::WrapperPost;
                self.after_output(now, rid, q);
            }
            FlowPurpose::WriteGpfs => {
                let bytes = run.task.output_bytes;
                self.metrics.add_bytes(ByteSource::GpfsWrite, bytes);
                self.runs.get_mut(rid).unwrap().phase = Phase::WrapperPost;
                self.after_output(now, rid, q);
            }
            FlowPurpose::WriteGpfsWan => {
                // Sender half done: hand the output to the home site
                // (metadata create + home-side legs + the ack happen
                // there). The run stays in AwaitFlow until WriteAck.
                let bytes = run.task.output_bytes;
                fedsim::send_write(self, now, rid, bytes);
            }
        }
    }

    /// Input resolved (from `source`); update cache + metrics, continue.
    fn finish_input_fetch(
        &mut self,
        now: f64,
        rid: u64,
        source: ByteSource,
        q: &mut EventQueue<Ev>,
    ) {
        self.metrics.add_resolution(source);
        let run = self.runs.get(rid).unwrap();
        let obj = run.task.inputs[run.next_input];
        let exec = run.exec;
        if self.caching && source != ByteSource::Local {
            // New object on this node (cached uncompressed).
            let bytes = self.cached_size(obj);
            let events = self.caches[exec].insert(obj, bytes);
            for ev in &events {
                if let CacheEvent::Evicted(v) = ev {
                    self.staged_replicas[exec].remove(v);
                }
            }
            self.runs.get_mut(rid).unwrap().events.extend(events);
        }
        let run = self.runs.get_mut(rid).unwrap();
        run.next_input += 1;
        run.phase = Phase::Fetch;
        self.fetch_next_input(now, rid, q);
    }

    /// All inputs resolved: run the compute, then move to output.
    fn start_compute(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        let run = self.runs.get_mut(rid).unwrap();
        let cpu = match run.task.kind {
            TaskKind::Synthetic { cpu_s } => cpu_s,
            TaskKind::Stack { .. } => self.cfg.app.radec2xy_s + self.cfg.app.stack_compute_s,
        };
        run.phase = Phase::OutputStart;
        if cpu > 0.0 {
            q.after(cpu, Ev::Step(rid));
        } else {
            self.step(now, rid, q);
        }
    }

    /// Output written (or skipped): wrapper post-op then completion.
    fn after_output(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        if self.cfg.scheduler.wrapper {
            // rmdir of the sandbox directory on persistent storage.
            if self.fed_remote() {
                let secs = self.cfg.shared_fs.wrapper_op_s;
                fedsim::meta_request(self, now, rid, 0, secs, fedsim::MetaThen::Ack);
            } else {
                let done = self
                    .plane
                    .testbed
                    .metadata
                    .submit_secs(now, self.cfg.shared_fs.wrapper_op_s);
                q.at(done, Ev::Step(rid));
            }
        } else {
            self.complete_run(now, rid, q);
        }
    }

    /// Task finished on its executor: report to the dispatcher.
    fn complete_run(&mut self, now: f64, rid: u64, q: &mut EventQueue<Ev>) {
        let mut run = self.runs.remove(rid).unwrap();
        self.metrics.tasks_done += 1;
        self.metrics.note_task_latency(now - run.t_submit);
        self.metrics.exec_latency.add(now - run.t_dispatch);
        self.metrics.t_end = now;
        self.core.on_task_complete(run.exec, run.task.id, &run.events);
        let mut events = std::mem::take(&mut run.events);
        if self.fed.is_some() {
            // The completion (with its cache deltas) feeds the home
            // site's directory and load books.
            fedsim::on_complete(self, now, run.exec, events);
        } else {
            events.clear();
            if self.events_pool.len() < 4096 {
                self.events_pool.push(events);
            }
        }
        // Wake only the shard that owns the freed executor: the other
        // shards' idle sets did not change (they steal on their own
        // wake-ups if this completion leaves queues imbalanced).
        let shard = self.core.shard_of_executor(run.exec) as u32;
        q.after(self.cfg.testbed.net_latency_s, Ev::Dispatch(shard));
    }
}

impl World for SimWorld {
    type Event = Ev;

    fn handle(&mut self, now: f64, ev: Ev, q: &mut EventQueue<Ev>) {
        match ev {
            Ev::Arrive(i) => {
                if let Some(task) = self.pending_tasks[i as usize].take() {
                    if self.fed.is_some() {
                        // Arrivals land at the home site's frontend,
                        // which routes them across sites.
                        fedsim::route_arrival(self, now, task, q);
                    } else {
                        self.submit_times.insert(task.id, now);
                        self.core.submit(task);
                        let orders = self.core.try_dispatch();
                        self.execute_orders(now, orders, q);
                    }
                }
            }
            Ev::Dispatch(s) => {
                let orders = self.core.try_dispatch_shard(s as usize);
                self.execute_orders(now, orders, q);
            }
            Ev::AtExecutor(rid) => self.step(now, rid, q),
            Ev::Step(rid) => self.step(now, rid, q),
            Ev::FlowCheck(v) => self.flow_check(now, v, q),
            Ev::ProvisionTick(site) => self.provision_tick(now, site, q),
            Ev::AllocReady(id) => self.alloc_ready(now, id, q),
            Ev::ReplTick => self.repl_tick(now, q),
            Ev::Msg(from, msg) => fedsim::handle_msg(self, now, from, msg, q),
            Ev::MetaStep(xid) => fedsim::meta_step(self, now, xid, q),
        }
    }
}

/// Drives one simulated experiment.
pub struct SimDriver {
    cfg: Config,
    spec: SimWorkloadSpec,
    catalog: Catalog,
}

impl SimDriver {
    /// Build a driver from a config, workload spec, and object catalog
    /// (stored sizes of every object the workload references).
    pub fn new(cfg: Config, spec: SimWorkloadSpec, catalog: Catalog) -> SimDriver {
        SimDriver { cfg, spec, catalog }
    }

    /// Run to completion and return the outcome.
    pub fn run(self) -> RunOutcome {
        let SimDriver { cfg, spec, catalog } = self;
        if cfg.sites() > 1 {
            // Multi-site runs decompose into per-site worlds on the
            // conservative-lookahead parallel engine; the merged
            // outcome is bit-for-bit identical at every `sim.threads`
            // setting (tests/parallel_equivalence.rs).
            return fedsim::run_federated(cfg, spec, catalog);
        }
        let t0 = std::time::Instant::now();

        // One dispatch core per site (one total without `[[site]]`
        // tables), each sharded with its own disjoint index slices; the
        // federation facade routes submissions and mirrors cache events
        // into the cross-site directory.
        let mut core = FedCore::new(&cfg, catalog);
        let nodes = cfg.testbed.nodes;
        let capacity = (cfg.testbed.cpus_per_node * cfg.scheduler.tasks_per_cpu).max(1);
        let mut provs = Vec::new();
        if cfg.provisioner.enabled {
            // Elastic pools, one per site over the site's node slice:
            // each starts at min_executors (granted instantly — the warm
            // floor is provisioned before the run), then grows and
            // shrinks through its own ProvisionTick / AllocReady events.
            assert!(
                nodes > 0 && cfg.provisioner.max_executors > 0,
                "elastic pool needs at least one allocatable executor"
            );
            let n_sites = core.site_count();
            for s in 0..n_sites {
                let range = core.topology().executor_range(SiteId(s as u32));
                let site_nodes = range.len();
                let mut pcfg = cfg.provisioner.clone();
                if n_sites > 1 {
                    // Clamp the global bounds to what the site owns.
                    pcfg.max_executors = pcfg.max_executors.min(site_nodes);
                    pcfg.min_executors = pcfg.min_executors.min(site_nodes);
                }
                let mut drp = Provisioner::new(pcfg.clone());
                let mut cluster =
                    ClusterProvider::with_range(range, cfg.provisioner.allocation_latency_s);
                let warm = pcfg.min_executors.min(site_nodes);
                if warm > 0 {
                    let grant = cluster.allocate(0.0, warm);
                    for &e in &grant.nodes {
                        core.register_executor_with(e, capacity);
                    }
                    drp.on_allocated(grant.nodes.len());
                }
                provs.push(ProvisionState {
                    site: s as u32,
                    drp,
                    cluster,
                    interval_s: cfg.provisioner.poll_interval_s.max(1e-3),
                    capacity,
                    pending_allocs: FxHashMap::default(),
                    last_tick: 0.0,
                });
            }
        } else {
            for e in 0..nodes {
                core.register_executor_with(e, capacity);
            }
        }
        // Enabled after the initial pool registered: the warm floor is
        // membership, not a join wave to pre-stage. Meaningless without
        // caching (there is nothing to replicate from).
        let replicating = cfg.replication.enabled && spec.caching;
        let repl_interval_s = cfg.replication.evaluate_interval_s.max(1e-3);
        if replicating {
            core.enable_replication(&cfg.replication);
        }

        let mut caches: Vec<DataCache> = (0..nodes)
            .map(|e| {
                DataCache::new(
                    cfg.cache.capacity_bytes,
                    cfg.cache.policy,
                    cfg.seed ^ (e as u64).wrapping_mul(0x9E37_79B9),
                )
            })
            .collect();

        // Pre-warm caches + index (100%-locality configurations).
        let expansion = spec.expansion;
        for &(exec, obj) in &spec.prewarm {
            let stored = core.catalog().size(obj).unwrap_or(1);
            let bytes = (stored as f64 * expansion).ceil() as u64;
            let events = caches[exec].insert(obj, bytes);
            core.apply_cache_events(exec, &events);
        }

        let plane = SimTransferPlane::new(SimTestbed::new(&cfg), &cfg.transfer);
        let caching = spec.caching;
        let format = spec.format;
        let arrivals: Vec<(f64, u32)> = spec
            .tasks
            .iter()
            .enumerate()
            .map(|(i, (t, _))| (*t, i as u32))
            .collect();
        let pending_tasks: Vec<Option<Task>> =
            spec.tasks.iter().map(|(_, t)| Some(t.clone())).collect();

        let total_tasks = pending_tasks.len() as u64;
        let n_pools = provs.len();
        let world = SimWorld {
            cfg,
            caching,
            format,
            expansion,
            core,
            plane,
            caches,
            metrics: Metrics::new(),
            dispatch_server: FifoServer::new(1.0 / DISPATCH_RATE),
            pending_tasks,
            runs: RunTable::new(),
            flow_map: FxHashMap::default(),
            flow_version: 0,
            staged_replicas: (0..nodes).map(|_| FxHashSet::default()).collect(),
            submit_times: FxHashMap::default(),
            first_dispatch: None,
            total_tasks,
            provs,
            next_alloc_id: 0,
            events_pool: Vec::new(),
            fed: None,
        };

        let mut engine = Engine::new(world);
        for s in 0..n_pools {
            engine.schedule(0.0, Ev::ProvisionTick(s as u32));
        }
        if replicating {
            engine.schedule(repl_interval_s, Ev::ReplTick);
        }
        for (t, i) in arrivals {
            engine.schedule(t, Ev::Arrive(i));
        }
        let end = engine.run();
        // Final harvests: static pools never tick the provisioner, so
        // bootstrap registrations (Chord: one rebuild per join) and the
        // transfer plane's admission counters are collected here.
        let control = engine.world.core.take_index_control();
        engine.world.metrics.add_control_traffic(control);
        engine.world.metrics.staging_deferred = engine.world.plane.stats().deferred;
        let shard_stats = engine.world.core.shard_stats();
        engine.world.metrics.harvest_shard_stats(&shard_stats);
        // Federation bill: tasks shipped off their origin site plus the
        // directory cost of routing them there.
        engine.world.metrics.cross_site_tasks = engine.world.core.cross_site_tasks();
        let route_cost = engine.world.core.take_route_cost();
        engine.world.metrics.add_index_cost(route_cost);
        let mut metrics = engine.world.metrics.clone();
        metrics.peak_executors = metrics
            .peak_executors
            .max(engine.world.core.executor_count());
        let makespan = (metrics.t_end - metrics.t_start).max(0.0);
        debug_assert!(
            engine.world.runs.is_empty(),
            "tasks stuck in flight at quiesce"
        );
        let _ = end;
        RunOutcome {
            metrics,
            makespan_s: makespan,
            events: engine.events_processed(),
            wall_s: t0.elapsed().as_secs_f64(),
            sample_checksums: Vec::new(),
        }
    }
}

impl Driver for SimDriver {
    fn run(self) -> crate::error::Result<RunOutcome> {
        Ok(SimDriver::run(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::DispatchPolicy;
    use crate::util::units::MB;

    fn catalog(n: u64, bytes: u64) -> Catalog {
        let mut c = Catalog::new();
        for i in 0..n {
            c.insert(ObjectId(i), bytes);
        }
        c
    }

    fn read_tasks(n: u64) -> Vec<(f64, Task)> {
        (0..n)
            .map(|i| (0.0, Task::with_inputs(TaskId(i), vec![ObjectId(i)])))
            .collect()
    }

    fn dummy_run(i: u64) -> Running {
        Running {
            task: Task::with_inputs(TaskId(i), vec![ObjectId(i)]),
            exec: 0,
            hints: LocationHints::new(),
            t_submit: 0.0,
            t_dispatch: 0.0,
            next_input: 0,
            phase: Phase::Start,
            refetch_src: None,
            events: Vec::new(),
        }
    }

    #[test]
    fn run_table_recycles_slots_with_generation_guard() {
        let mut t = RunTable::new();
        let a = t.insert(dummy_run(1));
        let b = t.insert(dummy_run(2));
        assert_ne!(a, b);
        assert_eq!(t.get(a).unwrap().task.id, TaskId(1));
        assert_eq!(t.remove(a).unwrap().task.id, TaskId(1));
        assert!(t.get(a).is_none(), "removed id never resolves");
        // LIFO slot reuse: the freed slot returns under a new generation,
        // so the recycled id differs and the stale one stays dead.
        let c = t.insert(dummy_run(3));
        assert_eq!(c & 0xFFFF_FFFF, a & 0xFFFF_FFFF, "slot reused");
        assert_ne!(c, a, "generation advanced");
        assert!(t.get(a).is_none(), "stale id cannot see the new run");
        assert_eq!(t.get_mut(c).unwrap().task.id, TaskId(3));
        let _ = t.remove(b);
        let _ = t.remove(c);
        assert!(t.is_empty());
    }

    #[test]
    fn all_tasks_complete_exactly_once() {
        let cfg = Config::with_nodes(4);
        let spec = SimWorkloadSpec::new(read_tasks(50));
        let out = SimDriver::new(cfg, spec, catalog(50, MB)).run();
        assert_eq!(out.metrics.tasks_done, 50);
        assert_eq!(out.metrics.tasks_dispatched, 50);
        assert!(out.makespan_s > 0.0);
    }

    #[test]
    fn cold_unique_objects_all_miss_to_gpfs() {
        let cfg = Config::with_nodes(4);
        let spec = SimWorkloadSpec::new(read_tasks(20));
        let out = SimDriver::new(cfg, spec, catalog(20, MB)).run();
        assert_eq!(out.metrics.gpfs_misses, 20);
        assert_eq!(out.metrics.cache_hits, 0);
        assert_eq!(out.metrics.gpfs_bytes, 20 * MB);
    }

    #[test]
    fn repeated_object_hits_cache_with_data_aware_policy() {
        let mut cfg = Config::with_nodes(4);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        // 40 sequential tasks over the same object: first misses, the
        // rest should be routed back to the cache holder.
        let tasks: Vec<(f64, Task)> = (0..40)
            .map(|i| {
                (
                    i as f64 * 10.0, // spaced: strictly sequential
                    Task::with_inputs(TaskId(i), vec![ObjectId(0)]),
                )
            })
            .collect();
        let spec = SimWorkloadSpec::new(tasks);
        let out = SimDriver::new(cfg, spec, catalog(1, MB)).run();
        assert_eq!(out.metrics.gpfs_misses, 1, "only the cold miss");
        assert_eq!(out.metrics.cache_hits, 39);
        assert_eq!(out.metrics.gpfs_bytes, MB);
    }

    #[test]
    fn caching_off_always_goes_to_gpfs() {
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        let tasks: Vec<(f64, Task)> = (0..10)
            .map(|i| (0.0, Task::with_inputs(TaskId(i), vec![ObjectId(0)])))
            .collect();
        let mut spec = SimWorkloadSpec::new(tasks);
        spec.caching = false;
        let out = SimDriver::new(cfg, spec, catalog(1, MB)).run();
        assert_eq!(out.metrics.gpfs_misses, 10);
        assert_eq!(out.metrics.cache_hits, 0);
        assert_eq!(out.metrics.gpfs_bytes, 10 * MB);
    }

    #[test]
    fn prewarm_gives_full_locality() {
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        let mut spec = SimWorkloadSpec::new(
            (0..10u64)
                .map(|i| {
                    (
                        i as f64, // sequential
                        Task::with_inputs(TaskId(i), vec![ObjectId(i % 2)]),
                    )
                })
                .collect(),
        );
        spec.prewarm = vec![(0, ObjectId(0)), (1, ObjectId(1))];
        let out = SimDriver::new(cfg, spec, catalog(2, MB)).run();
        assert_eq!(out.metrics.gpfs_misses, 0, "warm caches: no GPFS reads");
        assert_eq!(out.metrics.cache_hits + out.metrics.peer_hits, 10);
    }

    #[test]
    fn gz_pays_decompression_and_expands() {
        let mut cfg = Config::with_nodes(1);
        cfg.app.decompress_s = 0.5;
        let mut spec = SimWorkloadSpec::new(read_tasks(2));
        spec.format = DataFormat::Gz;
        spec.expansion = 3.0;
        let out = SimDriver::new(cfg.clone(), spec, catalog(2, 2 * MB)).run();
        // 2 sequential tasks, each: GPFS fetch (2 MB) + 0.5 s decompress.
        assert!(out.makespan_s > 1.0, "decompression must be charged");
        assert_eq!(out.metrics.gpfs_bytes, 4 * MB);
    }

    #[test]
    fn read_write_accounts_gpfs_writes_when_uncached() {
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        let tasks: Vec<(f64, Task)> = (0..5)
            .map(|i| (0.0, Task::read_write(TaskId(i), ObjectId(i), MB)))
            .collect();
        let mut spec = SimWorkloadSpec::new(tasks);
        spec.caching = false;
        let out = SimDriver::new(cfg, spec, catalog(5, MB)).run();
        assert_eq!(out.metrics.gpfs_write_bytes, 5 * MB);
    }

    #[test]
    fn chord_backend_runs_end_to_end_and_charges_cost() {
        use crate::index::IndexBackend;
        let run = |backend: IndexBackend| {
            let mut cfg = Config::with_nodes(8);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.index.backend = backend;
            // Repeated objects: warm index state, real lookups.
            let tasks: Vec<(f64, Task)> = (0..64)
                .map(|i| {
                    (
                        i as f64 * 0.5,
                        Task::with_inputs(TaskId(i), vec![ObjectId(i % 16)]),
                    )
                })
                .collect();
            SimDriver::new(cfg, SimWorkloadSpec::new(tasks), catalog(16, MB)).run()
        };
        let central = run(IndexBackend::Central);
        let chord = run(IndexBackend::Chord);
        // Both complete the workload; placement (and thus byte movement)
        // is identical — the backend changes only the charged cost.
        assert_eq!(chord.metrics.tasks_done, 64);
        assert_eq!(central.metrics.cache_hits, chord.metrics.cache_hits);
        assert_eq!(central.metrics.gpfs_misses, chord.metrics.gpfs_misses);
        assert_eq!(central.metrics.index_lookups, chord.metrics.index_lookups);
        assert!(central.metrics.index_hops == 0, "central index never routes");
        assert!(chord.metrics.index_hops > 0, "chord lookups must route");
        assert!(chord.metrics.index_cost_s > central.metrics.index_cost_s);
        // Control plane: even a static pool pays bootstrap stabilization
        // on chord (one rebuild per registration); central pays nothing.
        assert!(chord.metrics.stabilization_msgs > 0, "chord joins must stabilize");
        assert_eq!(central.metrics.stabilization_msgs, 0, "central has no control plane");
        assert!(
            chord.makespan_s >= central.makespan_s,
            "routed lookups cannot make the run faster: {} vs {}",
            chord.makespan_s,
            central.makespan_s
        );
    }

    #[test]
    fn replication_stages_copies_and_serves_local_hits() {
        // One hot object, prewarmed on executor 0 only, tasks spaced so
        // the holder is always idle when the next task arrives: without
        // replication every task runs on executor 0 and no second copy
        // ever exists. With replication the manager stages a copy and
        // the tie-rotation spreads tasks across both holders.
        let run = |replication: bool| {
            let mut cfg = Config::with_nodes(4);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.replication.enabled = replication;
            cfg.replication.max_replicas = 2;
            cfg.replication.demand_threshold = 0.5;
            cfg.replication.ewma_alpha = 0.5;
            cfg.replication.evaluate_interval_s = 1.0;
            let tasks: Vec<(f64, Task)> = (0..32)
                .map(|i| {
                    let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(0)]);
                    t.kind = TaskKind::Synthetic { cpu_s: 0.2 };
                    (i as f64, t)
                })
                .collect();
            let mut spec = SimWorkloadSpec::new(tasks);
            spec.prewarm = vec![(0, ObjectId(0))];
            SimDriver::new(cfg, spec, catalog(1, MB)).run()
        };
        let off = run(false);
        assert_eq!(off.metrics.tasks_done, 32);
        assert_eq!(off.metrics.replicas_created, 0);
        assert_eq!(off.metrics.c2c_bytes, 0, "sole holder serves everything");

        let on = run(true);
        assert_eq!(on.metrics.tasks_done, 32);
        assert_eq!(on.metrics.replicas_created, 1, "max_replicas 2 = one copy");
        assert_eq!(on.metrics.replica_bytes_staged, MB);
        assert_eq!(on.metrics.c2c_bytes, MB, "staging rides the c2c path");
        assert!(
            on.metrics.replica_hits > 0,
            "tasks must rotate onto the staged copy"
        );
        // Replication must not cost any locality: everything stays local.
        assert_eq!(on.metrics.cache_hits, 32);
        assert_eq!(on.metrics.gpfs_misses, 0);
        assert_eq!(on.metrics.peer_hits, 0);
    }

    #[test]
    fn staging_admission_defers_under_load_and_still_converges() {
        // One 64 MB object prewarmed on executor 0; sequential tasks read
        // it there (a ~1.1 s local-disk flow each). The replication
        // manager wants a second copy while task 0's read has executor
        // 0's disk at 100% — with a 0.3 budget the staging must defer
        // (foreground is never blocked), then run in the load gap after
        // the flow completes. Budget 1.0 reproduces the old unmetered
        // behavior exactly: admitted mid-read, zero deferrals.
        let run = |budget: f64| {
            let mut cfg = Config::with_nodes(4);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.replication.enabled = true;
            cfg.replication.max_replicas = 2;
            cfg.replication.demand_threshold = 0.5;
            cfg.replication.ewma_alpha = 0.5;
            cfg.replication.evaluate_interval_s = 0.5;
            cfg.transfer.staging_budget = budget;
            let tasks: Vec<(f64, Task)> = (0..6)
                .map(|i| {
                    let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(0)]);
                    t.kind = TaskKind::Synthetic { cpu_s: 0.3 };
                    (i as f64 * 2.0, t)
                })
                .collect();
            let mut spec = SimWorkloadSpec::new(tasks);
            spec.prewarm = vec![(0, ObjectId(0))];
            SimDriver::new(cfg, spec, catalog(1, 64 * MB)).run()
        };
        let off = run(1.0);
        assert_eq!(off.metrics.tasks_done, 6);
        assert_eq!(off.metrics.staging_deferred, 0, "budget 1.0 never defers");
        assert_eq!(off.metrics.replicas_created, 1);

        let on = run(0.3);
        assert_eq!(on.metrics.tasks_done, 6);
        assert!(
            on.metrics.staging_deferred > 0,
            "staging from a 100%-busy source must defer"
        );
        assert_eq!(
            on.metrics.replicas_created, 1,
            "deferred staging must eventually run in a load gap"
        );
        assert!(
            on.metrics.pool_timeline.is_empty(),
            "static pool: deferral must not require the provisioner"
        );
    }

    #[test]
    fn binary_policy_ignores_class_weights_bit_for_bit() {
        use crate::transfer::{ClassWeights, SharePolicyKind};
        // Under share_policy = binary the configured class weights must
        // be inert: every flow runs at unit weight (PR 4's behavior),
        // so two runs differing only in weights replay identically —
        // and a weighted run with *unit* weights and budget 1.0 is the
        // same computation as binary-off, bit for bit.
        let run = |policy: SharePolicyKind, weights: ClassWeights| {
            let mut cfg = Config::with_nodes(4);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.replication.enabled = true;
            cfg.replication.max_replicas = 2;
            cfg.replication.demand_threshold = 0.5;
            cfg.replication.ewma_alpha = 0.5;
            cfg.replication.evaluate_interval_s = 0.5;
            cfg.transfer.share_policy = policy;
            cfg.transfer.staging_budget = 1.0;
            cfg.transfer.class_weights = weights;
            let tasks: Vec<(f64, Task)> = (0..12)
                .map(|i| {
                    let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(0)]);
                    t.kind = TaskKind::Synthetic { cpu_s: 0.3 };
                    (i as f64 * 1.5, t)
                })
                .collect();
            let mut spec = SimWorkloadSpec::new(tasks);
            spec.prewarm = vec![(0, ObjectId(0))];
            SimDriver::new(cfg, spec, catalog(1, 32 * MB)).run()
        };
        let skew = ClassWeights {
            foreground: 1.0,
            staging: 0.01,
            prestage: 0.01,
        };
        let a = run(SharePolicyKind::Binary, ClassWeights::default());
        let b = run(SharePolicyKind::Binary, skew);
        assert_eq!(a.events, b.events, "binary must ignore class weights");
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
        assert_eq!(a.metrics.replicas_created, b.metrics.replicas_created);
        let c = run(SharePolicyKind::Weighted, ClassWeights::UNIT);
        assert_eq!(a.events, c.events, "weighted@unit == binary@1.0");
        assert!((a.makespan_s - c.makespan_s).abs() < 1e-12);
        // The skewed weighted run really throttles: same workload, same
        // replication outcome, but staging's achieved rate drops below
        // binary's while foreground work is untouched.
        let d = run(SharePolicyKind::Weighted, skew);
        assert_eq!(d.metrics.tasks_done, 12);
        if d.metrics.class_bytes[TransferClass::Staging.index()] > 0
            && a.metrics.class_bytes[TransferClass::Staging.index()] > 0
        {
            assert!(
                d.metrics.class_mean_rate_bps(TransferClass::Staging)
                    < a.metrics.class_mean_rate_bps(TransferClass::Staging),
                "weight 0.01 staging must move slower than unit-weight staging"
            );
        }
    }

    #[test]
    fn weighted_shares_protect_foreground_inflight() {
        use crate::transfer::{ClassWeights, SharePolicyKind};
        // One 64 MB object on executor 0; a staging copy of it starts
        // while a foreground task reads it locally — both contend on
        // node 0's disk-read for the whole overlap. Unweighted (binary,
        // budget 1.0) the two flows split the disk 50:50; weighted, the
        // foreground read keeps an 80% share, so tasks finish strictly
        // faster while the (slower) staging copy still lands.
        let run = |policy: SharePolicyKind| {
            let mut cfg = Config::with_nodes(4);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.replication.enabled = true;
            cfg.replication.max_replicas = 2;
            cfg.replication.demand_threshold = 0.5;
            cfg.replication.ewma_alpha = 0.5;
            cfg.replication.evaluate_interval_s = 0.5;
            cfg.transfer.share_policy = policy;
            cfg.transfer.staging_budget = 1.0; // never defer: isolate weighting
            let tasks: Vec<(f64, Task)> = (0..6)
                .map(|i| {
                    let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(0)]);
                    t.kind = TaskKind::Synthetic { cpu_s: 0.3 };
                    (i as f64 * 2.0, t)
                })
                .collect();
            let mut spec = SimWorkloadSpec::new(tasks);
            spec.prewarm = vec![(0, ObjectId(0))];
            SimDriver::new(cfg, spec, catalog(1, 64 * MB)).run()
        };
        let flat = run(SharePolicyKind::Binary);
        let mut weighted = run(SharePolicyKind::Weighted);
        assert_eq!(flat.metrics.tasks_done, 6);
        assert_eq!(weighted.metrics.tasks_done, 6);
        // Both modes converge the replica (admit-but-throttle ≠ starve).
        assert_eq!(flat.metrics.replicas_created, 1);
        assert_eq!(weighted.metrics.replicas_created, 1);
        assert_eq!(weighted.metrics.staging_deferred, 0, "budget 1.0 never defers");
        // In-flight protection: the foreground tail tightens…
        let mut flat_m = flat.metrics.clone();
        assert!(
            weighted.metrics.task_latency_p99() < flat_m.task_latency_p99() - 1e-9,
            "weighted p99 {} must beat unweighted p99 {}",
            weighted.metrics.task_latency_p99(),
            flat_m.task_latency_p99()
        );
        // …because staging's achieved rate dropped (throttled), which is
        // exactly what the per-class rate metric reads out.
        assert!(
            weighted.metrics.class_mean_rate_bps(TransferClass::Staging)
                < flat.metrics.class_mean_rate_bps(TransferClass::Staging)
        );
        assert!(
            weighted.metrics.class_bytes[TransferClass::Staging.index()]
                >= flat.metrics.class_bytes[TransferClass::Staging.index()],
            "throttling must not reduce the bytes replication moves"
        );
    }

    #[test]
    fn chord_charges_index_updates_central_does_not() {
        use crate::index::IndexBackend;
        let run = |backend: IndexBackend| {
            let mut cfg = Config::with_nodes(8);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.index.backend = backend;
            let tasks: Vec<(f64, Task)> = (0..32)
                .map(|i| {
                    (
                        i as f64 * 0.5,
                        Task::with_inputs(TaskId(i), vec![ObjectId(i % 8)]),
                    )
                })
                .collect();
            SimDriver::new(cfg, SimWorkloadSpec::new(tasks), catalog(8, MB)).run()
        };
        let central = run(IndexBackend::Central);
        let chord = run(IndexBackend::Chord);
        // Cold fetches insert into the index at completion: on chord
        // every insert routes to its ring owner and is billed.
        assert_eq!(central.metrics.index_update_msgs, 0, "central updates are free");
        assert!(
            chord.metrics.index_update_msgs > 0,
            "chord cache inserts must charge routed update messages"
        );
        // Placement (and the data plane) stays backend-invariant.
        assert_eq!(central.metrics.cache_hits, chord.metrics.cache_hits);
        assert_eq!(central.metrics.gpfs_misses, chord.metrics.gpfs_misses);
    }

    #[test]
    fn replica_teardown_frees_copies_when_demand_decays() {
        // Phase 1 hammers object 0 (prewarmed on executor 0) so the
        // manager stages extra copies; phase 2 is a trickle of unrelated
        // tasks that keeps the run (and its ReplTicks) alive while object
        // 0's demand EWMA decays below the release threshold — the
        // manager must then actively drop the surplus copies instead of
        // waiting for cache pressure.
        let mut cfg = Config::with_nodes(4);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.replication.enabled = true;
        cfg.replication.max_replicas = 3;
        cfg.replication.demand_threshold = 0.5;
        cfg.replication.release_threshold = 0.3;
        cfg.replication.ewma_alpha = 0.5;
        cfg.replication.evaluate_interval_s = 1.0;
        let mut tasks: Vec<(f64, Task)> = (0..24)
            .map(|i| {
                let mut t = Task::with_inputs(TaskId(i), vec![ObjectId(0)]);
                t.kind = TaskKind::Synthetic { cpu_s: 0.2 };
                (i as f64 * 0.5, t)
            })
            .collect();
        for i in 0..10u64 {
            let mut t = Task::with_inputs(TaskId(100 + i), vec![ObjectId(1 + i)]);
            t.kind = TaskKind::Synthetic { cpu_s: 0.1 };
            tasks.push((20.0 + i as f64 * 3.0, t));
        }
        let mut spec = SimWorkloadSpec::new(tasks);
        spec.prewarm = vec![(0, ObjectId(0))];
        let out = SimDriver::new(cfg, spec, catalog(16, MB)).run();
        assert_eq!(out.metrics.tasks_done, 34);
        assert!(out.metrics.replicas_created > 0, "the burst must replicate");
        assert!(
            out.metrics.replicas_dropped > 0,
            "decayed demand must tear surplus copies down"
        );
        assert!(out.metrics.replicas_dropped <= out.metrics.replicas_created);
    }

    #[test]
    fn stale_hints_reresolve_at_the_executor_and_charge_lookups() {
        // first-cache-available ships hints but picks executors blindly.
        // Executor 1's only cache slot holds obj0; T1 running there
        // evicts it (capacity = one object) while T2 — dispatched with a
        // hint pointing at executor 1 — is still in flight. T2's fetch
        // finds every hinted copy gone, re-resolves at the executor
        // (charged through DataIndex::lookup_cost), finds nothing fresh,
        // and falls through to persistent storage.
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::FirstCacheAvailable;
        cfg.cache.capacity_bytes = MB;
        let mut t0 = Task::with_inputs(TaskId(0), vec![]);
        t0.kind = TaskKind::Synthetic { cpu_s: 0.05 };
        let mut t1 = Task::with_inputs(TaskId(1), vec![ObjectId(2)]);
        t1.kind = TaskKind::Synthetic { cpu_s: 0.1 };
        let t2 = Task::with_inputs(TaskId(2), vec![ObjectId(0)]);
        let mut spec = SimWorkloadSpec::new(vec![(0.0, t0), (0.0, t1), (0.0, t2)]);
        spec.prewarm = vec![(1, ObjectId(0))];
        let out = SimDriver::new(cfg, spec, catalog(3, MB)).run();
        assert_eq!(out.metrics.tasks_done, 3);
        // Two dispatch-side lookups (T1, T2 — T0 has no inputs) plus the
        // executor-side stale-hint re-resolution.
        assert_eq!(out.metrics.index_lookups, 3, "stale re-resolve must be charged");
        assert_eq!(out.metrics.gpfs_misses, 2, "obj2 cold, obj0 re-fetched");
        assert_eq!(out.metrics.peer_hits, 0, "the hinted copy was gone");
    }

    #[test]
    fn replication_is_backend_invariant_and_deterministic() {
        use crate::index::IndexBackend;
        use crate::workloads::bursty::{self, BurstSpec, DemandShape};
        let run = |backend: IndexBackend| {
            let mut cfg = elastic_cfg(6);
            cfg.index.backend = backend;
            cfg.index.hop_latency_s = 0.0;
            cfg.index.hop_proc_s = 0.0;
            cfg.index.central_lookup_s = 0.0;
            cfg.replication.enabled = true;
            cfg.replication.max_replicas = 4;
            cfg.replication.demand_threshold = 1.0;
            cfg.replication.evaluate_interval_s = 2.0;
            cfg.replication.prestage_top_k = 4;
            let w = bursty::generate(
                &BurstSpec {
                    shape: DemandShape::Square,
                    tasks: 160,
                    objects: 8,
                    object_bytes: MB,
                    period_s: 120.0,
                    base_rate: 0.0,
                    peak_rate: 2.5,
                    duty: 0.3,
                    task_cpu_s: 1.0,
                },
                9,
            );
            SimDriver::new(cfg, w.spec, w.catalog).run()
        };
        let a = run(IndexBackend::Chord);
        let b = run(IndexBackend::Chord);
        assert_eq!(a.events, b.events, "replicated chord runs must replay");
        let c = run(IndexBackend::Central);
        assert_eq!(a.metrics.tasks_done, 160);
        assert_eq!(a.metrics.tasks_done, c.metrics.tasks_done);
        // Placement — and therefore replication decisions, which are a
        // function of placement-derived demand — is backend-invariant.
        assert_eq!(a.metrics.cache_hits, c.metrics.cache_hits);
        assert_eq!(a.metrics.peer_hits, c.metrics.peer_hits);
        assert_eq!(a.metrics.gpfs_misses, c.metrics.gpfs_misses);
        assert_eq!(a.metrics.replicas_created, c.metrics.replicas_created);
        assert_eq!(a.metrics.replica_hits, c.metrics.replica_hits);
        assert!(a.metrics.replicas_created > 0, "bursty hot set must replicate");
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let cfg = Config::with_nodes(8);
            let spec = SimWorkloadSpec::new(read_tasks(64));
            SimDriver::new(cfg, spec, catalog(64, MB)).run()
        };
        let a = run();
        let b = run();
        assert_eq!(a.metrics.tasks_done, b.metrics.tasks_done);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn sharded_dispatch_drains_batches_and_replays() {
        // A 4-shard run over 8 executors (2 per shard) must retire the
        // whole workload, replay deterministically (per-shard wake-ups
        // included), and account its dispatch batches.
        let run = |shards: usize| {
            let mut cfg = Config::with_nodes(8);
            cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
            cfg.coordinator.shards = shards;
            let tasks: Vec<(f64, Task)> = (0..96)
                .map(|i| {
                    (
                        i as f64 * 0.25,
                        Task::with_inputs(TaskId(i), vec![ObjectId(i % 12)]),
                    )
                })
                .collect();
            SimDriver::new(cfg, SimWorkloadSpec::new(tasks), catalog(12, MB)).run()
        };
        let a = run(4);
        let b = run(4);
        assert_eq!(a.events, b.events, "sharded runs must replay");
        assert_eq!(a.metrics.tasks_done, 96);
        assert_eq!(a.metrics.tasks_dispatched, 96);
        assert!(a.metrics.dispatch_batches > 0, "batches must be accounted");
        assert_eq!(a.metrics.shard_queue_depths.len(), 4);
        assert!(
            a.metrics.shard_queue_depths.iter().all(|&d| d == 0),
            "all shard queues drain by quiesce"
        );
        let single = run(1);
        assert_eq!(single.metrics.tasks_done, 96);
        assert_eq!(single.metrics.dispatch_steals, 0, "one shard cannot steal");
    }

    /// A bursty-demand config with an elastic pool.
    fn elastic_cfg(nodes: usize) -> Config {
        let mut cfg = Config::with_nodes(nodes);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = crate::provisioner::AllocationPolicy::Adaptive;
        cfg.provisioner.min_executors = 1;
        cfg.provisioner.max_executors = nodes;
        cfg.provisioner.allocation_latency_s = 20.0;
        cfg.provisioner.idle_release_s = 15.0;
        cfg.provisioner.poll_interval_s = 2.0;
        cfg.provisioner.queue_per_executor = 2;
        cfg
    }

    #[test]
    fn elastic_pool_grows_under_burst_and_shrinks_in_the_lull() {
        use crate::workloads::bursty::{self, BurstSpec, DemandShape};
        let cfg = elastic_cfg(8);
        let w = bursty::generate(
            &BurstSpec {
                shape: DemandShape::Square,
                // 2 tasks/s over 60 s-long bursts: 120 tasks in burst one,
                // a 140 s lull, 120 more in burst two — so the idle
                // timeout (15 s) fires mid-run.
                tasks: 240,
                objects: 32,
                object_bytes: MB,
                period_s: 200.0,
                base_rate: 0.0,
                peak_rate: 2.0,
                duty: 0.3,
                task_cpu_s: 1.0,
            },
            11,
        );
        let out = SimDriver::new(cfg.clone(), w.spec, w.catalog).run();
        assert_eq!(out.metrics.tasks_done, 240, "elastic run must drain");
        assert!(
            out.metrics.executors_joined > 0,
            "pool must grow beyond the warm floor"
        );
        assert!(
            out.metrics.executors_released > 0,
            "pool must shrink during the 140 s lull (idle timeout 15 s)"
        );
        assert!(out.metrics.peak_executors > cfg.provisioner.min_executors);
        assert!(!out.metrics.pool_timeline.is_empty());
        for s in &out.metrics.pool_timeline {
            assert!(
                s.allocated + s.pending <= cfg.provisioner.max_executors,
                "pool {} + pending {} exceeded max {}",
                s.allocated,
                s.pending,
                cfg.provisioner.max_executors
            );
        }
        // The mid-run churn costs idle executor-seconds and allocation
        // waiting — both must be accounted.
        assert!(out.metrics.idle_exec_s > 0.0);
        assert!(out.metrics.alloc_wait_s > 0.0);
        assert!(out.metrics.alloc_requests > 0);
    }

    #[test]
    fn elastic_pool_is_deterministic_and_chord_survives_churn() {
        use crate::index::IndexBackend;
        use crate::workloads::bursty::{self, BurstSpec, DemandShape};
        let run = |backend: IndexBackend| {
            let mut cfg = elastic_cfg(6);
            cfg.index.backend = backend;
            // Zero the chord cost model: placement AND timing must then
            // match central exactly, so the provisioning feedback loop
            // (queue peaks sampled at tick times) cannot diverge.
            cfg.index.hop_latency_s = 0.0;
            cfg.index.hop_proc_s = 0.0;
            cfg.index.central_lookup_s = 0.0;
            let w = bursty::generate(
                &BurstSpec {
                    shape: DemandShape::Sine,
                    tasks: 120,
                    objects: 16,
                    object_bytes: MB,
                    period_s: 120.0,
                    base_rate: 0.2,
                    peak_rate: 3.0,
                    duty: 0.3,
                    task_cpu_s: 1.0,
                },
                5,
            );
            SimDriver::new(cfg, w.spec, w.catalog).run()
        };
        let a = run(IndexBackend::Chord);
        let b = run(IndexBackend::Chord);
        assert_eq!(a.events, b.events, "elastic chord runs must replay");
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-9);
        assert_eq!(a.metrics.tasks_done, 120);
        // Placement is backend-invariant even with mid-run membership
        // churn (the ring rebuilds on every join/leave).
        let c = run(IndexBackend::Central);
        assert_eq!(a.metrics.tasks_done, c.metrics.tasks_done);
        assert_eq!(a.metrics.cache_hits, c.metrics.cache_hits);
        assert_eq!(a.metrics.gpfs_misses, c.metrics.gpfs_misses);
        assert_eq!(a.metrics.executors_joined, c.metrics.executors_joined);
        assert_eq!(a.metrics.executors_released, c.metrics.executors_released);
        assert!(a.metrics.index_hops > 0, "chord must route mid-churn too");
        // Churn charges chord's control plane; central stays free.
        assert!(
            a.metrics.stabilization_msgs > 0,
            "chord membership churn must charge stabilization messages"
        );
        assert_eq!(c.metrics.stabilization_msgs, 0, "central has no control plane");
    }

    #[test]
    fn elastic_pool_starting_from_zero_still_drains() {
        let mut cfg = elastic_cfg(4);
        cfg.provisioner.min_executors = 0;
        cfg.provisioner.allocation_latency_s = 10.0;
        let spec = SimWorkloadSpec::new(read_tasks(20));
        let out = SimDriver::new(cfg, spec, catalog(20, MB)).run();
        assert_eq!(out.metrics.tasks_done, 20);
        assert!(out.metrics.executors_joined > 0);
        // Nothing could run before the first allocation landed.
        assert!(out.makespan_s >= 0.0);
        assert!(out.metrics.t_start >= 10.0, "first dispatch waits for the grant");
    }

    #[test]
    fn one_site_federation_reproduces_the_flat_config_bit_for_bit() {
        use crate::config::SiteConfig;
        // One [[site]] covering every node must be a pure passthrough:
        // no WAN fabric, no routing draws, no extra cost — the exact
        // same computation as the pre-federation flat config.
        let run = |federated: bool| {
            let mut cfg = elastic_cfg(4);
            cfg.replication.enabled = true;
            cfg.replication.evaluate_interval_s = 0.5;
            if federated {
                cfg.federation.sites.push(SiteConfig {
                    nodes: 4,
                    ..SiteConfig::default()
                });
            }
            let spec = SimWorkloadSpec::new(read_tasks(40));
            SimDriver::new(cfg, spec, catalog(40, MB)).run()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(a.events, b.events, "one-site federation must replay the flat run");
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
        assert_eq!(a.metrics.tasks_done, b.metrics.tasks_done);
        assert_eq!(a.metrics.cache_hits, b.metrics.cache_hits);
        assert_eq!(a.metrics.executors_joined, b.metrics.executors_joined);
        assert_eq!(b.metrics.wan_bytes, 0);
        assert_eq!(b.metrics.cross_site_tasks, 0);
    }

    #[test]
    fn two_sites_meter_wan_traffic_and_cross_site_placement() {
        use crate::federation::PlacementMode;
        let run = |mode: PlacementMode| {
            let mut cfg = Config::with_nodes(8);
            cfg.split_into_sites(2);
            cfg.federation.placement = mode;
            cfg.federation.skew = 0.0; // origins uniform across sites
            let spec = SimWorkloadSpec::new(read_tasks(40));
            SimDriver::new(cfg, spec, catalog(40, 4 * MB)).run()
        };
        let random = run(PlacementMode::RandomSite);
        assert_eq!(random.metrics.tasks_done, 40);
        assert!(
            random.metrics.wan_bytes > 0,
            "random placement runs tasks at site 1, whose GPFS reads cross the WAN"
        );
        let affinity = run(PlacementMode::Affinity);
        assert_eq!(affinity.metrics.tasks_done, 40);
        assert!(
            affinity.metrics.cross_site_tasks > 0,
            "uniform origins + cold caches pull site-1 work to the GPFS home site"
        );
    }

    #[test]
    fn ship_data_pulls_from_a_remote_site_cache_over_the_wan() {
        use crate::federation::PlacementMode;
        let mut cfg = Config::with_nodes(8);
        cfg.split_into_sites(2);
        cfg.federation.placement = PlacementMode::AlwaysHome;
        cfg.federation.skew = 1.0; // every origin (hence placement) is site 0
        let tasks: Vec<(f64, Task)> = (0..4)
            .map(|i| (i as f64 * 0.5, Task::with_inputs(TaskId(i), vec![ObjectId(0)])))
            .collect();
        let mut spec = SimWorkloadSpec::new(tasks);
        spec.prewarm = vec![(6, ObjectId(0))]; // the only cached copy: site 1
        let out = SimDriver::new(cfg, spec, catalog(1, 16 * MB)).run();
        assert_eq!(out.metrics.tasks_done, 4);
        assert!(
            out.metrics.c2c_bytes > 0,
            "the global directory must surface the site-1 copy as a peer fetch"
        );
        assert!(
            out.metrics.wan_bytes > 0,
            "a cross-site peer fetch traverses the WAN"
        );
        assert_eq!(
            out.metrics.gpfs_bytes, 0,
            "no task should fall back to a GPFS data read"
        );
    }

    #[test]
    fn per_site_elastic_pools_sample_their_own_timelines() {
        let mut cfg = elastic_cfg(8);
        cfg.split_into_sites(2);
        let spec = SimWorkloadSpec::new(read_tasks(40));
        let out = SimDriver::new(cfg, spec, catalog(40, MB)).run();
        assert_eq!(out.metrics.tasks_done, 40);
        assert_eq!(out.metrics.site_pool_timeline.len(), 2, "one timeline per site");
        assert!(
            out.metrics.site_pool_timeline.iter().all(|t| !t.is_empty()),
            "both site pools tick independently"
        );
        assert!(
            !out.metrics.pool_timeline.is_empty(),
            "the combined timeline keeps feeding the legacy figures"
        );
    }
}
