//! Live execution driver: real threads, real files, real compute.
//!
//! The same shard layer as the simulator, but executors are OS threads
//! doing real I/O against a directory tree ("persistent storage"), real
//! per-executor cache directories, real gzip decoding
//! ([`crate::util::gzip`]), and real PJRT stacking compute through
//! [`crate::runtime::PjrtEngine`] (when the `pjrt` feature is on).
//!
//! ## Channel topology
//!
//! Every executor is a thread with an inbox
//! (`mpsc::Sender<ExecMsg>`). What changes with `--shards` is who owns
//! the *other* end of the report path:
//!
//! * **`--shards 1` — single coordinator loop.** One loop owns the
//!   [`ShardedCore`], every executor reports into one shared channel,
//!   and the loop interleaves provisioning, replication, dispatch, and
//!   report application. This is the pre-shard-thread topology,
//!   preserved byte-for-byte for static single-shard runs.
//! * **`--shards >= 2` — per-shard dispatcher threads.** The core is
//!   decomposed into a [`ShardPlane`] and each shard gets its own
//!   long-lived dispatcher thread with a *dedicated* channel
//!   ([`ShardMsg`]): executor `e` sends its `Report`s to shard
//!   `e % shards`'s channel, so dispatch decisions, cache-event
//!   application, and index updates for shard *s* run concurrently
//!   with shard *t*. Each shard loop also owns the inbox senders of
//!   its executors, its own [`LiveTransferPlane`] admission state and
//!   replication cadence (replica managers are per-shard), and a
//!   shard-local [`Metrics`].
//!
//! ## Cross-thread steal protocol (`--shards >= 2`)
//!
//! A starved shard loop (idle slots, empty ready queue) steals through
//! [`ShardPlane::steal_into`]: the victim is picked from lock-free
//! published ready-length hints, and the victim's core is only ever
//! `try_lock`ed while the thief holds its own — contention means "no
//! steal this round", so no thread blocks on a second shard lock and
//! no deadlock cycle can form. Batch size adapts via
//! [`crate::coordinator::StealSizer`].
//!
//! ## Churn handoff (`--shards >= 2`)
//!
//! A thin control loop (the caller's thread) handles only membership
//! churn, QoS harvest, and the metrics merge. It runs the DRP on
//! wall-clock time and talks to shard loops through their channels:
//! a granted executor `e` is spawned by the control loop and handed to
//! shard `e % shards` with [`ShardCtl::Register`] (the shard loop
//! registers it with its core slice and adopts the inbox); a release
//! is *proposed* with [`ShardCtl::Release`] — the owning shard loop
//! re-validates quiescence (a dispatch may have raced the control
//! loop's observation) and acks the outcome, and only an `ok` ack lets
//! the control loop join the thread, tear down the cache directory,
//! and bill the cluster. Completion is tracked by a shared atomic; the
//! loop that retires the last task sends a `Drained` ack so the
//! control loop wakes promptly.
//!
//! Replication `Stage` messages pass through a per-shard
//! [`LiveTransferPlane`] ([`crate::transfer`]) that defers them while
//! the source executor's egress runs over the staging budget — measured
//! by real byte-level accounting against the *shared*
//! [`crate::transfer::live::EgressLedger`] — re-admits them as it
//! drains, and under the weighted share policy paces the staging copies
//! themselves with a per-source token bucket
//! ([`crate::transfer::live::StagingPacer`]); `Drop` messages actively
//! release decayed replicas from cache directories.
//!
//! PJRT compute runs on a dedicated **compute service** thread (the
//! `xla` crate's client is not `Send`/`Sync` — and a single shared
//! accelerator queue is how a real deployment looks anyway).
//!
//! Python is never involved: executors load AOT artifacts only.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::store::{CacheEvent, DataCache};
use crate::config::Config;
use crate::coordinator::metrics::{ByteSource, Metrics};
use crate::coordinator::sharded::{ShardPlane, ShardStats, ShardedCore, StealSizer};
use crate::coordinator::task::{Task, TaskId, TaskKind};
use crate::error::{Error, Result};
use crate::index::central::ExecutorId;
use crate::index::DataIndex;
use crate::provisioner::{ClusterProvider, ProvisionAction, Provisioner};
use crate::replication::ReplicaDirective;
use crate::runtime::{PjrtEngine, StackRequest};
use crate::scheduler::decision::LocationHints;
use crate::storage::live::{pixels_of, read_object_file, LiveCacheDir, LiveStore};
use crate::storage::object::{Catalog, DataFormat, ObjectId};
use crate::transfer::live::{
    copy_into_cache, copy_into_cache_paced, EgressGuard, EgressLedger, LiveTransferPlane,
    StagingPacer,
};
use crate::transfer::{Admission, TransferClass, TransferPlane, TransferRequest};
use crate::workloads::sky;

/// Message to an executor thread.
enum ExecMsg {
    Run {
        task: Task,
        hints: LocationHints,
        t_submit: Instant,
    },
    /// Replication staging: copy `obj` from executor `src`'s cache dir
    /// (abandoned if the source copy vanished) into this executor's
    /// cache, paced at `class`'s share of the source's egress under the
    /// weighted policy.
    Stage {
        obj: ObjectId,
        src: ExecutorId,
        class: TransferClass,
    },
    /// Replica teardown: demand decayed, actively evict `obj` from this
    /// executor's cache (file + cache entry) and report the eviction.
    Drop { obj: ObjectId },
    Shutdown,
}

/// Completion report from an executor thread.
struct Completion {
    exec: ExecutorId,
    task: TaskId,
    events: Vec<CacheEvent>,
    /// How each input was resolved: (source, bytes, object).
    resolutions: Vec<(ByteSource, u64, ObjectId)>,
    /// Timed data movements this task performed: (class, bytes, secs) —
    /// per-class byte/rate accounting for the metrics.
    xfers: Vec<(TransferClass, u64, f64)>,
    /// Inputs whose hints were all stale (§3.2.2): the coordinator
    /// charges one executor-side index lookup per entry.
    stale: Vec<ObjectId>,
    t_submit: Instant,
    t_dispatch: Instant,
    error: Option<String>,
}

/// Outcome of a replication staging request.
struct StageReport {
    exec: ExecutorId,
    obj: ObjectId,
    /// The transfer class the copy ran under (staging or prestage).
    class: TransferClass,
    /// Bytes copied (0 if the stage was skipped).
    bytes: u64,
    /// Wall seconds the copy took (pacing included).
    elapsed_s: f64,
    /// Whether a new cache entry was actually created.
    created: bool,
    events: Vec<CacheEvent>,
}

/// Outcome of a replica-teardown request.
struct DropReport {
    exec: ExecutorId,
    obj: ObjectId,
    /// The eviction event (empty if the copy was already gone).
    events: Vec<CacheEvent>,
}

/// Everything an executor thread can report back.
enum Report {
    Done(Completion),
    Staged(StageReport),
    Dropped(DropReport),
}

/// Message into a coordinator/shard dispatcher loop. Executor reports
/// share the channel with control handoffs so a single `recv` wakes a
/// shard loop for either kind of event.
enum ShardMsg {
    Report(Report),
    Ctl(ShardCtl),
}

/// Control handoff from the thin control loop to a shard dispatcher
/// loop (`--shards >= 2` only; the single-loop path never sends these).
enum ShardCtl {
    /// A provisioning grant landed: adopt executor `e` — register it
    /// with this shard's core slice and dispatch to `inbox` from now on.
    Register {
        e: ExecutorId,
        capacity: usize,
        inbox: mpsc::Sender<ExecMsg>,
    },
    /// The provisioner wants `e` released. The owning loop re-validates
    /// quiescence (a dispatch may have raced the control loop's
    /// observation), shuts the executor down and deregisters it on
    /// success, and always acks the outcome.
    Release { e: ExecutorId },
    /// Run over (or aborted): shut down owned executors and exit.
    Shutdown,
}

/// Shard-loop → control-loop acknowledgements.
enum CtlAck {
    /// Outcome of a [`ShardCtl::Release`] handoff. `ok` means the
    /// executor was quiescent, shut down, and deregistered — the
    /// control loop may now join its thread, tear down its cache
    /// directory, and bill the cluster. A refusal means a dispatch won
    /// the race; the release is simply dropped, as on the single loop.
    Released { e: ExecutorId, ok: bool },
    /// Sent by the shard loop that retired the last task of the batch,
    /// so the control loop wakes promptly instead of on its backstop.
    Drained,
}

/// Request to the compute-service thread.
enum ComputeMsg {
    Stack(StackRequest, mpsc::Sender<Result<Vec<f32>>>),
    /// (ra, dec, ra0, dec0, scale) — the paper's radec2xy phase.
    Radec(Vec<f32>, Vec<f32>, f32, f32, f32, mpsc::Sender<Result<Vec<(f32, f32)>>>),
    Shutdown,
}

/// Handle to the compute service.
#[derive(Clone)]
pub struct ComputeClient {
    tx: mpsc::Sender<ComputeMsg>,
}

impl ComputeClient {
    /// Execute one stacking synchronously.
    pub fn stack(&self, req: StackRequest) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(ComputeMsg::Stack(req, tx))
            .map_err(|_| Error::Runtime("compute service gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("compute service dropped reply".into()))?
    }

    /// Convert (ra, dec) coordinates to pixel (x, y) synchronously.
    pub fn radec2xy(
        &self,
        ra: Vec<f32>,
        dec: Vec<f32>,
        ra0: f32,
        dec0: f32,
        scale: f32,
    ) -> Result<Vec<(f32, f32)>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(ComputeMsg::Radec(ra, dec, ra0, dec0, scale, tx))
            .map_err(|_| Error::Runtime("compute service gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("compute service dropped reply".into()))?
    }
}

/// Spawn the compute service. The PJRT client is not `Send`, so the
/// engine is constructed *inside* the service thread from the artifacts
/// directory; construction errors surface through the handshake channel.
fn spawn_compute(
    artifacts: PathBuf,
) -> Result<(ComputeClient, mpsc::Sender<ComputeMsg>, JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<ComputeMsg>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
    let handle = std::thread::spawn(move || {
        let engine = match PjrtEngine::load(&artifacts) {
            Ok(e) => {
                let _ = ready_tx.send(Ok(e.platform()));
                e
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e));
                return;
            }
        };
        while let Ok(msg) = rx.recv() {
            match msg {
                ComputeMsg::Stack(req, reply) => {
                    let _ = reply.send(engine.stack(&req));
                }
                ComputeMsg::Radec(ra, dec, ra0, dec0, scale, reply) => {
                    let _ = reply.send(engine.radec2xy(&ra, &dec, ra0, dec0, scale));
                }
                ComputeMsg::Shutdown => break,
            }
        }
    });
    match ready_rx.recv() {
        Ok(Ok(_platform)) => Ok((ComputeClient { tx: tx.clone() }, tx, handle)),
        Ok(Err(e)) => {
            let _ = handle.join();
            Err(e)
        }
        Err(_) => Err(Error::Runtime("compute service failed to start".into())),
    }
}

use super::{Driver, RunOutcome};

/// A [`LiveCluster`] with its task batch bound, so a live run can be
/// launched through the common [`Driver`] interface.
pub struct LiveDriver {
    /// The cluster to run on.
    pub cluster: LiveCluster,
    /// The batch to run to completion.
    pub tasks: Vec<Task>,
}

impl Driver for LiveDriver {
    fn run(self) -> Result<RunOutcome> {
        self.cluster.run(self.tasks)
    }
}

/// A live mini-cluster.
pub struct LiveCluster {
    cfg: Config,
    store: LiveStore,
    workdir: PathBuf,
    artifacts: Option<PathBuf>,
}

impl LiveCluster {
    /// Create a cluster over an existing populated store. `workdir` holds
    /// the executor cache directories. `artifacts` (the AOT directory)
    /// enables real PJRT stacking for `TaskKind::Stack` tasks; synthetic
    /// tasks run without it.
    pub fn new(
        cfg: Config,
        store: LiveStore,
        workdir: PathBuf,
        artifacts: Option<PathBuf>,
    ) -> LiveCluster {
        LiveCluster {
            cfg,
            store,
            workdir,
            artifacts,
        }
    }

    /// Run a batch of tasks to completion.
    ///
    /// With `provisioner.enabled` the executor pool is elastic: threads
    /// are spawned when the cluster grants an allocation (after the
    /// configured allocation latency, on wall-clock time) and reaped —
    /// shutdown message, deregistration, cache-directory teardown — when
    /// the provisioner releases an idle executor.
    pub fn run(self, tasks: Vec<Task>) -> Result<RunOutcome> {
        // `--shards >= 2`: per-shard dispatcher threads (see module
        // docs). The single-loop path below is kept verbatim for
        // `--shards 1`, so static single-shard runs reproduce the
        // pre-shard-thread summary metrics exactly.
        if self.cfg.coordinator.shards.max(1) >= 2 {
            return self.run_sharded(tasks);
        }
        let LiveCluster {
            cfg,
            store,
            workdir,
            artifacts,
        } = self;
        let n_exec = cfg.testbed.nodes;
        let format = store.format();
        let capacity = (cfg.testbed.cpus_per_node * cfg.scheduler.tasks_per_cpu).max(1);
        let elastic = cfg.provisioner.enabled;

        // Catalog from the store (sizes as stored).
        let mut catalog = Catalog::new();
        for id in store.catalog().ids() {
            catalog.insert(id, store.catalog().size(id).unwrap());
        }

        // The live coordinator threads the same pluggable index backend
        // as the simulator: lookups resolve instantly (the overlay is a
        // cost model, not real RPCs), but the charged cost lands in the
        // run metrics so live and simulated accounting stay comparable.
        let shards = cfg.coordinator.shards.max(1);
        let indexes = (0..shards)
            .map(|_| crate::index::build(&cfg.index, cfg.seed))
            .collect();
        let mut core = ShardedCore::with_indexes(&cfg.scheduler, catalog, indexes);

        // Compute service (if stacking compute is wanted).
        let compute = match artifacts {
            Some(dir) => Some(spawn_compute(dir)?),
            None => None,
        };
        let compute_client = compute.as_ref().map(|(c, _, _)| c.clone());

        // The metered transfer plane's live substrate: per-source
        // byte-level egress accounting shared by every executor thread
        // (the coordinator reads utilization from it for admission) and
        // the token-bucket pacer that throttles background copies under
        // the weighted share policy. Egress capacity is the tighter of
        // NIC and local-disk read — the same legs the sim's utilization
        // meters.
        let egress_bps = cfg.testbed.nic_bps.min(cfg.local_disk.read_bps);
        let ledger = Arc::new(EgressLedger::new(n_exec, egress_bps));
        let pacer = Arc::new(StagingPacer::new(n_exec, egress_bps, &cfg.transfer));

        // Executor plumbing: a slot per provisionable node. `inboxes[e]`
        // is `Some` exactly while executor `e`'s thread is alive.
        let (done_tx, done_rx) = mpsc::channel::<ShardMsg>();
        let mut inboxes: Vec<Option<mpsc::Sender<ExecMsg>>> = (0..n_exec).map(|_| None).collect();
        let mut handles: Vec<(ExecutorId, JoinHandle<()>)> = Vec::new();
        let cache_roots: Vec<PathBuf> =
            (0..n_exec).map(|e| workdir.join(format!("cache{e}"))).collect();
        let store_root = store.path_of(ObjectId(0)).parent().unwrap().to_path_buf();
        let spawn_exec = |e: ExecutorId,
                          done: mpsc::Sender<ShardMsg>|
         -> Result<(mpsc::Sender<ExecMsg>, JoinHandle<()>)> {
            let (tx, rx) = mpsc::channel::<ExecMsg>();
            let ctx = ExecutorCtx {
                exec: e,
                cfg: cfg.clone(),
                format,
                store_root: store_root.clone(),
                cache_dir: LiveCacheDir::create(&cache_roots[e])?,
                cache_roots: cache_roots.clone(),
                cache: DataCache::new(
                    cfg.cache.capacity_bytes,
                    cfg.cache.policy,
                    cfg.seed ^ e as u64,
                ),
                compute: compute_client.clone(),
                ledger: ledger.clone(),
                pacer: pacer.clone(),
                done,
            };
            Ok((tx, std::thread::spawn(move || executor_loop(ctx, rx))))
        };

        // Provisioning state (elastic runs).
        let mut drp = Provisioner::new(cfg.provisioner.clone());
        let mut cluster = ClusterProvider::new(n_exec, cfg.provisioner.allocation_latency_s);
        let mut pending_allocs: Vec<(f64, Vec<usize>)> = Vec::new(); // (ready_at_s, nodes)
        let poll_s = cfg.provisioner.poll_interval_s.max(0.005);
        let mut last_eval = 0.0f64;
        let mut metrics = Metrics::new();
        metrics.t_start = 0.0;

        if elastic {
            if n_exec == 0 || cfg.provisioner.max_executors == 0 {
                return Err(Error::Config(
                    "elastic pool needs at least one allocatable executor \
                     (testbed.nodes and provisioner.max_executors must be >= 1)"
                        .into(),
                ));
            }
            // Warm floor: min_executors come up instantly, before t=0.
            let warm = cfg.provisioner.min_executors.min(n_exec);
            if warm > 0 {
                let grant = cluster.allocate(0.0, warm);
                for &e in &grant.nodes {
                    core.register_executor_with(e, capacity);
                    let (tx, h) = spawn_exec(e, done_tx.clone())?;
                    inboxes[e] = Some(tx);
                    handles.push((e, h));
                }
                drp.on_allocated(grant.nodes.len());
            }
        } else {
            for e in 0..n_exec {
                core.register_executor_with(e, capacity);
                let (tx, h) = spawn_exec(e, done_tx.clone())?;
                inboxes[e] = Some(tx);
                handles.push((e, h));
            }
        }
        // In a static pool every live sender now sits in an executor
        // thread, so a fully-dead pool disconnects `done_rx` and turns
        // into a clean error (the pre-elastic behavior). An elastic pool
        // must keep one sender for future spawns — an *empty* pool is a
        // legitimate transient there, not a death.
        let done_tx = if elastic {
            Some(done_tx)
        } else {
            drop(done_tx);
            None
        };

        // Demand-driven replication: enabled after the initial pool
        // registered (the warm pool is membership, not a join wave), and
        // only when the policy caches at all.
        let replicating = cfg.replication.enabled && cfg.scheduler.policy.is_data_aware();
        if replicating {
            core.enable_replication(&cfg.replication);
        }
        let repl_poll_s = cfg.replication.evaluate_interval_s.max(0.005);
        let mut last_repl = 0.0f64;
        // Manager-staged (executor, object) entries, for replica-hit
        // accounting; scrubbed on eviction and release.
        let mut staged: HashSet<(ExecutorId, ObjectId)> = HashSet::new();
        // Metered transfer plane: Stage messages are admission-controlled
        // against the source executor's measured egress backlog (the
        // shared byte ledger), deferred while it runs over budget and
        // re-admitted as it drains.
        let mut plane = LiveTransferPlane::new(&cfg.transfer, ledger.clone());

        // Coordinator loop.
        let t0 = Instant::now();
        let total = tasks.len() as u64;
        let mut submit_times: HashMap<TaskId, Instant> = HashMap::new();
        for t in tasks {
            submit_times.insert(t.id, Instant::now());
            core.submit(t);
        }
        let mut sample_checksums = Vec::new();
        let mut completed = 0u64;
        let mut first_error: Option<String> = None;

        while completed < total {
            if elastic {
                let now_s = t0.elapsed().as_secs_f64();
                // Deliver allocation grants whose latency elapsed: the
                // nodes register with the core (and index) and their
                // threads start pulling work.
                let mut i = 0;
                while i < pending_allocs.len() {
                    if pending_allocs[i].0 <= now_s {
                        let (_, nodes) = pending_allocs.swap_remove(i);
                        let n = nodes.len();
                        let done = done_tx.as_ref().expect("elastic keeps a sender");
                        for e in nodes {
                            core.register_executor_with(e, capacity);
                            let (tx, h) = spawn_exec(e, done.clone())?;
                            inboxes[e] = Some(tx);
                            handles.push((e, h));
                        }
                        drp.on_allocated(n);
                        metrics.executors_joined += n as u64;
                        metrics.peak_executors =
                            metrics.peak_executors.max(core.executor_count());
                    } else {
                        i += 1;
                    }
                }
                // A thread that finished while its inbox is still open
                // died on its own (panic) — the keep-alive `done_tx`
                // means channel disconnect can no longer signal this, so
                // probe the join handles instead of hanging forever.
                for (e, h) in &handles {
                    if inboxes[*e].is_some() && h.is_finished() {
                        return Err(Error::Protocol(format!("executor {e} died unexpectedly")));
                    }
                }
                if now_s - last_eval >= poll_s {
                    let dt = now_s - last_eval;
                    last_eval = now_s;
                    let queued_now = core.queue_len();
                    let demand = core.take_queue_peak().max(queued_now);
                    let quiescent = core.quiescent_executors();
                    for &e in core.executors() {
                        if quiescent.binary_search(&e).is_ok() {
                            drp.note_idle(e, now_s);
                        } else {
                            drp.note_busy(e);
                        }
                    }
                    metrics.idle_exec_s += quiescent.len() as f64 * dt;
                    metrics.alloc_wait_s += drp.pending() as f64 * dt;
                    for action in drp.evaluate(demand, now_s) {
                        match action {
                            ProvisionAction::Allocate { count } => {
                                metrics.alloc_requests += 1;
                                let grant = cluster.allocate(now_s, count);
                                if grant.nodes.len() < count {
                                    drp.cancel_pending(count - grant.nodes.len());
                                }
                                if !grant.nodes.is_empty() {
                                    pending_allocs.push((grant.ready_at, grant.nodes));
                                }
                            }
                            ProvisionAction::Release { executors } => {
                                for e in executors {
                                    if quiescent.binary_search(&e).is_err() {
                                        continue;
                                    }
                                    // Reap: shutdown + join the thread
                                    // (it is quiescent, so the inbox recv
                                    // returns immediately), purge the
                                    // index, tear down the cache
                                    // directory. Joining here also keeps
                                    // `handles` free of finished entries
                                    // so the death probe above cannot
                                    // false-positive on a later re-join
                                    // of the same node id.
                                    if let Some(tx) = inboxes[e].take() {
                                        let _ = tx.send(ExecMsg::Shutdown);
                                    }
                                    if let Some(pos) =
                                        handles.iter().position(|(he, _)| *he == e)
                                    {
                                        let (_, h) = handles.swap_remove(pos);
                                        let _ = h.join();
                                    }
                                    let _orphans = core.deregister_executor(e);
                                    // Deferred stagings touching the
                                    // released executor are cancelled;
                                    // free the manager's in-flight slots.
                                    for req in plane.executor_released(e) {
                                        core.replication_staged(req.obj, req.dst);
                                    }
                                    staged.retain(|&(se, _)| se != e);
                                    let _ = std::fs::remove_dir_all(&cache_roots[e]);
                                    cluster.release(e);
                                    drp.on_released(e);
                                    metrics.executors_released += 1;
                                }
                            }
                        }
                    }
                    // Membership may have changed: harvest the index
                    // backend's control-plane bill (Chord stabilization)
                    // and the transfer plane's deferral count before
                    // sampling the pool.
                    let ct = core.take_index_control();
                    metrics.add_control_traffic(ct);
                    metrics.staging_deferred = plane.stats().deferred;
                    let replicas = core.replica_location_entries();
                    metrics.sample_pool(
                        now_s,
                        core.executor_count(),
                        drp.pending(),
                        queued_now,
                        replicas,
                    );
                }
            }
            if replicating {
                // Wall-clock replication cadence. Static pools block on
                // the completion channel between iterations, so the
                // effective cadence there is completion-granular — fine
                // for a manager that only needs to sample demand trends.
                let now_s = t0.elapsed().as_secs_f64();
                let poll_due = now_s - last_repl >= repl_poll_s;
                // Drain deferred stagings whose source's egress drained —
                // the plane reads the shared byte ledger directly (no
                // snapshot to refresh), and this runs every loop
                // iteration while any wait, so re-admission reacts to
                // copies finishing, not just the poll cadence.
                if plane.deferred_len() > 0 {
                    for req in plane.readmit() {
                        let sent = inboxes
                            .get(req.dst)
                            .and_then(|o| o.as_ref())
                            .map(|tx| {
                                tx.send(ExecMsg::Stage {
                                    obj: req.obj,
                                    src: req.src,
                                    class: req.class,
                                })
                                .is_ok()
                            })
                            .unwrap_or(false);
                        if !sent {
                            // Destination already released: abandon.
                            core.replication_staged(req.obj, req.dst);
                        }
                    }
                }
                if poll_due {
                    last_repl = now_s;
                    for d in core.poll_replication() {
                        match d {
                            ReplicaDirective::Stage {
                                obj,
                                src,
                                dst,
                                prestage,
                            } => {
                                let class = if prestage {
                                    TransferClass::Prestage
                                } else {
                                    TransferClass::Staging
                                };
                                let req = TransferRequest {
                                    class,
                                    obj,
                                    src,
                                    dst,
                                    bytes: core.catalog().size(obj).unwrap_or(1),
                                };
                                match plane.submit(req) {
                                    // Counted by the plane; synced into
                                    // the metrics at harvest points.
                                    Admission::Defer => {}
                                    Admission::Start => {
                                        let sent = inboxes
                                            .get(dst)
                                            .and_then(|o| o.as_ref())
                                            .map(|tx| {
                                                tx.send(ExecMsg::Stage { obj, src, class }).is_ok()
                                            })
                                            .unwrap_or(false);
                                        if !sent {
                                            // Destination already released.
                                            core.replication_staged(obj, dst);
                                        }
                                    }
                                }
                            }
                            ReplicaDirective::Drop { obj, victim } => {
                                // Same guard as the sim driver: honor the
                                // drop only while the index still records
                                // a second copy to fall back on (the
                                // world may have moved since the
                                // directive — eviction pressure, churn).
                                let droppable = {
                                    let locs = core.locations_for(victim, obj);
                                    locs.len() > 1 && locs.binary_search(&victim).is_ok()
                                };
                                let sent = droppable
                                    && inboxes
                                        .get(victim)
                                        .and_then(|o| o.as_ref())
                                        .map(|tx| tx.send(ExecMsg::Drop { obj }).is_ok())
                                        .unwrap_or(false);
                                if !sent {
                                    // Victim released or copy already
                                    // gone: abandon the teardown.
                                    core.replication_dropped(obj, victim);
                                }
                            }
                        }
                    }
                }
            }
            for order in core.try_dispatch() {
                metrics.tasks_dispatched += 1;
                metrics.add_index_cost(order.cost);
                let msg = ExecMsg::Run {
                    t_submit: submit_times
                        .remove(&order.task.id)
                        .unwrap_or_else(Instant::now),
                    task: order.task,
                    hints: order.hints,
                };
                inboxes[order.executor]
                    .as_ref()
                    .ok_or_else(|| {
                        Error::Protocol(format!("dispatched to released executor {}", order.executor))
                    })?
                    .send(msg)
                    .map_err(|_| Error::Protocol(format!("executor {} died", order.executor)))?;
            }
            // Elastic pools use a timed receive so provisioning can
            // progress while the pool is empty — sleeping until the
            // next provisioning deadline (grant delivery, DRP
            // evaluation, or the replication poll) rather than a fixed
            // 20 ms tick, so an idle elastic pool stops spinning 50×/s
            // doing nothing. Static pools block, as before the
            // refactor.
            let msg = if elastic {
                let now_s = t0.elapsed().as_secs_f64();
                let mut next = last_eval + poll_s;
                for (ready_at, _) in &pending_allocs {
                    next = next.min(*ready_at);
                }
                if replicating {
                    next = next.min(last_repl + repl_poll_s);
                }
                let mut wait = (next - now_s).clamp(0.001, 0.25);
                if replicating && plane.deferred_len() > 0 {
                    // Deferred stagings re-admit as the source's egress
                    // drains, which no deadline announces — keep the
                    // old 20 ms cadence while any are parked.
                    wait = wait.min(0.02);
                }
                match done_rx.recv_timeout(Duration::from_secs_f64(wait)) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(Error::Protocol("all executors died".into()))
                    }
                }
            } else {
                done_rx
                    .recv()
                    .map_err(|_| Error::Protocol("all executors died".into()))?
            };
            let report = match msg {
                ShardMsg::Report(r) => r,
                // Control handoffs exist only on the `--shards >= 2`
                // path; the single loop never receives one.
                ShardMsg::Ctl(_) => continue,
            };
            let c = match report {
                Report::Staged(s) => {
                    // A staging copy landed (or was skipped): index and
                    // manager book-keeping, then back to dispatching.
                    core.replication_staged(s.obj, s.exec);
                    if s.bytes > 0 {
                        metrics.add_bytes(ByteSource::CacheToCache, s.bytes);
                        metrics.replica_bytes_staged += s.bytes;
                        metrics.note_class_transfer(s.class, s.bytes, s.elapsed_s);
                    }
                    // The executor may have been released between sending
                    // this report and us reading it — its index entries
                    // are already purged and must stay purged.
                    if core.executors().binary_search(&s.exec).is_err() {
                        continue;
                    }
                    for ev in &s.events {
                        if let CacheEvent::Evicted(v) = ev {
                            staged.remove(&(s.exec, *v));
                        }
                    }
                    core.apply_cache_events(s.exec, &s.events);
                    if s.created {
                        metrics.replicas_created += 1;
                        staged.insert((s.exec, s.obj));
                    }
                    continue;
                }
                Report::Dropped(d) => {
                    // A teardown executed (or found the copy already
                    // gone): manager bookkeeping first, then the index —
                    // unless the executor was released meanwhile (its
                    // entries are already purged and must stay purged).
                    core.replication_dropped(d.obj, d.exec);
                    if core.executors().binary_search(&d.exec).is_ok() {
                        if !d.events.is_empty() {
                            metrics.replicas_dropped += 1;
                        }
                        staged.remove(&(d.exec, d.obj));
                        core.apply_cache_events(d.exec, &d.events);
                    }
                    continue;
                }
                Report::Done(c) => c,
            };
            completed += 1;
            metrics.tasks_done += 1;
            metrics.note_task_latency(c.t_submit.elapsed().as_secs_f64());
            metrics
                .exec_latency
                .add(c.t_dispatch.elapsed().as_secs_f64());
            for (class, bytes, secs) in &c.xfers {
                metrics.note_class_transfer(*class, *bytes, *secs);
            }
            for (src, bytes, obj) in &c.resolutions {
                metrics.add_resolution(*src);
                metrics.add_bytes(*src, *bytes);
                match src {
                    // Peer fetches are a replication demand signal.
                    ByteSource::CacheToCache => core.note_peer_fetch(*obj, c.exec),
                    ByteSource::Local => {
                        if staged.contains(&(c.exec, *obj)) {
                            metrics.replica_hits += 1;
                        }
                    }
                    _ => {}
                }
            }
            // Executor-side re-resolution of stale hints (§3.2.2):
            // charged at the backend's lookup cost, like dispatch-side
            // lookups.
            for obj in &c.stale {
                metrics.add_index_cost(core.lookup_cost_for(c.exec, *obj));
            }
            for ev in &c.events {
                if let CacheEvent::Evicted(v) = ev {
                    staged.remove(&(c.exec, *v));
                }
            }
            if let Some(e) = c.error {
                first_error.get_or_insert(e);
            }
            if sample_checksums.len() < 8 {
                // Checksum reported through resolutions? kept simple: the
                // executor reports it via the events channel below.
            }
            core.on_task_complete(c.exec, c.task, &c.events);
        }
        // Final harvests (static pools never hit the elastic harvest
        // point; bootstrap registrations and the transfer plane's
        // admission counters are collected here).
        let control = core.take_index_control();
        metrics.add_control_traffic(control);
        metrics.staging_deferred = plane.stats().deferred;
        metrics.t_end = t0.elapsed().as_secs_f64();
        metrics.peak_executors = metrics.peak_executors.max(core.executor_count());
        metrics.harvest_shard_stats(&core.shard_stats());

        // Shutdown. (In elastic mode our keep-alive `done_tx` lives until
        // the function returns; the loop above exits on the completion
        // count, not on channel disconnect, so that is harmless.)
        for tx in inboxes.iter().flatten() {
            let _ = tx.send(ExecMsg::Shutdown);
        }
        for (_, h) in handles {
            let _ = h.join();
        }
        if let Some((_, tx, h)) = compute {
            let _ = tx.send(ComputeMsg::Shutdown);
            let _ = h.join();
        }
        if let Some(e) = first_error {
            return Err(Error::Protocol(format!("task failed: {e}")));
        }
        let makespan = metrics.t_end;
        sample_checksums.truncate(8);
        Ok(RunOutcome {
            metrics,
            makespan_s: makespan,
            events: 0,
            wall_s: t0.elapsed().as_secs_f64(),
            sample_checksums,
        })
    }

    /// Run a batch with per-shard dispatcher threads (`--shards >= 2`).
    ///
    /// Each shard owns a dispatcher loop, the dedicated [`ShardMsg`]
    /// channel its executors report into, the inbox senders of those
    /// executors, its own [`LiveTransferPlane`] admission state, and a
    /// shard-local [`Metrics`]. The calling thread becomes the thin
    /// control loop: provisioning on wall-clock time, membership-churn
    /// handoffs, pool sampling, and the final merge. See the module
    /// docs for the full protocol.
    fn run_sharded(self, tasks: Vec<Task>) -> Result<RunOutcome> {
        let LiveCluster {
            cfg,
            store,
            workdir,
            artifacts,
        } = self;
        let n_exec = cfg.testbed.nodes;
        let format = store.format();
        let capacity = (cfg.testbed.cpus_per_node * cfg.scheduler.tasks_per_cpu).max(1);
        let elastic = cfg.provisioner.enabled;
        let shards = cfg.coordinator.shards.max(1);

        let mut catalog = Catalog::new();
        for id in store.catalog().ids() {
            catalog.insert(id, store.catalog().size(id).unwrap());
        }
        let indexes = (0..shards)
            .map(|_| crate::index::build(&cfg.index, cfg.seed))
            .collect();
        let mut core = ShardedCore::with_indexes(&cfg.scheduler, catalog, indexes);

        let compute = match artifacts {
            Some(dir) => Some(spawn_compute(dir)?),
            None => None,
        };
        let compute_client = compute.as_ref().map(|(c, _, _)| c.clone());

        let egress_bps = cfg.testbed.nic_bps.min(cfg.local_disk.read_bps);
        let ledger = Arc::new(EgressLedger::new(n_exec, egress_bps));
        let pacer = Arc::new(StagingPacer::new(n_exec, egress_bps, &cfg.transfer));

        // One dedicated report/control channel per shard. Executor `e`
        // reports to shard `e % shards`; the control loop keeps a sender
        // clone per shard for churn handoffs — which also keeps every
        // channel alive while a shard's pool is transiently empty.
        let mut shard_txs: Vec<mpsc::Sender<ShardMsg>> = Vec::with_capacity(shards);
        let mut shard_rxs: Vec<mpsc::Receiver<ShardMsg>> = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::channel::<ShardMsg>();
            shard_txs.push(tx);
            shard_rxs.push(rx);
        }

        let cache_roots: Vec<PathBuf> =
            (0..n_exec).map(|e| workdir.join(format!("cache{e}"))).collect();
        let store_root = store.path_of(ObjectId(0)).parent().unwrap().to_path_buf();
        let spawn_exec = |e: ExecutorId| -> Result<(mpsc::Sender<ExecMsg>, JoinHandle<()>)> {
            let (tx, rx) = mpsc::channel::<ExecMsg>();
            let ctx = ExecutorCtx {
                exec: e,
                cfg: cfg.clone(),
                format,
                store_root: store_root.clone(),
                cache_dir: LiveCacheDir::create(&cache_roots[e])?,
                cache_roots: cache_roots.clone(),
                cache: DataCache::new(
                    cfg.cache.capacity_bytes,
                    cfg.cache.policy,
                    cfg.seed ^ e as u64,
                ),
                compute: compute_client.clone(),
                ledger: ledger.clone(),
                pacer: pacer.clone(),
                done: shard_txs[e % shards].clone(),
            };
            Ok((tx, std::thread::spawn(move || executor_loop(ctx, rx))))
        };

        // Provisioning + pool bookkeeping, owned by the control loop.
        let mut drp = Provisioner::new(cfg.provisioner.clone());
        let mut cluster = ClusterProvider::new(n_exec, cfg.provisioner.allocation_latency_s);
        let mut pending_allocs: Vec<(f64, Vec<usize>)> = Vec::new(); // (ready_at_s, nodes)
        let poll_s = cfg.provisioner.poll_interval_s.max(0.005);
        let mut last_eval = 0.0f64;
        let mut metrics = Metrics::new();
        metrics.t_start = 0.0;
        let mut handles: Vec<(ExecutorId, JoinHandle<()>)> = Vec::new();
        // `alive[e]`: executor `e`'s thread is up and owned by a shard
        // loop — the per-shard analogue of the single loop's
        // `inboxes[e].is_some()`.
        let mut alive: Vec<bool> = vec![false; n_exec];
        let mut init_inboxes: Vec<Vec<(ExecutorId, mpsc::Sender<ExecMsg>)>> =
            (0..shards).map(|_| Vec::new()).collect();

        if elastic {
            if n_exec == 0 || cfg.provisioner.max_executors == 0 {
                return Err(Error::Config(
                    "elastic pool needs at least one allocatable executor \
                     (testbed.nodes and provisioner.max_executors must be >= 1)"
                        .into(),
                ));
            }
            let warm = cfg.provisioner.min_executors.min(n_exec);
            if warm > 0 {
                let grant = cluster.allocate(0.0, warm);
                for &e in &grant.nodes {
                    core.register_executor_with(e, capacity);
                    let (tx, h) = spawn_exec(e)?;
                    init_inboxes[e % shards].push((e, tx));
                    handles.push((e, h));
                    alive[e] = true;
                }
                drp.on_allocated(grant.nodes.len());
            }
        } else {
            for e in 0..n_exec {
                core.register_executor_with(e, capacity);
                let (tx, h) = spawn_exec(e)?;
                init_inboxes[e % shards].push((e, tx));
                handles.push((e, h));
                alive[e] = true;
            }
        }

        let replicating = cfg.replication.enabled && cfg.scheduler.policy.is_data_aware();
        if replicating {
            core.enable_replication(&cfg.replication);
        }

        let t0 = Instant::now();
        let total = tasks.len() as u64;
        // Frozen before the loops start; shard loops read concurrently.
        let mut submit_times: HashMap<TaskId, Instant> = HashMap::new();
        for t in tasks {
            submit_times.insert(t.id, Instant::now());
            core.submit(t);
        }
        let plane = core.into_plane();
        let completed = AtomicU64::new(0);
        let abort = AtomicBool::new(false);
        let first_error: Mutex<Option<String>> = Mutex::new(None);
        let fatal: Mutex<Option<String>> = Mutex::new(None);
        let (ack_tx, ack_rx) = mpsc::channel::<CtlAck>();

        let mut shard_outs: Vec<ShardLoopOut> = Vec::with_capacity(shards);
        let run_result: Result<()> = std::thread::scope(|scope| {
            let mut loops = Vec::with_capacity(shards);
            for (s, rx) in shard_rxs.into_iter().enumerate() {
                let ctx = ShardLoopCtx {
                    s,
                    plane: &plane,
                    rx,
                    inboxes: init_inboxes[s].drain(..).collect(),
                    cfg: &cfg,
                    ledger: ledger.clone(),
                    submit_times: &submit_times,
                    completed: &completed,
                    total,
                    abort: &abort,
                    first_error: &first_error,
                    fatal: &fatal,
                    ack_tx: ack_tx.clone(),
                    replicating,
                    t0,
                };
                loops.push(scope.spawn(move || shard_loop(ctx)));
            }
            // The shard loops now hold the only long-lived ack senders:
            // `ack_rx` disconnecting means every loop is gone.
            drop(ack_tx);

            let ctl = (|| -> Result<()> {
                while completed.load(Ordering::Relaxed) < total && !abort.load(Ordering::Relaxed)
                {
                    let now_s = t0.elapsed().as_secs_f64();
                    // A thread that finished while a shard loop still
                    // owns its inbox died on its own (panic).
                    for (e, h) in &handles {
                        if alive[*e] && h.is_finished() {
                            return Err(Error::Protocol(format!(
                                "executor {e} died unexpectedly"
                            )));
                        }
                    }
                    let mut next = now_s + 0.2; // death-probe backstop
                    if elastic {
                        // Deliver allocation grants whose latency
                        // elapsed: spawn the thread here, hand its inbox
                        // to the owning shard loop.
                        let mut i = 0;
                        while i < pending_allocs.len() {
                            if pending_allocs[i].0 <= now_s {
                                let (_, nodes) = pending_allocs.swap_remove(i);
                                let n = nodes.len();
                                for e in nodes {
                                    let (tx, h) = spawn_exec(e)?;
                                    handles.push((e, h));
                                    alive[e] = true;
                                    shard_txs[e % shards]
                                        .send(ShardMsg::Ctl(ShardCtl::Register {
                                            e,
                                            capacity,
                                            inbox: tx,
                                        }))
                                        .map_err(|_| {
                                            Error::Protocol(format!(
                                                "shard loop {} gone",
                                                e % shards
                                            ))
                                        })?;
                                }
                                drp.on_allocated(n);
                                metrics.executors_joined += n as u64;
                                let count = alive.iter().filter(|&&a| a).count();
                                metrics.peak_executors = metrics.peak_executors.max(count);
                            } else {
                                next = next.min(pending_allocs[i].0);
                                i += 1;
                            }
                        }
                        if now_s - last_eval >= poll_s {
                            let dt = now_s - last_eval;
                            last_eval = now_s;
                            let queued_now = plane.queue_len();
                            let demand = plane.take_queue_peak().max(queued_now);
                            let quiescent = plane.quiescent_executors();
                            for e in plane.executors() {
                                if quiescent.binary_search(&e).is_ok() {
                                    drp.note_idle(e, now_s);
                                } else {
                                    drp.note_busy(e);
                                }
                            }
                            metrics.idle_exec_s += quiescent.len() as f64 * dt;
                            metrics.alloc_wait_s += drp.pending() as f64 * dt;
                            let mut releases = 0usize;
                            for action in drp.evaluate(demand, now_s) {
                                match action {
                                    ProvisionAction::Allocate { count } => {
                                        metrics.alloc_requests += 1;
                                        let grant = cluster.allocate(now_s, count);
                                        if grant.nodes.len() < count {
                                            drp.cancel_pending(count - grant.nodes.len());
                                        }
                                        if !grant.nodes.is_empty() {
                                            pending_allocs.push((grant.ready_at, grant.nodes));
                                        }
                                    }
                                    ProvisionAction::Release { executors } => {
                                        for e in executors {
                                            if quiescent.binary_search(&e).is_err() || !alive[e]
                                            {
                                                continue;
                                            }
                                            // Propose; the owning loop
                                            // re-validates and acks.
                                            shard_txs[e % shards]
                                                .send(ShardMsg::Ctl(ShardCtl::Release { e }))
                                                .map_err(|_| {
                                                    Error::Protocol(format!(
                                                        "shard loop {} gone",
                                                        e % shards
                                                    ))
                                                })?;
                                            releases += 1;
                                        }
                                    }
                                }
                            }
                            // Reap `ok` releases: join the thread, tear
                            // down the cache directory, bill the
                            // cluster. A refused release means a
                            // dispatch won the race — dropped, exactly
                            // as the single loop skips it.
                            let mut acked = 0usize;
                            while acked < releases {
                                match ack_rx.recv_timeout(Duration::from_secs(10)) {
                                    Ok(CtlAck::Released { e, ok }) => {
                                        acked += 1;
                                        if !ok {
                                            continue;
                                        }
                                        if let Some(pos) =
                                            handles.iter().position(|(he, _)| *he == e)
                                        {
                                            let (_, h) = handles.swap_remove(pos);
                                            let _ = h.join();
                                        }
                                        alive[e] = false;
                                        let _ = std::fs::remove_dir_all(&cache_roots[e]);
                                        cluster.release(e);
                                        drp.on_released(e);
                                        metrics.executors_released += 1;
                                    }
                                    // Advisory; the outer condition
                                    // re-checks the completion count.
                                    Ok(CtlAck::Drained) => {}
                                    Err(_) => {
                                        return Err(Error::Protocol(
                                            "release ack lost (shard loop gone?)".into(),
                                        ))
                                    }
                                }
                            }
                            // Pool sample + control-plane harvest.
                            // (`staging_deferred` is per-shard plane
                            // state here; the merged total lands in the
                            // summary at run end.)
                            let ct = plane.take_index_control();
                            metrics.add_control_traffic(ct);
                            let replicas = plane.replica_location_entries();
                            let count = alive.iter().filter(|&&a| a).count();
                            metrics.sample_pool(
                                now_s,
                                count,
                                drp.pending(),
                                queued_now,
                                replicas,
                            );
                        }
                        next = next.min(last_eval + poll_s);
                    }
                    // Sleep until the next provisioning deadline (or the
                    // death-probe backstop); a `Drained` ack wakes us
                    // early, stray release refusals are ignored.
                    let wait = (next - t0.elapsed().as_secs_f64()).clamp(0.001, 0.2);
                    match ack_rx.recv_timeout(Duration::from_secs_f64(wait)) {
                        Ok(_) | Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            return Err(Error::Protocol("all shard loops gone".into()));
                        }
                    }
                }
                Ok(())
            })();

            // Stop the shard loops however the control loop ended; they
            // drain their channels, shut their executors down, and hand
            // back their tallies.
            for tx in &shard_txs {
                let _ = tx.send(ShardMsg::Ctl(ShardCtl::Shutdown));
            }
            for h in loops {
                match h.join() {
                    Ok(out) => shard_outs.push(out),
                    Err(_) => return Err(Error::Protocol("shard loop panicked".into())),
                }
            }
            ctl
        });

        for (_, h) in handles {
            let _ = h.join();
        }
        if let Some((_, tx, h)) = compute {
            let _ = tx.send(ComputeMsg::Shutdown);
            let _ = h.join();
        }
        if let Some(msg) = fatal.into_inner().expect("fatal lock") {
            return Err(Error::Protocol(msg));
        }
        run_result?;
        if let Some(e) = first_error.into_inner().expect("error lock") {
            return Err(Error::Protocol(format!("task failed: {e}")));
        }

        // Merge: shard tallies into one ShardStats, shard metrics into
        // the control metrics, then the pool-level overrides only this
        // thread can set.
        let mut stats = ShardStats {
            queue_depths: plane.queue_depths(),
            ..ShardStats::default()
        };
        for out in &shard_outs {
            stats.steals += out.steals;
            stats.stolen_tasks += out.stolen_tasks;
            stats.batches += out.batches;
            for (h, o) in stats.batch_hist.iter_mut().zip(out.batch_hist) {
                *h += o;
            }
        }
        for out in &shard_outs {
            metrics.merge(&out.metrics);
        }
        let control = plane.take_index_control();
        metrics.add_control_traffic(control);
        metrics.harvest_shard_stats(&stats);
        metrics.t_end = t0.elapsed().as_secs_f64();
        metrics.peak_executors = metrics.peak_executors.max(plane.executor_count());
        let makespan = metrics.t_end;
        Ok(RunOutcome {
            metrics,
            makespan_s: makespan,
            events: 0,
            wall_s: t0.elapsed().as_secs_f64(),
            sample_checksums: Vec::new(),
        })
    }
}

/// Everything one shard dispatcher loop owns or borrows for the
/// duration of the scoped run (`--shards >= 2`).
struct ShardLoopCtx<'a> {
    s: usize,
    plane: &'a ShardPlane,
    rx: mpsc::Receiver<ShardMsg>,
    /// Inbox senders of the executors this shard currently owns.
    inboxes: HashMap<ExecutorId, mpsc::Sender<ExecMsg>>,
    cfg: &'a Config,
    ledger: Arc<EgressLedger>,
    /// Submission instants, frozen before the loops start.
    submit_times: &'a HashMap<TaskId, Instant>,
    completed: &'a AtomicU64,
    total: u64,
    abort: &'a AtomicBool,
    first_error: &'a Mutex<Option<String>>,
    fatal: &'a Mutex<Option<String>>,
    ack_tx: mpsc::Sender<CtlAck>,
    replicating: bool,
    t0: Instant,
}

/// What one shard dispatcher loop hands back when it exits.
struct ShardLoopOut {
    /// Shard-local metrics: everything derived from the reports this
    /// loop processed, plus its dispatch busy time and report-burst
    /// peak. Pool-level fields are left at zero for the control loop's
    /// merge (`Metrics::merge` *sums* `peak_executors`).
    metrics: Metrics,
    steals: u64,
    stolen_tasks: u64,
    batches: u64,
    batch_hist: [u64; 6],
}

/// One shard's dispatcher loop: drain reports and control handoffs from
/// the shard channel, apply them to the locked shard core, run the
/// shard's replication cadence, steal when starved, dispatch a batch,
/// publish hints — concurrently with every other shard's loop. Inbox
/// sends happen while the shard lock is held, but mpsc sends never
/// block, so the lock is only ever held for bounded CPU work.
fn shard_loop(ctx: ShardLoopCtx<'_>) -> ShardLoopOut {
    let ShardLoopCtx {
        s,
        plane,
        rx,
        mut inboxes,
        cfg,
        ledger,
        submit_times,
        completed,
        total,
        abort,
        first_error,
        fatal,
        ack_tx,
        replicating,
        t0,
    } = ctx;
    let mut m = Metrics::new();
    m.t_start = 0.0;
    let mut xfer = LiveTransferPlane::new(&cfg.transfer, ledger);
    let mut staged: HashSet<(ExecutorId, ObjectId)> = HashSet::new();
    let mut sizer = StealSizer::new();
    let mut orders = Vec::new();
    let mut steals = 0u64;
    let mut stolen_tasks = 0u64;
    let mut batches = 0u64;
    let mut batch_hist = [0u64; 6];
    let repl_poll_s = cfg.replication.evaluate_interval_s.max(0.005);
    let mut last_repl = 0.0f64;
    let mut busy = 0.0f64;
    let mut burst_peak = 0u64;
    let mut steal_retry = false;
    let mut first_pass = true;

    'run: loop {
        // Sleep until something is due: a 200 ms backstop (abort
        // checks), pulled in to 2 ms after a steal whiff while work is
        // visible elsewhere (the victim's lock was contended — retry
        // soon), and by the replication cadence. The first pass does
        // not wait at all: tasks submitted before the loops started
        // must dispatch immediately, as on the single loop.
        let mut wait = Duration::from_millis(200);
        if steal_retry {
            wait = Duration::from_millis(2);
        }
        if first_pass {
            first_pass = false;
            wait = Duration::ZERO;
        }
        if replicating {
            let now_s = t0.elapsed().as_secs_f64();
            let until = (last_repl + repl_poll_s - now_s).max(0.0005);
            wait = wait.min(Duration::from_secs_f64(until));
            if xfer.deferred_len() > 0 {
                wait = wait.min(Duration::from_millis(20));
            }
        }
        let first = match rx.recv_timeout(wait) {
            Ok(msg) => Some(msg),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            // Control loop gone without a shutdown handoff: bail out.
            Err(mpsc::RecvTimeoutError::Disconnected) => break 'run,
        };
        let t_work = Instant::now();
        if abort.load(Ordering::Relaxed) {
            for tx in inboxes.values() {
                let _ = tx.send(ExecMsg::Shutdown);
            }
            break 'run;
        }

        // Apply the whole burst under one core lock.
        let mut core = plane.lock(s);
        let mut burst = 0u64;
        let mut shutdown = false;
        let mut next_msg = first;
        while let Some(msg) = next_msg {
            match msg {
                ShardMsg::Ctl(ShardCtl::Shutdown) => shutdown = true,
                ShardMsg::Ctl(ShardCtl::Register { e, capacity, inbox }) => {
                    core.register_executor_with(e, capacity);
                    inboxes.insert(e, inbox);
                }
                ShardMsg::Ctl(ShardCtl::Release { e }) => {
                    // Re-validate: a dispatch this loop made after the
                    // control loop's observation voids the release.
                    let ok = inboxes.contains_key(&e)
                        && core.quiescent_executors().binary_search(&e).is_ok();
                    if ok {
                        if let Some(tx) = inboxes.remove(&e) {
                            let _ = tx.send(ExecMsg::Shutdown);
                        }
                        let _orphans = core.deregister_executor(e);
                        // Deferred stagings touching the released
                        // executor are cancelled; free the manager's
                        // in-flight slots.
                        for req in xfer.executor_released(e) {
                            core.replication_staged(req.obj, req.dst);
                        }
                        staged.retain(|&(se, _)| se != e);
                    }
                    let _ = ack_tx.send(CtlAck::Released { e, ok });
                }
                ShardMsg::Report(Report::Staged(sr)) => {
                    burst += 1;
                    core.replication_staged(sr.obj, sr.exec);
                    if sr.bytes > 0 {
                        m.add_bytes(ByteSource::CacheToCache, sr.bytes);
                        m.replica_bytes_staged += sr.bytes;
                        m.note_class_transfer(sr.class, sr.bytes, sr.elapsed_s);
                    }
                    // Released between sending and reading: index
                    // entries are already purged and must stay purged.
                    if core.executors().binary_search(&sr.exec).is_ok() {
                        for ev in &sr.events {
                            if let CacheEvent::Evicted(v) = ev {
                                staged.remove(&(sr.exec, *v));
                            }
                        }
                        core.apply_cache_events(sr.exec, &sr.events);
                        if sr.created {
                            m.replicas_created += 1;
                            staged.insert((sr.exec, sr.obj));
                        }
                    }
                }
                ShardMsg::Report(Report::Dropped(d)) => {
                    burst += 1;
                    core.replication_dropped(d.obj, d.exec);
                    if core.executors().binary_search(&d.exec).is_ok() {
                        if !d.events.is_empty() {
                            m.replicas_dropped += 1;
                        }
                        staged.remove(&(d.exec, d.obj));
                        core.apply_cache_events(d.exec, &d.events);
                    }
                }
                ShardMsg::Report(Report::Done(c)) => {
                    burst += 1;
                    m.tasks_done += 1;
                    m.note_task_latency(c.t_submit.elapsed().as_secs_f64());
                    m.exec_latency.add(c.t_dispatch.elapsed().as_secs_f64());
                    for (class, bytes, secs) in &c.xfers {
                        m.note_class_transfer(*class, *bytes, *secs);
                    }
                    for (src, bytes, obj) in &c.resolutions {
                        m.add_resolution(*src);
                        m.add_bytes(*src, *bytes);
                        match src {
                            // Peer fetches are a replication demand
                            // signal.
                            ByteSource::CacheToCache => core.note_peer_fetch(*obj, c.exec),
                            ByteSource::Local => {
                                if staged.contains(&(c.exec, *obj)) {
                                    m.replica_hits += 1;
                                }
                            }
                            _ => {}
                        }
                    }
                    // Executor-side re-resolution of stale hints
                    // (§3.2.2), charged at this shard's backend cost.
                    for obj in &c.stale {
                        m.add_index_cost(core.index().lookup_cost(*obj));
                    }
                    for ev in &c.events {
                        if let CacheEvent::Evicted(v) = ev {
                            staged.remove(&(c.exec, *v));
                        }
                    }
                    if let Some(e) = c.error {
                        first_error.lock().expect("error lock").get_or_insert(e);
                    }
                    core.on_task_complete(c.exec, c.task, &c.events);
                    if completed.fetch_add(1, Ordering::Relaxed) + 1 == total {
                        // We retired the last task: wake the control
                        // loop promptly.
                        let _ = ack_tx.send(CtlAck::Drained);
                    }
                }
            }
            next_msg = rx.try_recv().ok();
        }
        burst_peak = burst_peak.max(burst);
        if shutdown {
            for tx in inboxes.values() {
                let _ = tx.send(ExecMsg::Shutdown);
            }
            plane.publish(s, core.ready_len(), core.executor_count());
            drop(core);
            busy += t_work.elapsed().as_secs_f64();
            break 'run;
        }

        // Shard-local replication cadence: this shard's manager only
        // ever names this shard's executors (locations live in the
        // index slice its executors report into), so the inbox map and
        // transfer-plane state stay strictly shard-local.
        if replicating {
            let now_s = t0.elapsed().as_secs_f64();
            if xfer.deferred_len() > 0 {
                for req in xfer.readmit() {
                    let sent = inboxes
                        .get(&req.dst)
                        .map(|tx| {
                            tx.send(ExecMsg::Stage {
                                obj: req.obj,
                                src: req.src,
                                class: req.class,
                            })
                            .is_ok()
                        })
                        .unwrap_or(false);
                    if !sent {
                        // Destination already released: abandon.
                        core.replication_staged(req.obj, req.dst);
                    }
                }
            }
            if now_s - last_repl >= repl_poll_s {
                last_repl = now_s;
                for d in core.poll_replication() {
                    match d {
                        ReplicaDirective::Stage {
                            obj,
                            src,
                            dst,
                            prestage,
                        } => {
                            let class = if prestage {
                                TransferClass::Prestage
                            } else {
                                TransferClass::Staging
                            };
                            let req = TransferRequest {
                                class,
                                obj,
                                src,
                                dst,
                                bytes: plane.catalog().size(obj).unwrap_or(1),
                            };
                            match xfer.submit(req) {
                                Admission::Defer => {}
                                Admission::Start => {
                                    let sent = inboxes
                                        .get(&dst)
                                        .map(|tx| {
                                            tx.send(ExecMsg::Stage { obj, src, class }).is_ok()
                                        })
                                        .unwrap_or(false);
                                    if !sent {
                                        core.replication_staged(obj, dst);
                                    }
                                }
                            }
                        }
                        ReplicaDirective::Drop { obj, victim } => {
                            // Honor the drop only while the index still
                            // records a second copy to fall back on.
                            let droppable = {
                                let locs = core.index().locations(obj);
                                locs.len() > 1 && locs.binary_search(&victim).is_ok()
                            };
                            let sent = droppable
                                && inboxes
                                    .get(&victim)
                                    .map(|tx| tx.send(ExecMsg::Drop { obj }).is_ok())
                                    .unwrap_or(false);
                            if !sent {
                                core.replication_dropped(obj, victim);
                            }
                        }
                    }
                }
            }
        }

        // Steal if starved, dispatch one batch, publish hints for the
        // other loops' victim selection.
        let moved = plane.steal_into(s, &mut core, &mut sizer);
        if moved > 0 {
            steals += 1;
            stolen_tasks += moved;
        }
        core.dispatch_into(&mut orders);
        ShardedCore::record_batch(&mut batches, &mut batch_hist, orders.len());
        let starved = core.idle_count() > 0 && core.ready_len() == 0;
        plane.publish(s, core.ready_len(), core.executor_count());
        drop(core);
        steal_retry = starved && plane.work_visible_elsewhere(s);
        for o in orders.drain(..) {
            m.tasks_dispatched += 1;
            m.add_index_cost(o.cost);
            let exec = o.executor;
            let msg = ExecMsg::Run {
                t_submit: submit_times
                    .get(&o.task.id)
                    .copied()
                    .unwrap_or_else(Instant::now),
                task: o.task,
                hints: o.hints,
            };
            let sent = inboxes.get(&exec).map(|tx| tx.send(msg).is_ok()).unwrap_or(false);
            if !sent {
                // Only reachable on protocol breakage — the core never
                // places work on an unregistered executor. Surface it
                // and stop the whole run.
                fatal
                    .lock()
                    .expect("fatal lock")
                    .get_or_insert(format!("shard {s}: executor {exec} unavailable for dispatch"));
                abort.store(true, Ordering::Relaxed);
                for tx in inboxes.values() {
                    let _ = tx.send(ExecMsg::Shutdown);
                }
                busy += t_work.elapsed().as_secs_f64();
                break 'run;
            }
        }
        busy += t_work.elapsed().as_secs_f64();
    }
    m.dispatch_loop_busy_s = busy;
    m.report_queue_peaks = vec![burst_peak];
    m.staging_deferred = xfer.stats().deferred;
    ShardLoopOut {
        metrics: m,
        steals,
        stolen_tasks,
        batches,
        batch_hist,
    }
}

struct ExecutorCtx {
    exec: ExecutorId,
    cfg: Config,
    format: DataFormat,
    store_root: PathBuf,
    cache_dir: LiveCacheDir,
    cache_roots: Vec<PathBuf>,
    cache: DataCache,
    compute: Option<ComputeClient>,
    /// Shared per-source egress byte accounting: every copy out of a
    /// peer's cache registers its bytes against that source.
    ledger: Arc<EgressLedger>,
    /// Token-bucket pacing for background staging copies (no-op under
    /// the binary share policy).
    pacer: Arc<StagingPacer>,
    /// Report channel of this executor's owning coordinator loop: the
    /// shared coordinator channel at `--shards 1`, shard `e % shards`'s
    /// dedicated channel at `--shards >= 2`.
    done: mpsc::Sender<ShardMsg>,
}

/// File extension of stored/cached objects in `format`.
fn ext_of(format: DataFormat) -> &'static str {
    match format {
        DataFormat::Gz => "fits.gz",
        DataFormat::Fit => "fits",
    }
}

fn executor_loop(mut ctx: ExecutorCtx, rx: mpsc::Receiver<ExecMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ExecMsg::Shutdown => break,
            ExecMsg::Run {
                task,
                hints,
                t_submit,
            } => {
                let t_dispatch = Instant::now();
                let mut events = Vec::new();
                let mut resolutions = Vec::new();
                let mut xfers = Vec::new();
                let mut stale = Vec::new();
                let err = run_task(
                    &mut ctx,
                    &task,
                    &hints,
                    &mut events,
                    &mut resolutions,
                    &mut xfers,
                    &mut stale,
                )
                .err()
                .map(|e| e.to_string());
                let _ = ctx.done.send(ShardMsg::Report(Report::Done(Completion {
                    exec: ctx.exec,
                    task: task.id,
                    events,
                    resolutions,
                    xfers,
                    stale,
                    t_submit,
                    t_dispatch,
                    error: err,
                })));
            }
            ExecMsg::Stage { obj, src, class } => {
                let report = stage_object(&mut ctx, obj, src, class);
                let _ = ctx.done.send(ShardMsg::Report(Report::Staged(report)));
            }
            ExecMsg::Drop { obj } => {
                // Replica teardown: release the cache entry and the file
                // now, ahead of eviction pressure. A copy that is already
                // gone (evicted, never landed) reports an empty event
                // list so the coordinator only counts real releases.
                let mut events = Vec::new();
                if ctx.cache.remove(obj) {
                    ctx.cache_dir.evict(obj, ctx.format);
                    events.push(CacheEvent::Evicted(obj));
                }
                let _ = ctx.done.send(ShardMsg::Report(Report::Dropped(DropReport {
                    exec: ctx.exec,
                    obj,
                    events,
                })));
            }
        }
    }
}

/// Replication staging on the destination executor: copy the object from
/// the source peer's cache directory into our own cache — charged to the
/// source's egress ledger for the duration, and paced at the class's
/// fair share of that egress under the weighted policy. If the source
/// copy vanished (evicted or the lease ended) the stage is abandoned —
/// the same rule the sim driver applies — so staged bytes are always
/// genuine cache-to-cache traffic and the manager can retry with a
/// holder that still exists.
fn stage_object(
    ctx: &mut ExecutorCtx,
    obj: ObjectId,
    src: ExecutorId,
    class: TransferClass,
) -> StageReport {
    let mut report = StageReport {
        exec: ctx.exec,
        obj,
        class,
        bytes: 0,
        elapsed_s: 0.0,
        created: false,
        events: Vec::new(),
    };
    if ctx.cache.contains(obj) {
        return report; // organic copy won the race
    }
    let ext = ext_of(ctx.format);
    let Some(peer_path) = ctx
        .cache_roots
        .get(src)
        .map(|root| root.join(format!("{obj}.{ext}")))
        .filter(|p| p.exists())
    else {
        return report; // source copy gone: abandon, demand will retry
    };
    let cached_path = ctx.cache_dir.path_of(obj, ctx.format);
    let expect = std::fs::metadata(&peer_path).map(|m| m.len()).unwrap_or(0);
    let t = Instant::now();
    let copied = {
        let _egress = EgressGuard::new(ctx.ledger.clone(), src, expect);
        copy_into_cache_paced(&peer_path, &cached_path, &ctx.pacer, src, class)
    };
    if let Ok(bytes) = copied {
        report.bytes = bytes;
        report.elapsed_s = t.elapsed().as_secs_f64();
        report.events = apply_cache_insert(ctx, obj, bytes);
        report.created = report
            .events
            .iter()
            .any(|e| matches!(e, CacheEvent::Inserted(o) if *o == obj));
    }
    report
}

/// Execute one task on this executor: resolve inputs (own cache → peer →
/// persistent storage), then run the compute. `xfers` collects the timed
/// copies this task performed (all `Foreground` — per-class accounting);
/// `stale` collects inputs whose hints all went stale (every hinted copy
/// gone), so the coordinator can charge the executor-side re-resolution.
fn run_task(
    ctx: &mut ExecutorCtx,
    task: &Task,
    hints: &LocationHints,
    events: &mut Vec<CacheEvent>,
    resolutions: &mut Vec<(ByteSource, u64, ObjectId)>,
    xfers: &mut Vec<(TransferClass, u64, f64)>,
    stale: &mut Vec<ObjectId>,
) -> Result<()> {
    let ext = ext_of(ctx.format);
    let caching = ctx.cfg.scheduler.policy.is_data_aware();
    let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(task.inputs.len());

    for &obj in &task.inputs {
        let cached_path = ctx.cache_dir.path_of(obj, ctx.format);
        if caching && ctx.cache.access(obj) && cached_path.exists() {
            // Own cache hit.
            let raw = read_object_file(&cached_path, ctx.format)?;
            resolutions.push((ByteSource::Local, raw.len() as u64, obj));
            payloads.push(raw);
            continue;
        }

        // Peer fetch: first hinted peer whose cache file exists (hints
        // are ranked by the scheduler so replicas share the load).
        let mut fetched = false;
        let mut hinted_peer = false;
        if caching {
            if let Some(locs) = hints.get(&obj) {
                for &peer in locs {
                    if peer == ctx.exec || peer >= ctx.cache_roots.len() {
                        continue;
                    }
                    hinted_peer = true;
                    let peer_path = ctx.cache_roots[peer].join(format!("{obj}.{ext}"));
                    if peer_path.exists() {
                        // Foreground peer fetch: never paced, but its
                        // bytes do load the source's egress ledger while
                        // in flight — that is what holds background
                        // staging from the same source back.
                        let expect = std::fs::metadata(&peer_path).map(|m| m.len()).unwrap_or(0);
                        let t = Instant::now();
                        let copied = {
                            let _egress = EgressGuard::new(ctx.ledger.clone(), peer, expect);
                            copy_into_cache(&peer_path, &cached_path)
                        };
                        if let Ok(bytes) = copied {
                            xfers.push((
                                TransferClass::Foreground,
                                bytes,
                                t.elapsed().as_secs_f64(),
                            ));
                            resolutions.push((ByteSource::CacheToCache, bytes, obj));
                            fetched = true;
                            break;
                        }
                    }
                }
            }
        }

        if !fetched {
            if hinted_peer {
                // Every hinted copy vanished (§3.2.2 stale hints): the
                // executor re-resolves; the coordinator charges it.
                stale.push(obj);
            }
            // Persistent storage (not an executor's egress: no ledger).
            let store_path = ctx.store_root.join(format!("{obj}.{ext}"));
            if caching {
                let t = Instant::now();
                let bytes = copy_into_cache(&store_path, &cached_path).map_err(|e| {
                    Error::UnknownObject(format!("{obj} ({}): {e}", store_path.display()))
                })?;
                xfers.push((TransferClass::Foreground, bytes, t.elapsed().as_secs_f64()));
                resolutions.push((ByteSource::Gpfs, bytes, obj));
            } else {
                let bytes = std::fs::metadata(&store_path)
                    .map_err(|e| {
                        Error::UnknownObject(format!("{obj} ({}): {e}", store_path.display()))
                    })?
                    .len();
                resolutions.push((ByteSource::Gpfs, bytes, obj));
            }
        }

        // Read (and decompress) the object.
        let raw = if caching {
            let r = read_object_file(&cached_path, ctx.format)?;
            let bytes = std::fs::metadata(&cached_path)?.len();
            events.extend(apply_cache_insert(ctx, obj, bytes));
            r
        } else {
            read_object_file(&ctx.store_root.join(format!("{obj}.{ext}")), ctx.format)?
        };
        payloads.push(raw);
    }

    // Compute.
    if let TaskKind::Stack { stack_depth } = task.kind {
        if let Some(compute) = &ctx.compute {
            let file = task.inputs.first().copied().unwrap_or(ObjectId(0));
            // radec2xy: locate the object on its source images (runs on
            // the compute service before any pixel work, as in Fig 7).
            let (ra, dec) = sky::radec_for(file);
            let _xy = compute.radec2xy(vec![ra], vec![dec], 0.15, 0.0, 1.0e4)?;
            let payload = payloads.first().map(|p| pixels_of(p)).unwrap_or_default();
            let depth = stack_depth.max(1) as usize;
            // ROI geometry must match the AOT artifacts (100×100).
            let (h, w) = (100, 100);
            let (raw, sky_v, cal, shifts, weights) =
                sky::stack_inputs(file, &payload, depth, h, w);
            let out = compute.stack(StackRequest {
                raw,
                sky: sky_v,
                cal,
                shifts,
                weights,
                depth,
            })?;
            // Write the stacked image to the cache dir (diffused output).
            if task.output_bytes > 0 {
                let out_path = ctx
                    .cache_dir
                    .path_of(ObjectId(u64::MAX - task.id.0), DataFormat::Fit);
                let bytes: Vec<u8> = out.iter().flat_map(|f| f.to_le_bytes()).collect();
                std::fs::write(out_path, &bytes)?;
            }
        }
    }
    Ok(())
}

/// Insert into the executor's cache, deleting evicted files from disk.
fn apply_cache_insert(ctx: &mut ExecutorCtx, obj: ObjectId, bytes: u64) -> Vec<CacheEvent> {
    let events = ctx.cache.insert(obj, bytes);
    for ev in &events {
        if let CacheEvent::Evicted(victim) = ev {
            ctx.cache_dir.evict(*victim, ctx.format);
        }
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Task;
    use crate::scheduler::DispatchPolicy;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dd_live_drv_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// End-to-end live run without PJRT (synthetic tasks, real files).
    #[test]
    fn live_cluster_moves_real_bytes() {
        let root = tmp("move");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
        for i in 0..8 {
            store.populate(ObjectId(i), 5_000).unwrap();
        }
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        // Each object requested twice: second pass should hit caches.
        let tasks: Vec<Task> = (0..16)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 8)]))
            .collect();
        let cluster = LiveCluster::new(cfg, store, root.join("work"), None);
        let out = cluster.run(tasks).unwrap();
        assert_eq!(out.metrics.tasks_done, 16);
        assert_eq!(
            out.metrics.cache_hits + out.metrics.peer_hits + out.metrics.gpfs_misses,
            16
        );
        assert!(out.metrics.gpfs_misses <= 8 + 2, "most repeats hit caches");
        assert!(out.metrics.total_read_bytes() > 0);
        assert_eq!(
            out.metrics.stabilization_msgs, 0,
            "central index has no control plane"
        );
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn live_cluster_chord_index_accounts_cost() {
        let root = tmp("chord");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
        for i in 0..4 {
            store.populate(ObjectId(i), 2_000).unwrap();
        }
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.index.backend = crate::index::IndexBackend::Chord;
        let tasks: Vec<Task> = (0..8)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 4)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        assert_eq!(out.metrics.tasks_done, 8);
        assert_eq!(
            out.metrics.index_lookups, 8,
            "one charged lookup per single-input task"
        );
        assert!(
            out.metrics.stabilization_msgs > 0,
            "chord bootstrap joins must charge stabilization messages"
        );
        let _ = std::fs::remove_dir_all(root);
    }

    /// Elastic live run: the pool starts EMPTY (min_executors = 0), so
    /// nothing can run until the provisioner's first grant lands — real
    /// threads must come up mid-run for the workload to drain at all.
    #[test]
    fn live_cluster_elastic_pool_spawns_executors_mid_run() {
        let root = tmp("elastic");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
        for i in 0..6 {
            store.populate(ObjectId(i), 3_000).unwrap();
        }
        let mut cfg = Config::with_nodes(3);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        // Chord backend: mid-run joins are real membership churn, so the
        // run must charge control-plane stabilization traffic.
        cfg.index.backend = crate::index::IndexBackend::Chord;
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = crate::provisioner::AllocationPolicy::Adaptive;
        cfg.provisioner.min_executors = 0;
        cfg.provisioner.max_executors = 3;
        cfg.provisioner.allocation_latency_s = 0.05;
        cfg.provisioner.poll_interval_s = 0.01;
        cfg.provisioner.idle_release_s = 30.0; // no shrink before drain
        cfg.provisioner.queue_per_executor = 4;
        let tasks: Vec<Task> = (0..24)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 6)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        assert_eq!(out.metrics.tasks_done, 24);
        assert!(
            out.metrics.executors_joined > 0,
            "work only ran because executors joined mid-run"
        );
        assert!(out.metrics.alloc_requests > 0);
        assert!(out.metrics.peak_executors >= 1);
        assert!(out.metrics.peak_executors <= 3, "pool capped at max");
        assert!(!out.metrics.pool_timeline.is_empty());
        assert!(out.makespan_s >= 0.05, "first grant pays allocation latency");
        assert!(
            out.metrics.stabilization_msgs > 0,
            "chord must charge stabilization for mid-run membership churn"
        );
        let _ = std::fs::remove_dir_all(root);
    }

    /// Elastic pool with replication: joins get pre-staged, Stage
    /// messages flow through real executor threads, and the run drains
    /// with coherent accounting. Live timing is nondeterministic, so the
    /// assertions check mechanics and conservation, not exact counts.
    #[test]
    fn live_cluster_replication_runs_end_to_end() {
        let root = tmp("repl");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
        for i in 0..6 {
            store.populate(ObjectId(i), 3_000).unwrap();
        }
        let mut cfg = Config::with_nodes(3);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = crate::provisioner::AllocationPolicy::Adaptive;
        cfg.provisioner.min_executors = 1;
        cfg.provisioner.max_executors = 3;
        cfg.provisioner.allocation_latency_s = 0.05;
        cfg.provisioner.poll_interval_s = 0.01;
        cfg.provisioner.idle_release_s = 30.0;
        cfg.provisioner.queue_per_executor = 2;
        cfg.replication.enabled = true;
        cfg.replication.max_replicas = 3;
        cfg.replication.demand_threshold = 0.5;
        cfg.replication.ewma_alpha = 0.8;
        cfg.replication.evaluate_interval_s = 0.01;
        cfg.replication.prestage_top_k = 4;
        // Active teardown + a real (if generous) staging budget: the
        // Drop / deferral paths run end-to-end through real executor
        // threads. Live timing is nondeterministic, so assertions below
        // check mechanics and conservation, not exact counts.
        cfg.replication.release_threshold = 0.2;
        cfg.transfer.staging_budget = 0.9;
        let tasks: Vec<Task> = (0..24)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 6)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        assert_eq!(out.metrics.tasks_done, 24);
        assert_eq!(
            out.metrics.cache_hits + out.metrics.peer_hits + out.metrics.gpfs_misses,
            24,
            "every input resolved exactly once"
        );
        // Staging accounting is self-consistent: bytes only move when
        // transfers happened, and hits on replicas imply replicas exist.
        if out.metrics.replicas_created == 0 {
            assert_eq!(out.metrics.replica_hits, 0);
        }
        if out.metrics.replica_bytes_staged > 0 {
            assert!(out.metrics.c2c_bytes >= out.metrics.replica_bytes_staged);
        }
        // Per-class byte conservation: background classes carry exactly
        // the staged bytes; foreground carries every peer + GPFS copy
        // (c2c minus staged, plus gpfs) — nothing double- or un-counted.
        let m = &out.metrics;
        let staging_ix = TransferClass::Staging.index();
        let prestage_ix = TransferClass::Prestage.index();
        assert_eq!(
            m.class_bytes[staging_ix] + m.class_bytes[prestage_ix],
            m.replica_bytes_staged,
            "background class bytes must equal staged bytes"
        );
        assert_eq!(
            m.class_bytes[TransferClass::Foreground.index()] + m.replica_bytes_staged,
            m.c2c_bytes + m.gpfs_bytes,
            "foreground class bytes must cover peer + GPFS copies"
        );
        let _ = std::fs::remove_dir_all(root);
    }

    /// Weighted share policy end-to-end on real threads: staging copies
    /// run through the paced path and the egress ledger, the run drains,
    /// and per-class accounting stays conserved. Live timing is
    /// nondeterministic, so mechanics over exact counts.
    #[test]
    fn live_cluster_weighted_policy_paces_and_accounts() {
        use crate::transfer::SharePolicyKind;
        let root = tmp("weighted");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Fit).unwrap();
        for i in 0..6 {
            store.populate(ObjectId(i), 3_000).unwrap();
        }
        let mut cfg = Config::with_nodes(3);
        cfg.scheduler.policy = DispatchPolicy::MaxComputeUtil;
        cfg.replication.enabled = true;
        cfg.replication.max_replicas = 3;
        cfg.replication.demand_threshold = 0.5;
        cfg.replication.ewma_alpha = 0.8;
        cfg.replication.evaluate_interval_s = 0.01;
        cfg.transfer.share_policy = SharePolicyKind::Weighted;
        cfg.transfer.staging_budget = 1.0; // admit-but-throttle only
        let tasks: Vec<Task> = (0..24)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 6)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        assert_eq!(out.metrics.tasks_done, 24);
        assert_eq!(
            out.metrics.staging_deferred, 0,
            "budget 1.0 under weighted must never defer (throttle instead)"
        );
        let m = &out.metrics;
        assert_eq!(
            m.class_bytes[TransferClass::Staging.index()]
                + m.class_bytes[TransferClass::Prestage.index()],
            m.replica_bytes_staged
        );
        assert_eq!(
            m.class_bytes[TransferClass::Foreground.index()] + m.replica_bytes_staged,
            m.c2c_bytes + m.gpfs_bytes
        );
        let _ = std::fs::remove_dir_all(root);
    }

    #[test]
    fn live_cluster_no_caching_baseline() {
        let root = tmp("nocache");
        let mut store = LiveStore::create(root.join("gpfs"), DataFormat::Gz).unwrap();
        for i in 0..4 {
            store.populate(ObjectId(i), 5_000).unwrap();
        }
        let mut cfg = Config::with_nodes(2);
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        let tasks: Vec<Task> = (0..8)
            .map(|i| Task::with_inputs(TaskId(i), vec![ObjectId(i % 4)]))
            .collect();
        let out = LiveCluster::new(cfg, store, root.join("work"), None)
            .run(tasks)
            .unwrap();
        assert_eq!(out.metrics.gpfs_misses, 8, "no caching: all from store");
        assert_eq!(out.metrics.cache_hits, 0);
        let _ = std::fs::remove_dir_all(root);
    }
}
