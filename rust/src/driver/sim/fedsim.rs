//! Federated multi-site simulation on the parallel engine.
//!
//! A multi-site run builds one [`SimWorld`] per federation site and
//! executes them on [`ParallelEngine`] in conservative-lookahead rounds
//! (see [`crate::sim::parallel`]). Each site world owns its slice of
//! the cluster outright — executors, caches, a sharded dispatch core,
//! an elastic pool, its LAN and its WAN uplink — plus the home-site
//! resources (GPFS, the metadata server, the shared directory) when it
//! is site 0. Nothing cross-site is ever touched directly: it travels
//! as a timestamped [`SiteMsg`] through the engine's inter-site
//! channel, arriving one WAN one-way latency after it was sent.
//!
//! ## Roles
//!
//! **The home site (site 0)** runs the *frontend*: task arrivals land
//! there, are routed by the [`FederationScheduler`] against a
//! [`GlobalIndex`] fed by completion digests, and either submit locally
//! or ship to their run site as a [`SiteMsg::Submit`]. Site 0 also
//! serves every remote GPFS open/read/write and wrapper metadata op
//! ([`SiteMsg::MetaReq`]), and answers cross-site cache-location
//! queries ([`SiteMsg::HolderReq`]).
//!
//! **Every site** executes its tasks with the unmodified serial state
//! machine in `super` — the fed hooks only reroute the operations whose
//! backing resource lives at another site. Cross-site transfers are
//! *store-and-forward*: the sender runs its egress legs (disk/NIC/LAN +
//! WAN uplink), hands the bytes over the channel, and the receiver runs
//! its ingress legs — each half contends only with its own site's
//! traffic, which is what makes sites safely parallel (and is also a
//! reasonable physical model of a WAN relay). WAN bytes are metered on
//! the egress half only.
//!
//! ## Termination
//!
//! A site cannot see the global task count, so the frontend tracks
//! per-site completion counters (piggybacked on [`SiteMsg::Completion`]
//! and [`SiteMsg::Load`]) and broadcasts [`SiteMsg::Quiesce`] once every
//! task is done; periodic ticks stop rescheduling and the queues drain.
//!
//! ## Determinism
//!
//! Every per-site world is seeded exactly as the serial driver seeds
//! it, messages carry sender-derived ordering keys, and per-site
//! metrics merge in fixed site order — so the merged [`RunOutcome`] is
//! bit-for-bit identical at every `sim.threads` setting (pinned by
//! `tests/parallel_equivalence.rs`).

use super::{
    Ev, FlowPurpose, FlowTag, Metrics, Phase, ProvisionState, RunOutcome, RunTable, SimWorkloadSpec,
    SimWorld, DISPATCH_RATE,
};
use crate::cache::store::{CacheEvent, DataCache};
use crate::config::Config;
use crate::coordinator::metrics::ByteSource;
use crate::coordinator::task::Task;
use crate::federation::sched::SiteLoad;
use crate::federation::{FedCore, FederationScheduler, GlobalIndex, SiteId, Topology};
use crate::index::central::ExecutorId;
use crate::index::LookupCost;
use crate::provisioner::{ClusterProvider, Provisioner};
use crate::sim::engine::EventQueue;
use crate::sim::parallel::{OutMsg, ParallelEngine, SiteWorld};
use crate::sim::server::FifoServer;
use crate::storage::object::{Catalog, ObjectId};
use crate::storage::testbed::SimTestbed;
use crate::transfer::sim::SimTransferPlane;
use crate::transfer::{TransferClass, TransferPlane};
use crate::util::fxhash::{FxHashMap, FxHashSet};

/// Inter-site protocol. Every variant is delivered as
/// [`Ev::Msg`]`(from, msg)` at the destination, one WAN one-way latency
/// (plus any explicit extra) after it was sent. `rid` fields are run
/// ids in the *requesting* site's run table, echoed back opaquely.
#[derive(Debug)]
pub(super) enum SiteMsg {
    /// Frontend → run site: a routed task (submit time preserved so
    /// queue latency is charged from arrival, not from WAN delivery).
    Submit { task: Task, t_submit: f64 },
    /// Any site → frontend: a task finished; its buffered cache deltas
    /// plus a load/progress snapshot for the placement books.
    Completion {
        exec: ExecutorId,
        events: Vec<CacheEvent>,
        queued: usize,
        executors: usize,
        done: u64,
    },
    /// Any site → frontend: pool/queue change outside a completion
    /// (provisioner grew or shrank the pool), change-throttled.
    Load { queued: usize, executors: usize, done: u64 },
    /// Any site → frontend: cache deltas outside a completion
    /// (replication staged or dropped a copy).
    Digest { exec: ExecutorId, events: Vec<CacheEvent> },
    /// Any site → frontend: an executor's lease ended; purge it from
    /// the shared directory.
    Dropped { exec: ExecutorId },
    /// Remote site → frontend: which off-site executor caches `obj`?
    HolderReq { rid: u64, obj: ObjectId },
    /// Frontend → requester: the directory's answer plus the lookup
    /// bill (charged by the requester, whose metrics own the run).
    HolderResp {
        rid: u64,
        src: Option<ExecutorId>,
        cost: LookupCost,
    },
    /// Requester → holder site: ship `obj` out of `src`'s cache.
    FetchReq { rid: u64, obj: ObjectId, src: ExecutorId },
    /// Holder site → requester: the copy evaporated (or the lease
    /// ended) — fall back to persistent storage.
    FetchFail { rid: u64 },
    /// Holder site → requester: egress legs done; run your ingress.
    FetchData { rid: u64 },
    /// Remote site → home: run `ops` metadata operations (or `secs` of
    /// explicit service time when `ops == 0`) on the shared FS, then
    /// continue per `then`.
    MetaReq {
        rid: u64,
        ops: u32,
        secs: f64,
        then: MetaThen,
    },
    /// Home → requester: the metadata op completed (wrapper acks).
    MetaDone { rid: u64 },
    /// Home → requester: GPFS egress legs done; run your ingress.
    GpfsData { rid: u64 },
    /// Remote site → home: output bytes arrived over the WAN; run the
    /// metadata create and the home-side write legs.
    WriteData { rid: u64, bytes: u64 },
    /// Home → requester: the remote GPFS write is durable.
    WriteAck { rid: u64 },
    /// Frontend → everyone: all tasks are done, stop periodic ticks.
    Quiesce,
}

/// What the home site does after a [`SiteMsg::MetaReq`] completes.
#[derive(Debug, Clone, Copy)]
pub(super) enum MetaThen {
    /// Just acknowledge (wrapper pre/post ops).
    Ack,
    /// Start a GPFS read of `bytes` toward the requesting site.
    GpfsRead { bytes: u64 },
}

/// A continuation the home/holder site tracks on behalf of another
/// site's run: which requester to answer, and with what.
#[derive(Debug, Clone, Copy)]
struct RemoteOp {
    rid: u64,
    to: u32,
    bytes: u64,
    kind: RemoteKind,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum RemoteKind {
    /// Metadata done → `MetaDone`.
    MetaAck,
    /// Metadata done → start the GPFS egress flow.
    GpfsMeta,
    /// GPFS egress flow done → `GpfsData`.
    GpfsFlow,
    /// Peer egress flow done → `FetchData`.
    FetchFlow,
    /// Write metadata done → start the home-side write legs.
    WriteMeta,
    /// Home-side write legs done → `WriteAck`.
    WriteFlow,
}

/// The frontend: home-site-only routing state.
struct Frontend {
    sched: FederationScheduler,
    /// The shared directory, fed by completion digests from every site
    /// (loosely coherent, exactly like the serial global index).
    global: GlobalIndex,
    /// Last known queue/pool size per site (own entry refreshed
    /// inline; remote entries from `Completion`/`Load` messages).
    load: Vec<SiteLoad>,
    /// Completed-task counters per site (for quiesce detection).
    done: Vec<u64>,
    /// Tasks routed off their origin site.
    cross_site_tasks: u64,
    /// Accumulated placement-lookup bill.
    route_cost: LookupCost,
    quiesce_sent: bool,
}

/// Per-world federation scope: which site this world is, its outbox
/// into the engine's inter-site channel, and (at site 0) the frontend.
pub(super) struct FedScope {
    /// This world's site index.
    pub(super) site: u32,
    topo: Topology,
    outbox: Vec<OutMsg<SiteMsg>>,
    /// Per-sender message counter (ordering-key uniqueness).
    sent: u64,
    /// Continuations served for other sites, by remote-op id.
    remote: FxHashMap<u64, RemoteOp>,
    next_remote: u64,
    frontend: Option<Frontend>,
    /// Set once the frontend declares the run over; periodic ticks
    /// then stop rescheduling.
    pub(super) quiesced: bool,
    /// Last (queued, executors) reported via `Load` (change throttle).
    last_load: (usize, usize),
    /// Tasks completed at this site.
    done: u64,
}

impl FedScope {
    /// Stage `msg` for `dst`, arriving `extra` seconds plus one WAN
    /// one-way latency from now. The ordering key (bit 63, sender site,
    /// per-sender counter) makes equal-time deliveries reproducible
    /// regardless of routing (thread) order.
    fn send(&mut self, now: f64, extra: f64, dst: SiteId, msg: SiteMsg) {
        debug_assert_ne!(dst.index() as u32, self.site, "no self-sends");
        let at = now + extra + self.topo.wan_latency_s(SiteId(self.site), dst);
        self.sent += 1;
        let key = (1u64 << 63) | ((self.site as u64) << 48) | self.sent;
        self.outbox.push(OutMsg { dst: dst.index(), at, key, msg });
    }

    /// Register a continuation served on another site's behalf.
    fn alloc_remote(&mut self, op: RemoteOp) -> u64 {
        let xid = self.next_remote;
        self.next_remote += 1;
        self.remote.insert(xid, op);
        xid
    }
}

// ---- frontend bookkeeping ----------------------------------------------

/// Mirror a site's cache deltas into the shared directory.
fn frontend_mirror(fed: &mut FedScope, exec: ExecutorId, events: &[CacheEvent]) {
    let fe = fed.frontend.as_mut().expect("only the home site mirrors");
    for ev in events {
        match *ev {
            CacheEvent::Inserted(obj) => fe.global.insert(obj, exec),
            CacheEvent::Evicted(obj) => fe.global.remove(obj, exec),
        }
    }
}

/// Update one site's load/progress books; returns true exactly once —
/// when the last task completes and quiesce must be broadcast.
fn frontend_note(
    fed: &mut FedScope,
    total: u64,
    from: u32,
    queued: usize,
    executors: usize,
    done: u64,
) -> bool {
    let fe = fed.frontend.as_mut().expect("only the home site keeps books");
    fe.load[from as usize] = SiteLoad { queued, executors };
    // Counters only grow; max() guards against reordered reports.
    fe.done[from as usize] = fe.done[from as usize].max(done);
    let all: u64 = fe.done.iter().sum();
    if all >= total && !fe.quiesce_sent {
        fe.quiesce_sent = true;
        true
    } else {
        false
    }
}

/// Tell every non-home site the run is over.
fn broadcast_quiesce(fed: &mut FedScope, now: f64) {
    fed.quiesced = true;
    for s in 1..fed.topo.sites() as u32 {
        fed.send(now, 0.0, SiteId(s), SiteMsg::Quiesce);
    }
}

/// First off-site holder of `obj` per the shared directory, with the
/// lookup bill (same cost model as the serial `FedCore::remote_holder`).
fn frontend_remote_holder(
    fed: &FedScope,
    from: u32,
    obj: ObjectId,
) -> (Option<ExecutorId>, LookupCost) {
    let fe = fed.frontend.as_ref().expect("only the home site resolves");
    let (hit, cost) = fe.global.locate(SiteId(from), obj);
    let src = hit
        .filter(|&(s, _)| s != SiteId(from))
        .and_then(|(_, locs)| locs.first().copied());
    (src, cost)
}

// ---- hooks called from the serial state machine ------------------------

/// An arrival reached the frontend: place it and either submit locally
/// or ship it to its run site.
pub(super) fn route_arrival(w: &mut SimWorld, now: f64, task: Task, q: &mut EventQueue<Ev>) {
    let fed = w.fed.as_mut().expect("route_arrival is fed-only");
    let (chosen, cost) = {
        let fe = fed.frontend.as_mut().expect("arrivals land at the frontend");
        let origin = fe.sched.origin_site(task.id.0);
        let mut cost = LookupCost::ZERO;
        let inputs: Vec<(u64, Option<SiteId>)> = task
            .inputs
            .iter()
            .map(|&obj| {
                let bytes = w.core.catalog().size(obj).unwrap_or(0);
                let (hit, c) = fe.global.locate(origin, obj);
                cost.accumulate(c);
                (bytes, hit.map(|(s, _)| s))
            })
            .collect();
        fe.load[0] = SiteLoad {
            queued: w.core.site_queue_len(SiteId::HOME),
            executors: w.core.site(SiteId::HOME).executor_count(),
        };
        let chosen = fe.sched.choose(task.id.0, &inputs, &fe.load);
        if chosen != origin {
            fe.cross_site_tasks += 1;
        }
        fe.route_cost.accumulate(cost);
        (chosen, cost)
    };
    if chosen == SiteId::HOME {
        w.submit_times.insert(task.id, now);
        w.core.submit_at(SiteId::HOME, task);
        let orders = w.core.try_dispatch();
        w.execute_orders(now, orders, q);
    } else {
        // The routing lookup's latency delays the shipment, exactly as
        // it delays a local dispatch through the serial service.
        fed.send(now, cost.latency_s, chosen, SiteMsg::Submit { task, t_submit: now });
    }
}

/// Ship-data over the WAN: resolve an off-site cached copy of the
/// current input. At the home site the directory is local — resolve
/// inline and ask the holder site directly; elsewhere round-trip a
/// `HolderReq` through the home site. Returns false when the (local)
/// directory knows of no off-site copy and the caller should fall
/// through to persistent storage.
pub(super) fn request_remote(w: &mut SimWorld, now: f64, rid: u64) -> bool {
    let obj = {
        let run = w.runs.get(rid).unwrap();
        run.task.inputs[run.next_input]
    };
    let fed = w.fed.as_mut().expect("request_remote is fed-only");
    if fed.site == 0 {
        let (src, cost) = frontend_remote_holder(fed, 0, obj);
        let Some(src) = src else { return false };
        let dst = fed.topo.site_of(src);
        w.metrics.add_index_cost(cost);
        w.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
        fed.send(now, cost.latency_s, dst, SiteMsg::FetchReq { rid, obj, src });
    } else {
        w.runs.get_mut(rid).unwrap().phase = Phase::AwaitFlow;
        fed.send(now, 0.0, SiteId::HOME, SiteMsg::HolderReq { rid, obj });
    }
    true
}

/// Queue a home-site metadata operation for run `rid` (wrapper ops and
/// GPFS opens from non-home sites).
pub(super) fn meta_request(
    w: &mut SimWorld,
    now: f64,
    rid: u64,
    ops: u32,
    secs: f64,
    then: MetaThen,
) {
    let fed = w.fed.as_mut().expect("meta_request is fed-only");
    fed.send(now, 0.0, SiteId::HOME, SiteMsg::MetaReq { rid, ops, secs, then });
}

/// The sender half of a remote GPFS write finished: hand the bytes to
/// the home site.
pub(super) fn send_write(w: &mut SimWorld, now: f64, rid: u64, bytes: u64) {
    let fed = w.fed.as_mut().expect("send_write is fed-only");
    fed.send(now, 0.0, SiteId::HOME, SiteMsg::WriteData { rid, bytes });
}

/// A task completed at this site: update progress and feed the
/// frontend's directory and books.
pub(super) fn on_complete(w: &mut SimWorld, now: f64, exec: ExecutorId, events: Vec<CacheEvent>) {
    let fed = w.fed.as_mut().expect("on_complete fed hook");
    fed.done += 1;
    let done = fed.done;
    let own = SiteId(fed.site);
    let queued = w.core.site_queue_len(own);
    let executors = w.core.site(own).executor_count();
    let total = w.total_tasks;
    if fed.site == 0 {
        frontend_mirror(fed, exec, &events);
        if frontend_note(fed, total, 0, queued, executors, done) {
            broadcast_quiesce(fed, now);
        }
        let mut events = events;
        events.clear();
        if w.events_pool.len() < 4096 {
            w.events_pool.push(events);
        }
    } else {
        fed.send(
            now,
            0.0,
            SiteId::HOME,
            SiteMsg::Completion { exec, events, queued, executors, done },
        );
    }
}

/// Replication changed a cache outside a completion: keep the shared
/// directory loosely coherent.
pub(super) fn digest(w: &mut SimWorld, now: f64, exec: ExecutorId, events: &[CacheEvent]) {
    if events.is_empty() {
        return;
    }
    let Some(fed) = w.fed.as_mut() else { return };
    if fed.site == 0 {
        frontend_mirror(fed, exec, events);
    } else {
        fed.send(now, 0.0, SiteId::HOME, SiteMsg::Digest { exec, events: events.to_vec() });
    }
}

/// An executor's lease ended: purge it from the shared directory.
pub(super) fn note_executor_dropped(w: &mut SimWorld, now: f64, exec: ExecutorId) {
    let Some(fed) = w.fed.as_mut() else { return };
    if fed.site == 0 {
        let fe = fed.frontend.as_mut().expect("home site owns the frontend");
        fe.global.drop_executor(exec);
    } else {
        fed.send(now, 0.0, SiteId::HOME, SiteMsg::Dropped { exec });
    }
}

/// The pool or queue changed outside a completion: report to the
/// frontend's placement books (change-throttled).
pub(super) fn report_load(w: &mut SimWorld, now: f64) {
    let Some(fed) = w.fed.as_ref() else { return };
    if fed.site == 0 {
        return; // the frontend refreshes its own entry inline
    }
    let own = SiteId(fed.site);
    let queued = w.core.site_queue_len(own);
    let executors = w.core.site(own).executor_count();
    let fed = w.fed.as_mut().unwrap();
    if fed.last_load != (queued, executors) {
        fed.last_load = (queued, executors);
        let done = fed.done;
        fed.send(now, 0.0, SiteId::HOME, SiteMsg::Load { queued, executors, done });
    }
}

// ---- message / continuation handlers -----------------------------------

/// Handle one delivered inter-site message.
pub(super) fn handle_msg(
    w: &mut SimWorld,
    now: f64,
    from: u32,
    msg: SiteMsg,
    q: &mut EventQueue<Ev>,
) {
    match msg {
        SiteMsg::Submit { task, t_submit } => {
            let own = SiteId(w.fed.as_ref().unwrap().site);
            w.submit_times.insert(task.id, t_submit);
            w.core.submit_at(own, task);
            let orders = w.core.try_dispatch();
            w.execute_orders(now, orders, q);
        }
        SiteMsg::Completion { exec, events, queued, executors, done } => {
            let total = w.total_tasks;
            let fed = w.fed.as_mut().unwrap();
            frontend_mirror(fed, exec, &events);
            if frontend_note(fed, total, from, queued, executors, done) {
                broadcast_quiesce(fed, now);
            }
        }
        SiteMsg::Load { queued, executors, done } => {
            let total = w.total_tasks;
            let fed = w.fed.as_mut().unwrap();
            if frontend_note(fed, total, from, queued, executors, done) {
                broadcast_quiesce(fed, now);
            }
        }
        SiteMsg::Digest { exec, events } => {
            frontend_mirror(w.fed.as_mut().unwrap(), exec, &events);
        }
        SiteMsg::Dropped { exec } => {
            let fed = w.fed.as_mut().unwrap();
            let fe = fed.frontend.as_mut().expect("home site owns the frontend");
            fe.global.drop_executor(exec);
        }
        SiteMsg::HolderReq { rid, obj } => {
            let fed = w.fed.as_mut().unwrap();
            let (src, cost) = frontend_remote_holder(fed, from, obj);
            // The physical request/response hops already model the
            // lookup's WAN round trip; no extra delay on the answer.
            fed.send(now, 0.0, SiteId(from), SiteMsg::HolderResp { rid, src, cost });
        }
        SiteMsg::HolderResp { rid, src, cost } => {
            if w.runs.get(rid).is_none() {
                return;
            }
            w.metrics.add_index_cost(cost);
            match src {
                Some(src) => {
                    let obj = {
                        let run = w.runs.get(rid).unwrap();
                        run.task.inputs[run.next_input]
                    };
                    let fed = w.fed.as_mut().unwrap();
                    let dst = fed.topo.site_of(src);
                    fed.send(now, 0.0, dst, SiteMsg::FetchReq { rid, obj, src });
                }
                // No cached copy anywhere off-site: persistent storage.
                None => w.gpfs_open_input(now, rid, q),
            }
        }
        SiteMsg::FetchReq { rid, obj, src } => {
            // Re-validate against *this* site's live state: the copy may
            // have been evicted (or the lease ended) since the directory
            // answered — the serial Refetch arm does the same dance.
            let ok = w.caching
                && src < w.caches.len()
                && w.caches[src].contains(obj)
                && w.core.executors().binary_search(&src).is_ok();
            if ok {
                w.core.note_peer_fetch(obj, src);
                let bytes = w.cached_size(obj);
                let fed = w.fed.as_mut().unwrap();
                let xid = fed.alloc_remote(RemoteOp {
                    rid,
                    to: from,
                    bytes,
                    kind: RemoteKind::FetchFlow,
                });
                let rs = w.plane.testbed.peer_egress(src, SiteId(from));
                w.start_flow_over(
                    now,
                    FlowTag::Remote(xid),
                    TransferClass::Foreground,
                    &rs,
                    bytes,
                    true,
                    q,
                );
            } else {
                let fed = w.fed.as_mut().unwrap();
                fed.send(now, 0.0, SiteId(from), SiteMsg::FetchFail { rid });
            }
        }
        SiteMsg::FetchFail { rid } => {
            if w.runs.get(rid).is_some() {
                w.gpfs_open_input(now, rid, q);
            }
        }
        SiteMsg::FetchData { rid } => {
            let Some(run) = w.runs.get(rid) else { return };
            debug_assert_eq!(run.phase, Phase::AwaitFlow);
            let obj = run.task.inputs[run.next_input];
            let exec = run.exec;
            let bytes = w.cached_size(obj);
            // Peer fetches only exist with caching on: ingress includes
            // the destination disk write.
            let rs = w.plane.testbed.site_ingress(exec, true);
            w.start_flow_over(
                now,
                FlowTag::Run(rid, FlowPurpose::FetchPeer),
                TransferClass::Foreground,
                &rs,
                bytes,
                false,
                q,
            );
        }
        SiteMsg::MetaReq { rid, ops, secs, then } => {
            let (bytes, kind) = match then {
                MetaThen::Ack => (0, RemoteKind::MetaAck),
                MetaThen::GpfsRead { bytes } => (bytes, RemoteKind::GpfsMeta),
            };
            let fed = w.fed.as_mut().unwrap();
            let xid = fed.alloc_remote(RemoteOp { rid, to: from, bytes, kind });
            let done = if ops > 0 {
                w.plane.testbed.metadata.submit(now, ops)
            } else {
                w.plane.testbed.metadata.submit_secs(now, secs)
            };
            q.at(done, Ev::MetaStep(xid));
        }
        SiteMsg::MetaDone { rid } => {
            if w.runs.get(rid).is_some() {
                w.step(now, rid, q);
            }
        }
        SiteMsg::GpfsData { rid } => {
            let Some(run) = w.runs.get(rid) else { return };
            debug_assert_eq!(run.phase, Phase::AwaitFlow);
            let obj = run.task.inputs[run.next_input];
            let exec = run.exec;
            let bytes = w.stored_size(obj);
            let caching = w.caching;
            let rs = w.plane.testbed.site_ingress(exec, caching);
            w.start_flow_over(
                now,
                FlowTag::Run(rid, FlowPurpose::FetchGpfs),
                TransferClass::Foreground,
                &rs,
                bytes,
                false,
                q,
            );
        }
        SiteMsg::WriteData { rid, bytes } => {
            let fed = w.fed.as_mut().unwrap();
            let xid = fed.alloc_remote(RemoteOp {
                rid,
                to: from,
                bytes,
                kind: RemoteKind::WriteMeta,
            });
            let done = w.plane.testbed.metadata.submit(now, w.cfg.shared_fs.meta_ops_open);
            q.at(done, Ev::MetaStep(xid));
        }
        SiteMsg::WriteAck { rid } => {
            let Some(run) = w.runs.get_mut(rid) else { return };
            let bytes = run.task.output_bytes;
            run.phase = Phase::WrapperPost;
            w.metrics.add_bytes(ByteSource::GpfsWrite, bytes);
            w.after_output(now, rid, q);
        }
        SiteMsg::Quiesce => {
            w.fed.as_mut().unwrap().quiesced = true;
        }
    }
}

/// The home metadata server finished a remote site's operation.
pub(super) fn meta_step(w: &mut SimWorld, now: f64, xid: u64, q: &mut EventQueue<Ev>) {
    let fed = w.fed.as_mut().expect("meta_step is fed-only");
    let Some(op) = fed.remote.get(&xid).copied() else { return };
    match op.kind {
        RemoteKind::MetaAck => {
            fed.remote.remove(&xid);
            fed.send(now, 0.0, SiteId(op.to), SiteMsg::MetaDone { rid: op.rid });
        }
        RemoteKind::GpfsMeta => {
            fed.remote.get_mut(&xid).unwrap().kind = RemoteKind::GpfsFlow;
            let rs = w.plane.testbed.gpfs_egress(SiteId(op.to));
            w.start_flow_over(
                now,
                FlowTag::Remote(xid),
                TransferClass::Foreground,
                &rs,
                op.bytes,
                true,
                q,
            );
        }
        RemoteKind::WriteMeta => {
            fed.remote.get_mut(&xid).unwrap().kind = RemoteKind::WriteFlow;
            let rs = w.plane.testbed.gpfs_write_ingress();
            // WAN bytes were metered on the sender's egress half.
            w.start_flow_over(
                now,
                FlowTag::Remote(xid),
                TransferClass::Foreground,
                &rs,
                op.bytes,
                false,
                q,
            );
        }
        _ => debug_assert!(false, "flow kinds resolve via remote_flow_done"),
    }
}

/// A flow served on another site's behalf completed: answer them.
pub(super) fn remote_flow_done(w: &mut SimWorld, now: f64, xid: u64) {
    let fed = w.fed.as_mut().expect("remote flows are fed-only");
    let Some(op) = fed.remote.remove(&xid) else { return };
    let msg = match op.kind {
        RemoteKind::FetchFlow => SiteMsg::FetchData { rid: op.rid },
        RemoteKind::GpfsFlow => SiteMsg::GpfsData { rid: op.rid },
        RemoteKind::WriteFlow => SiteMsg::WriteAck { rid: op.rid },
        _ => {
            debug_assert!(false, "meta kinds resolve via meta_step");
            return;
        }
    };
    fed.send(now, 0.0, SiteId(op.to), msg);
}

// ---- engine integration ------------------------------------------------

impl SiteWorld for SimWorld {
    type Msg = SiteMsg;

    fn drain_outbox(&mut self) -> Vec<OutMsg<SiteMsg>> {
        match self.fed.as_mut() {
            Some(fed) => std::mem::take(&mut fed.outbox),
            None => Vec::new(),
        }
    }

    fn msg_event(from: u32, msg: SiteMsg) -> Ev {
        Ev::Msg(from, msg)
    }
}

/// Build one world per site and run them on the parallel engine.
pub(super) fn run_federated(cfg: Config, spec: SimWorkloadSpec, catalog: Catalog) -> RunOutcome {
    let t0 = std::time::Instant::now();
    let topo = Topology::from_config(&cfg);
    let n_sites = topo.sites();
    let nodes = cfg.testbed.nodes;
    let capacity = (cfg.testbed.cpus_per_node * cfg.scheduler.tasks_per_cpu).max(1);
    let replicating = cfg.replication.enabled && spec.caching;
    let repl_interval_s = cfg.replication.evaluate_interval_s.max(1e-3);
    let total_tasks = spec.tasks.len() as u64;

    // Initial pool sizes are known without building the worlds (static:
    // the full site slice; elastic: the warm floor) — the frontend's
    // load books start from them.
    let init_execs: Vec<usize> = (0..n_sites)
        .map(|s| {
            let site_nodes = topo.site_nodes(SiteId(s as u32));
            if cfg.provisioner.enabled {
                cfg.provisioner.min_executors.min(site_nodes)
            } else {
                site_nodes
            }
        })
        .collect();

    let mut engine: ParallelEngine<SimWorld> = ParallelEngine::new(cfg.sim.threads);
    for s in 0..n_sites {
        let sid = SiteId(s as u32);
        let range = topo.executor_range(sid);
        let mut core = FedCore::new(&cfg, catalog.clone());
        let mut provs = Vec::new();
        if cfg.provisioner.enabled {
            assert!(
                nodes > 0 && cfg.provisioner.max_executors > 0,
                "elastic pool needs at least one allocatable executor"
            );
            let site_nodes = range.len();
            let mut pcfg = cfg.provisioner.clone();
            pcfg.max_executors = pcfg.max_executors.min(site_nodes);
            pcfg.min_executors = pcfg.min_executors.min(site_nodes);
            let mut drp = Provisioner::new(pcfg.clone());
            let mut cluster =
                ClusterProvider::with_range(range.clone(), cfg.provisioner.allocation_latency_s);
            let warm = pcfg.min_executors.min(site_nodes);
            if warm > 0 {
                let grant = cluster.allocate(0.0, warm);
                for &e in &grant.nodes {
                    core.register_executor_with(e, capacity);
                }
                drp.on_allocated(grant.nodes.len());
            }
            provs.push(ProvisionState {
                site: s as u32,
                drp,
                cluster,
                interval_s: cfg.provisioner.poll_interval_s.max(1e-3),
                capacity,
                pending_allocs: FxHashMap::default(),
                last_tick: 0.0,
            });
        } else {
            for e in range.clone() {
                core.register_executor_with(e, capacity);
            }
        }
        if replicating {
            core.enable_replication(&cfg.replication);
        }

        // Full-length cache vector (global executor ids index it), but
        // only this site's slice ever holds real content.
        let mut caches: Vec<DataCache> =
            (0..nodes).map(|e| SimWorld::fresh_cache(&cfg, e)).collect();
        for &(exec, obj) in &spec.prewarm {
            if topo.site_of(exec) != sid {
                continue;
            }
            let stored = catalog.size(obj).unwrap_or(1);
            let bytes = (stored as f64 * spec.expansion).ceil() as u64;
            let events = caches[exec].insert(obj, bytes);
            core.apply_cache_events(exec, &events);
        }

        // The frontend lives at site 0: the placement scheduler, the
        // shared directory (seeded with every site's prewarm), and the
        // per-site load/progress books.
        let frontend = (s == 0).then(|| {
            let mut global = GlobalIndex::new(topo.clone());
            for &(exec, obj) in &spec.prewarm {
                global.insert(obj, exec);
            }
            Frontend {
                sched: FederationScheduler::new(
                    topo.clone(),
                    cfg.federation.placement,
                    cfg.federation.skew,
                    cfg.federation.queue_weight_s,
                    cfg.seed,
                ),
                global,
                load: init_execs
                    .iter()
                    .map(|&executors| SiteLoad { queued: 0, executors })
                    .collect(),
                done: vec![0; n_sites],
                cross_site_tasks: 0,
                route_cost: LookupCost::ZERO,
                quiesce_sent: false,
            }
        });

        let pending_tasks: Vec<Option<Task>> = if s == 0 {
            spec.tasks.iter().map(|(_, t)| Some(t.clone())).collect()
        } else {
            Vec::new()
        };

        let world = SimWorld {
            cfg: cfg.clone(),
            caching: spec.caching,
            format: spec.format,
            expansion: spec.expansion,
            core,
            plane: SimTransferPlane::new(SimTestbed::new(&cfg), &cfg.transfer),
            caches,
            metrics: Metrics::new(),
            dispatch_server: FifoServer::new(1.0 / DISPATCH_RATE),
            pending_tasks,
            runs: RunTable::new(),
            flow_map: FxHashMap::default(),
            flow_version: 0,
            staged_replicas: (0..nodes).map(|_| FxHashSet::default()).collect(),
            submit_times: FxHashMap::default(),
            first_dispatch: None,
            total_tasks,
            provs,
            next_alloc_id: 0,
            events_pool: Vec::new(),
            fed: Some(FedScope {
                site: s as u32,
                topo: topo.clone(),
                outbox: Vec::new(),
                sent: 0,
                remote: FxHashMap::default(),
                next_remote: 0,
                frontend,
                quiesced: total_tasks == 0,
                last_load: (usize::MAX, usize::MAX),
                done: 0,
            }),
        };
        engine.add_site(world, topo.lookahead_in(sid));
        if cfg.provisioner.enabled {
            engine.schedule(s, 0.0, Ev::ProvisionTick(s as u32));
        }
        if replicating {
            engine.schedule(s, repl_interval_s, Ev::ReplTick);
        }
    }

    // Every arrival lands at the frontend site.
    for (i, (t, _)) in spec.tasks.iter().enumerate() {
        engine.schedule(0, *t, Ev::Arrive(i as u32));
    }

    engine.run();
    let events = engine.events_processed();

    // Harvest per-site, then merge in fixed site order (deterministic
    // regardless of thread count).
    let mut merged: Option<Metrics> = None;
    for (s, mut state) in engine.into_sites().into_iter().enumerate() {
        let w = &mut state.world;
        let control = w.core.take_index_control();
        w.metrics.add_control_traffic(control);
        w.metrics.staging_deferred = w.plane.stats().deferred;
        let shard_stats = w.core.site(SiteId(s as u32)).shard_stats();
        w.metrics.harvest_shard_stats(&shard_stats);
        w.metrics.peak_executors = w.metrics.peak_executors.max(w.core.executor_count());
        if s == 0 {
            let fed = w.fed.as_mut().unwrap();
            let fe = fed.frontend.as_mut().unwrap();
            w.metrics.cross_site_tasks = fe.cross_site_tasks;
            let route_cost = std::mem::replace(&mut fe.route_cost, LookupCost::ZERO);
            w.metrics.add_index_cost(route_cost);
        }
        debug_assert!(w.runs.is_empty(), "tasks stuck in flight at quiesce");
        debug_assert!(
            w.fed.as_ref().unwrap().remote.is_empty(),
            "remote ops stuck in flight at quiesce"
        );
        match merged.as_mut() {
            None => merged = Some(w.metrics.clone()),
            Some(m) => m.merge(&w.metrics),
        }
    }
    let metrics = merged.expect("at least one site");
    let makespan = (metrics.t_end - metrics.t_start).max(0.0);
    RunOutcome {
        metrics,
        makespan_s: makespan,
        events,
        wall_s: t0.elapsed().as_secs_f64(),
        sample_checksums: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_worlds_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<SimWorld>();
        assert_send::<SiteMsg>();
    }

    #[test]
    fn ordering_keys_are_unique_and_keyed() {
        let cfg = {
            let mut c = Config::with_nodes(8);
            c.split_into_sites(2);
            c
        };
        let topo = Topology::from_config(&cfg);
        let mut fed = FedScope {
            site: 1,
            topo,
            outbox: Vec::new(),
            sent: 0,
            remote: FxHashMap::default(),
            next_remote: 0,
            frontend: None,
            quiesced: false,
            last_load: (usize::MAX, usize::MAX),
            done: 0,
        };
        fed.send(1.0, 0.0, SiteId::HOME, SiteMsg::Quiesce);
        fed.send(1.0, 0.0, SiteId::HOME, SiteMsg::Quiesce);
        assert_eq!(fed.outbox.len(), 2);
        assert_ne!(fed.outbox[0].key, fed.outbox[1].key);
        for m in &fed.outbox {
            assert!(m.key & (1 << 63) != 0, "message keys carry bit 63");
            assert!(m.at > 1.0, "WAN latency delays delivery");
        }
    }

    #[test]
    fn frontend_quiesces_exactly_once_when_all_sites_report_done() {
        let cfg = {
            let mut c = Config::with_nodes(8);
            c.split_into_sites(2);
            c
        };
        let topo = Topology::from_config(&cfg);
        let mut fed = FedScope {
            site: 0,
            topo: topo.clone(),
            outbox: Vec::new(),
            sent: 0,
            remote: FxHashMap::default(),
            next_remote: 0,
            frontend: Some(Frontend {
                sched: FederationScheduler::new(topo, cfg.federation.placement, 0.0, 1.0, 1),
                global: GlobalIndex::new(Topology::from_config(&cfg)),
                load: vec![SiteLoad { queued: 0, executors: 4 }; 2],
                done: vec![0; 2],
                cross_site_tasks: 0,
                route_cost: LookupCost::ZERO,
                quiesce_sent: false,
            }),
            quiesced: false,
            last_load: (usize::MAX, usize::MAX),
            done: 0,
        };
        assert!(!frontend_note(&mut fed, 10, 0, 0, 4, 6));
        assert!(frontend_note(&mut fed, 10, 1, 0, 4, 4), "last report quiesces");
        assert!(!frontend_note(&mut fed, 10, 1, 0, 4, 4), "only once");
        broadcast_quiesce(&mut fed, 5.0);
        assert!(fed.quiesced);
        assert_eq!(fed.outbox.len(), 1, "one Quiesce per non-home site");
    }
}
