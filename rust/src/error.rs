//! Crate-wide error types.
//!
//! Coarse-grained by subsystem; everything converges to [`Error`] at the
//! public API boundary. Internal modules may use more specific enums.

use thiserror::Error;

/// Top-level error type for the data-diffusion library.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration file / preset problems.
    #[error("config error: {0}")]
    Config(String),

    /// A referenced data object is unknown to the persistent store.
    #[error("unknown data object: {0}")]
    UnknownObject(String),

    /// Executor-side failure (fetch, cache, execute).
    #[error("executor {executor} failed: {msg}")]
    Executor { executor: usize, msg: String },

    /// The PJRT runtime failed to load or execute an artifact.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Workload generation / trace parsing problems.
    #[error("workload error: {0}")]
    Workload(String),

    /// Live-mode filesystem failures.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Coordinator protocol violation (e.g. completion for unknown task).
    #[error("protocol error: {0}")]
    Protocol(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
