//! Crate-wide error types.
//!
//! Coarse-grained by subsystem; everything converges to [`Error`] at the
//! public API boundary. Internal modules may use more specific enums.
//!
//! `Display`/`Error` are hand-implemented — the offline crate set has no
//! `thiserror` (see `rust/Cargo.toml`).

use std::fmt;

/// Top-level error type for the data-diffusion library.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / preset problems.
    Config(String),

    /// A referenced data object is unknown to the persistent store.
    UnknownObject(String),

    /// Executor-side failure (fetch, cache, execute).
    Executor {
        /// The executor that failed.
        executor: usize,
        /// What went wrong.
        msg: String,
    },

    /// The PJRT runtime failed to load or execute an artifact.
    Runtime(String),

    /// Artifact manifest missing or malformed.
    Artifact(String),

    /// Workload generation / trace parsing problems.
    Workload(String),

    /// Live-mode filesystem failures.
    Io(std::io::Error),

    /// Coordinator protocol violation (e.g. completion for unknown task).
    Protocol(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::UnknownObject(m) => write!(f, "unknown data object: {m}"),
            Error::Executor { executor, msg } => {
                write!(f, "executor {executor} failed: {msg}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Workload(m) => write!(f, "workload error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_subsystem_prefixes() {
        assert_eq!(
            Error::Config("bad key".into()).to_string(),
            "config error: bad key"
        );
        assert_eq!(
            Error::Executor {
                executor: 3,
                msg: "fetch failed".into()
            }
            .to_string(),
            "executor 3 failed: fetch failed"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(e.source().is_some());
    }
}
