//! Tiny measurement harness (criterion is unavailable offline).
//!
//! Used by the `cargo bench` targets (`rust/benches/*`, all
//! `harness = false`). Provides warmup + repeated timed runs with
//! mean/stddev reporting, and a black-box to defeat optimization.

use std::hint;
use std::time::Instant;

use super::stats::Summary;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Result of a [`time_it`] run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Bench label.
    pub name: String,
    /// Per-iteration wall time statistics, in seconds.
    pub secs: Summary,
}

impl BenchResult {
    /// Mean iterations/second.
    pub fn rate(&self) -> f64 {
        let m = self.secs.mean();
        if m > 0.0 {
            1.0 / m
        } else {
            0.0
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12} ±{:>10}  ({:.1} iters/s, n={})",
            self.name,
            crate::util::units::fmt_secs(self.secs.mean()),
            crate::util::units::fmt_secs(self.secs.stddev()),
            self.rate(),
            self.secs.count(),
        )
    }
}

/// Time `f` for `iters` measured iterations after `warmup` unmeasured ones.
pub fn time_it<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        secs.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        secs,
    }
}

/// Measure the total wall time of a single run of `f` (for end-to-end
/// simulations where one run is already statistically meaningful).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Print the standard bench header used by all figure benches.
pub fn bench_header(title: &str, paper_expectation: &str) {
    println!("\n=== {title} ===");
    println!("paper: {paper_expectation}");
    println!("{}", "-".repeat(96));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iterations() {
        let mut calls = 0usize;
        let r = time_it("noop", 2, 10, || {
            calls += 1;
            black_box(());
        });
        assert_eq!(calls, 12);
        assert_eq!(r.secs.count(), 10);
        assert!(r.secs.mean() >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
