//! Fast non-cryptographic hashing for hot-path maps.
//!
//! The profile of a 128-CPU simulated run shows SipHash (std's default)
//! costing ~19% of wall time — the dispatcher's window scan and the flow
//! bookkeeping hash small integer keys millions of times. This is the
//! FxHash algorithm (rustc's internal hasher: multiply-rotate mixing);
//! no DoS resistance, which is fine for internal integer keys.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// rustc-style Fx hasher.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }
}

/// HashMap with the Fx hasher.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// HashSet with the Fx hasher.
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_like_std() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i, i * 2);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&i), Some(&(i * 2)));
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn distributes_sequential_keys() {
        // Cheap sanity: sequential u64 keys should not all collide in the
        // low bits hashbrown uses.
        use std::hash::{BuildHasher, BuildHasherDefault};
        let b: BuildHasherDefault<FxHasher> = Default::default();
        let mut low7 = FxHashSet::default();
        for i in 0..128u64 {
            low7.insert(b.hash_one(i) & 0x7f);
        }
        assert!(low7.len() > 64, "poor low-bit distribution: {}", low7.len());
    }

    #[test]
    fn string_keys_work() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("stack_n8".into(), 8);
        m.insert("stack_n16".into(), 16);
        assert_eq!(m.get("stack_n8"), Some(&8));
    }
}
