//! Minimal vendored gzip (RFC 1952) — stored DEFLATE blocks only.
//!
//! The live storage backend needs real `.gz` files on disk so the GZ
//! configurations move real bytes through real file I/O, but the offline
//! crate set has no `flate2`. This module implements the gzip container
//! with **stored** (uncompressed) DEFLATE blocks: framing, CRC-32 and
//! length verification are all real, while the payload is carried
//! verbatim.
//!
//! Consequences, by design:
//!
//! * [`compress`] output is slightly *larger* than the input (18 bytes of
//!   gzip framing + 5 bytes per 64 KiB block). Live-mode GZ experiments
//!   therefore exercise the format's *mechanics* (separate cached
//!   decompressed form, integrity checks, per-fetch decode step), not its
//!   size reduction — the simulator still models the paper's 2 MB→6 MB
//!   ratio through catalog sizes, which is what every figure uses.
//! * [`decompress`] accepts only streams whose DEFLATE blocks are stored
//!   and byte-aligned — i.e. our own output (plus any other
//!   stored-block encoder). Huffman-coded streams from a general gzip
//!   are rejected with a clear error rather than mis-decoded.
//!
//! Swapping a real DEFLATE back in (ROADMAP open item) only has to
//! replace these two functions.

use crate::error::{Error, Result};

/// gzip magic + method: 0x1f 0x8b, CM=8 (deflate).
const MAGIC: [u8; 2] = [0x1f, 0x8b];
/// Largest payload of one stored DEFLATE block.
const STORED_MAX: usize = 0xFFFF;

fn bad(msg: &str) -> Error {
    Error::Io(std::io::Error::new(std::io::ErrorKind::InvalidData, msg))
}

/// CRC-32 (IEEE, reflected) over `data` — the gzip trailer checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Wrap `data` in a gzip stream (stored DEFLATE blocks).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let blocks = data.len().div_ceil(STORED_MAX).max(1);
    let mut out = Vec::with_capacity(18 + data.len() + 5 * blocks);
    out.extend_from_slice(&MAGIC);
    out.push(8); // CM = deflate
    out.push(0); // FLG = none
    out.extend_from_slice(&[0, 0, 0, 0]); // MTIME = unknown
    out.push(0); // XFL
    out.push(255); // OS = unknown

    if data.is_empty() {
        // One final empty stored block.
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xFF, 0xFF]);
    } else {
        let mut chunks = data.chunks(STORED_MAX).peekable();
        while let Some(chunk) = chunks.next() {
            let bfinal = if chunks.peek().is_none() { 1u8 } else { 0 };
            out.push(bfinal); // BTYPE=00 in bits 1-2; rest of byte is padding
            let len = chunk.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(chunk);
        }
    }

    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

/// Unwrap a gzip stream produced by a stored-block encoder; verifies the
/// header, block framing, CRC-32 and length trailer.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 18 {
        return Err(bad("gzip stream truncated"));
    }
    if data[..2] != MAGIC {
        return Err(bad("not a gzip stream (bad magic)"));
    }
    if data[2] != 8 {
        return Err(bad("unsupported gzip compression method"));
    }
    let flg = data[3];
    // Skip MTIME (4), XFL, OS.
    let mut pos = 10usize;
    let body_end = data.len() - 8; // trailer: CRC32 + ISIZE
    if flg & 0x04 != 0 {
        // FEXTRA
        if pos + 2 > body_end {
            return Err(bad("gzip FEXTRA truncated"));
        }
        let xlen = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    for flag in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: NUL-terminated strings.
        if flg & flag != 0 {
            loop {
                if pos >= body_end {
                    return Err(bad("gzip header string unterminated"));
                }
                pos += 1;
                if data[pos - 1] == 0 {
                    break;
                }
            }
        }
    }
    if flg & 0x02 != 0 {
        // FHCRC
        pos += 2;
    }
    if pos > body_end {
        return Err(bad("gzip header overruns stream"));
    }

    // Inflate: stored, byte-aligned blocks only (see module docs).
    let mut out = Vec::with_capacity(data.len());
    loop {
        if pos >= body_end {
            return Err(bad("deflate stream truncated (no final block)"));
        }
        let hdr = data[pos];
        pos += 1;
        let bfinal = hdr & 1;
        let btype = (hdr >> 1) & 3;
        if btype != 0 {
            return Err(bad(
                "unsupported deflate block (vendored inflate handles stored blocks only)",
            ));
        }
        if pos + 4 > body_end {
            return Err(bad("stored block header truncated"));
        }
        let len = u16::from_le_bytes([data[pos], data[pos + 1]]);
        let nlen = u16::from_le_bytes([data[pos + 2], data[pos + 3]]);
        if nlen != !len {
            return Err(bad("stored block LEN/NLEN mismatch"));
        }
        pos += 4;
        let len = len as usize;
        if pos + len > body_end {
            return Err(bad("stored block payload truncated"));
        }
        out.extend_from_slice(&data[pos..pos + len]);
        pos += len;
        if bfinal == 1 {
            break;
        }
    }
    if pos != body_end {
        return Err(bad("trailing garbage between deflate stream and trailer"));
    }

    let crc = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    let isize_ = u32::from_le_bytes([data[pos + 4], data[pos + 5], data[pos + 6], data[pos + 7]]);
    if crc32(&out) != crc {
        return Err(bad("gzip CRC-32 mismatch"));
    }
    if out.len() as u32 != isize_ {
        return Err(bad("gzip ISIZE mismatch"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_empty_small_large() {
        for data in [
            Vec::new(),
            b"hello gzip".to_vec(),
            // Spans two stored blocks (> 64 KiB).
            (0..70_000u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>(),
        ] {
            let gz = compress(&data);
            assert_eq!(decompress(&gz).unwrap(), data);
        }
    }

    #[test]
    fn framing_overhead_is_small_and_fixed() {
        let data = vec![7u8; 1000];
        let gz = compress(&data);
        assert_eq!(gz.len(), 18 + 5 + 1000, "header+trailer+block framing");
    }

    #[test]
    fn crc32_known_vector() {
        // The classic check value: CRC-32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn corruption_is_detected() {
        let mut gz = compress(b"payload under test");
        let last = gz.len() - 12; // a payload byte, not framing
        gz[last] ^= 0xFF;
        assert!(decompress(&gz).is_err(), "CRC must catch payload flips");
    }

    #[test]
    fn bad_magic_and_truncation_rejected() {
        assert!(decompress(b"not gzip").is_err());
        let gz = compress(b"abcdef");
        assert!(decompress(&gz[..gz.len() - 4]).is_err());
        let mut notgz = gz.clone();
        notgz[0] = 0;
        assert!(decompress(&notgz).is_err());
    }

    #[test]
    fn huffman_blocks_rejected_not_misdecoded() {
        let mut gz = compress(b"x");
        // Flip BTYPE of the first block to 01 (fixed Huffman).
        gz[10] |= 0b010;
        assert!(decompress(&gz).is_err());
    }
}
