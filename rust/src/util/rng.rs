//! Deterministic PRNG utilities.
//!
//! The offline environment has no `rand` crate, so we carry a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256++ for the
//! stream. Both are public-domain algorithms (Blackman & Vigna). All
//! randomness in workload generation, the Random eviction policy, and the
//! property-test harness flows through [`Rng`], so every experiment is
//! reproducible from a single `u64` seed.

/// SplitMix64 step — used to expand a seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator. Fast, 256-bit state, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's method (unbiased enough
    /// for simulation purposes; bound must be non-zero).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; slight modulo bias is irrelevant here
        // compared to running a rejection loop in hot paths.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` index in `[0, len)`.
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample an exponentially distributed value with the given mean.
    /// Used for arrival processes in workload generation.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_reaches_all_small_values() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_roughly_correct() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(13);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
