//! Minimal `log` backend: stderr logger with env-controlled level.
//!
//! `DD_LOG=debug cargo run ...` — levels: error, warn, info, debug, trace.
//! Kept deliberately tiny; the offline environment has no `env_logger`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::time::Instant;

use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();

struct StderrLogger {
    max: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once; safe to call repeatedly (later calls no-op).
pub fn init() {
    let level = match std::env::var("DD_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("info") => Level::Info,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Warn,
    };
    START.get_or_init(Instant::now);
    let logger = Box::new(StderrLogger { max: level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
