//! Hand-rolled argument parsing (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options up front so `--help` output is
//! consistent across the CLI, examples, and benches.

use std::collections::BTreeMap;

/// Declared option for help text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without leading dashes.
    pub name: &'static str,
    /// Value placeholder (`""` for boolean flags).
    pub value: &'static str,
    /// Help line.
    pub help: &'static str,
    /// Default rendered in help.
    pub default: &'static str,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    ///
    /// `bool_flags` lists options that take no value; everything else
    /// starting with `--` consumes the next token (or `=value`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, bool_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        // Treat as a flag after all (tolerant parsing).
                        args.flags.push(name.to_string());
                    } else {
                        let v = it.next().unwrap();
                        args.opts.insert(name.to_string(), v);
                    }
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env(bool_flags: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), bool_flags)
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parsed numeric option with default; exits with a message on a
    /// malformed value (CLI surface, not library surface).
    pub fn num_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a number, got {v:?}");
                std::process::exit(2);
            }),
        }
    }

    /// Comma-separated list of numbers, e.g. `--nodes 1,2,4,8`.
    pub fn num_list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: --{name} expects comma-separated numbers, got {v:?}");
                        std::process::exit(2);
                    })
                })
                .collect(),
        }
    }
}

/// Render standard help text and exit if `--help` was passed.
pub fn help_if_requested(args: &Args, bin: &str, about: &str, specs: &[OptSpec]) {
    if !args.flag("help") {
        return;
    }
    println!("{bin} — {about}\n");
    println!("USAGE: {bin} [OPTIONS]\n");
    for s in specs {
        let lhs = if s.value.is_empty() {
            format!("--{}", s.name)
        } else {
            format!("--{} <{}>", s.name, s.value)
        };
        let def = if s.default.is_empty() {
            String::new()
        } else {
            format!(" [default: {}]", s.default)
        };
        println!("  {lhs:28} {}{def}", s.help);
    }
    std::process::exit(0);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse(&["--nodes", "64", "--policy=lru"], &[]);
        assert_eq!(a.get("nodes"), Some("64"));
        assert_eq!(a.get("policy"), Some("lru"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["run", "--verbose", "trace.tsv"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "trace.tsv"]);
    }

    #[test]
    fn numeric_defaults() {
        let a = parse(&["--n", "5"], &[]);
        assert_eq!(a.num_or("n", 0u32), 5);
        assert_eq!(a.num_or("missing", 7u32), 7);
    }

    #[test]
    fn num_lists() {
        let a = parse(&["--nodes", "1,2,4"], &[]);
        assert_eq!(a.num_list_or("nodes", &[9usize]), vec![1, 2, 4]);
        assert_eq!(a.num_list_or("other", &[9usize]), vec![9]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--dry-run", "--nodes", "2"], &[]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("nodes"), Some("2"));
    }
}
