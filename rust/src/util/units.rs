//! Byte / bandwidth / time units and human-readable formatting.
//!
//! The paper mixes units freely (Mb/s, Gb/s, MB, GB, tasks/sec); all
//! internal accounting here is in **bytes** and **bits-per-second** with
//! explicit conversion helpers so calibration constants in
//! [`crate::config`] can be written the way the paper quotes them.

/// Bits per second — the unit the paper quotes bandwidth in.
pub type BitsPerSec = f64;

/// One kilobyte (decimal, as used for file sizes in the paper).
pub const KB: u64 = 1_000;
/// One megabyte.
pub const MB: u64 = 1_000_000;
/// One gigabyte.
pub const GB: u64 = 1_000_000_000;

/// Convert Mb/s (megabits per second) to bits per second.
#[inline]
pub const fn mbps(v: f64) -> BitsPerSec {
    v * 1e6
}

/// Convert Gb/s (gigabits per second) to bits per second.
#[inline]
pub const fn gbps(v: f64) -> BitsPerSec {
    v * 1e9
}

/// Seconds needed to move `bytes` at `rate` bits/sec.
#[inline]
pub fn transfer_secs(bytes: u64, rate: BitsPerSec) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    (bytes as f64 * 8.0) / rate
}

/// Aggregate throughput in bits/sec for `bytes` moved in `secs`.
#[inline]
pub fn throughput_bps(bytes: u64, secs: f64) -> BitsPerSec {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 * 8.0 / secs
}

/// Format a byte count with binary-free, paper-style units (1 MB = 10^6 B).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e12 {
        format!("{:.2}TB", b / 1e12)
    } else if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2}KB", b / 1e3)
    } else {
        format!("{bytes}B")
    }
}

/// Format a bandwidth in the paper's Mb/s / Gb/s convention.
pub fn fmt_bps(rate: BitsPerSec) -> String {
    if rate >= 1e9 {
        format!("{:.2}Gb/s", rate / 1e9)
    } else if rate >= 1e6 {
        format!("{:.1}Mb/s", rate / 1e6)
    } else if rate >= 1e3 {
        format!("{:.1}Kb/s", rate / 1e3)
    } else {
        format!("{rate:.0}b/s")
    }
}

/// Format seconds compactly (ms below 1s, h/m/s above).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 0.001 {
        format!("{:.0}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else if secs < 7200.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{:.1}h", secs / 3600.0)
    }
}

/// Parse a size string like `100MB`, `1GB`, `1B`, `10KB` (paper notation).
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = s.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_uppercase().as_str() {
        "B" => 1.0,
        "KB" => 1e3,
        "MB" => 1e6,
        "GB" => 1e9,
        "TB" => 1e12,
        _ => return None,
    };
    Some((num * mult) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_basics() {
        // 1 GB at 1 Gb/s = 8 seconds.
        assert!((transfer_secs(GB, gbps(1.0)) - 8.0).abs() < 1e-9);
        assert!(transfer_secs(GB, 0.0).is_infinite());
    }

    #[test]
    fn throughput_inverse_of_transfer() {
        let secs = transfer_secs(100 * MB, mbps(500.0));
        let tput = throughput_bps(100 * MB, secs);
        assert!((tput - mbps(500.0)).abs() < 1.0);
    }

    #[test]
    fn parse_paper_sizes() {
        assert_eq!(parse_size("1B"), Some(1));
        assert_eq!(parse_size("1KB"), Some(1_000));
        assert_eq!(parse_size("10KB"), Some(10_000));
        assert_eq!(parse_size("100KB"), Some(100_000));
        assert_eq!(parse_size("1MB"), Some(1_000_000));
        assert_eq!(parse_size("10MB"), Some(10_000_000));
        assert_eq!(parse_size("100MB"), Some(100_000_000));
        assert_eq!(parse_size("1GB"), Some(1_000_000_000));
        assert_eq!(parse_size("2.5MB"), Some(2_500_000));
        assert_eq!(parse_size("bogus"), None);
    }

    #[test]
    fn formatting_round_trips_visually() {
        assert_eq!(fmt_bytes(100 * MB), "100.00MB");
        assert_eq!(fmt_bps(gbps(3.4)), "3.40Gb/s");
        assert_eq!(fmt_bps(mbps(500.0)), "500.0Mb/s");
        assert_eq!(fmt_secs(0.0005), "500us");
    }
}
