//! Streaming statistics accumulators used by metrics and the bench harness.

/// Online mean/variance (Welford) plus min/max and count.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (0 if < 2 observations).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum (NaN if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum (NaN if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Merge another summary into this one.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean = (n1 * self.mean + n2 * other.mean) / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Hard cap on stored samples: past it the sample is decimated (every
/// other stored value dropped, keep-stride doubled), bounding memory at
/// 10⁷–10⁸-task simulations while keeping a deterministic, evenly
/// strided subsample. 2²⁰ f64s ≈ 8 MiB per estimator.
const MAX_SAMPLES: usize = 1 << 20;

/// Percentile estimator over a stored sample — exact below
/// [`MAX_SAMPLES`] observations (every existing figure and test), a
/// deterministic strided subsample beyond. For running moments over
/// unbounded streams, prefer [`Summary`].
#[derive(Debug, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
    /// Keep every `stride`-th observation (1 until the buffer first
    /// fills, then doubling at each decimation).
    stride: u64,
    /// Observations offered, kept or not.
    seen: u64,
}

impl Default for Percentiles {
    fn default() -> Self {
        Percentiles::new()
    }
}

impl Percentiles {
    /// Empty sample set.
    pub fn new() -> Self {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
            stride: 1,
            seen: 0,
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        let keep = self.seen % self.stride == 0;
        self.seen += 1;
        if !keep {
            return;
        }
        self.samples.push(x);
        self.sorted = false;
        if self.samples.len() >= MAX_SAMPLES {
            // Drop every other stored sample. Kept arrivals were the
            // multiples of `stride`, so the survivors are exactly the
            // multiples of the doubled stride — one uniform subsample,
            // regardless of when decimations happened. (If a quantile
            // call sorted the buffer first, this decimates the sorted
            // order instead — an equally valid stratified thinning.)
            let mut i = 0usize;
            self.samples.retain(|_| {
                let k = i % 2 == 0;
                i += 1;
                k
            });
            self.stride *= 2;
        }
    }

    /// Number of *stored* observations (== observations offered until
    /// the first decimation).
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Number of observations offered, kept or not.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Exact p-quantile by linear interpolation (p in [0, 1]).
    pub fn quantile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let idx = p.clamp(0.0, 1.0) * (self.samples.len() - 1) as f64;
        let lo = idx.floor() as usize;
        let hi = idx.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let frac = idx - lo as f64;
            self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
        }
    }

    /// Median shorthand.
    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    /// Merge another estimator's stored sample into this one (the
    /// federated driver combines per-site latency estimators this way).
    /// Deterministic: appends `other`'s kept samples in order, then
    /// re-decimates while over the cap. The merged set is a union of
    /// two (possibly differently) strided subsamples — still a valid
    /// sample of the combined stream, exact while both were exact.
    pub fn merge(&mut self, other: &Percentiles) {
        self.seen += other.seen;
        if other.samples.is_empty() {
            return;
        }
        self.sorted = false;
        self.stride = self.stride.max(other.stride);
        self.samples.extend_from_slice(&other.samples);
        while self.samples.len() >= MAX_SAMPLES {
            let mut i = 0usize;
            self.samples.retain(|_| {
                let k = i % 2 == 0;
                i += 1;
                k
            });
            self.stride *= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic dataset is ~2.138.
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in data.iter().enumerate() {
            all.add(x);
            if i % 2 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.stddev() - all.stddev()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_exact() {
        let mut p = Percentiles::new();
        for x in 1..=100 {
            p.add(x as f64);
        }
        assert!((p.median() - 50.5).abs() < 1e-12);
        assert!((p.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((p.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((p.quantile(0.99) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn empty_edge_cases() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert!(s.min().is_nan());
        let mut p = Percentiles::new();
        assert!(p.median().is_nan());
    }

    #[test]
    fn percentiles_merge_equals_combined_below_cap() {
        let mut all = Percentiles::new();
        let mut a = Percentiles::new();
        let mut b = Percentiles::new();
        for i in 0..1000 {
            let x = (i as f64).cos() * 5.0;
            all.add(x);
            if i % 3 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.seen(), all.seen());
        assert_eq!(a.count(), all.count());
        assert!((a.median() - all.median()).abs() < 1e-12);
        assert!((a.quantile(0.99) - all.quantile(0.99)).abs() < 1e-12);
    }

    #[test]
    fn percentiles_decimation_bounds_memory_and_preserves_quantiles() {
        let mut p = Percentiles::new();
        let n: u64 = (1 << 21) + 123;
        for i in 0..n {
            p.add(i as f64);
        }
        assert_eq!(p.seen(), n);
        assert!(p.count() < (1 << 20), "count={}", p.count());
        // Uniform ramp: the strided subsample keeps quantiles within a
        // fraction of a percent of exact.
        let med = p.median();
        assert!((med / (n as f64 / 2.0) - 1.0).abs() < 1e-3, "med={med}");
        let p99 = p.quantile(0.99);
        assert!((p99 / (0.99 * n as f64) - 1.0).abs() < 1e-3, "p99={p99}");
    }
}
