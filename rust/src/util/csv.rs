//! Minimal CSV writing for bench/figure output.
//!
//! Every figure bench writes both a human-readable table to stdout and a
//! CSV under `results/` so plots can be regenerated externally. No quoting
//! support is needed — all our fields are numbers and simple identifiers.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// A CSV file under construction.
pub struct CsvWriter {
    path: PathBuf,
    buf: String,
    cols: usize,
}

impl CsvWriter {
    /// Start a CSV with the given header columns.
    pub fn new<P: AsRef<Path>>(path: P, header: &[&str]) -> Self {
        let mut buf = String::new();
        buf.push_str(&header.join(","));
        buf.push('\n');
        CsvWriter {
            path: path.as_ref().to_path_buf(),
            buf,
            cols: header.len(),
        }
    }

    /// Append one row; panics if the column count mismatches the header
    /// (a bench bug we want loudly, not silently).
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.cols,
            "CSV row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        self.buf.push_str(&fields.join(","));
        self.buf.push('\n');
    }

    /// Convenience: format anything Display into a row.
    pub fn rowf(&mut self, fields: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v);
    }

    /// Write the file (creating parent directories) and return its path.
    pub fn finish(self) -> std::io::Result<PathBuf> {
        if let Some(parent) = self.path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(&self.path)?;
        f.write_all(self.buf.as_bytes())?;
        Ok(self.path)
    }
}

/// Default output directory for bench results.
pub fn results_dir() -> PathBuf {
    std::env::var("DD_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dd_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::new(&path, &["a", "b"]);
        w.rowf(&[&1, &2.5]);
        w.rowf(&[&"x", &"y"]);
        let p = w.finish().unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\nx,y\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    #[should_panic(expected = "CSV row")]
    fn panics_on_column_mismatch() {
        let mut w = CsvWriter::new("/tmp/never.csv", &["a", "b"]);
        w.row(&["only-one".into()]);
    }
}
