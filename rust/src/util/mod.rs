//! Shared utilities: PRNG, units, stats, CSV, gzip, bench harness, CLI,
//! logging.
//!
//! The offline crate set has no `rand`/`clap`/`criterion`/`serde`/
//! `flate2`, so this module carries small, tested substitutes that the
//! rest of the crate (and the benches/examples) build on.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fxhash;
pub mod gzip;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod units;
