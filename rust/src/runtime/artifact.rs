//! Artifact manifest: what `make artifacts` produced.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.tsv` alongside the
//! HLO text files. TSV (not JSON) because this offline environment has no
//! serde; the format is a stable two-column-plus-params contract:
//!
//! ```text
//! # kind  name          file               params...
//! stack    stack_n8     stack_n8.hlo.txt   n=8  h=100  w=100
//! radec2xy radec2xy_m128 radec2xy_m128.hlo.txt m=128
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Artifact kind (`stack`, `radec2xy`).
    pub kind: String,
    /// Unique name (`stack_n8`).
    pub name: String,
    /// HLO text file path (absolute, resolved against the manifest dir).
    pub path: PathBuf,
    /// Key=value parameters (`n`, `h`, `w`, `m`, ...).
    pub params: BTreeMap<String, u64>,
}

impl Artifact {
    /// Numeric parameter, erroring with context if missing.
    pub fn param(&self, key: &str) -> Result<u64> {
        self.params
            .get(key)
            .copied()
            .ok_or_else(|| Error::Artifact(format!("artifact {} missing param {key}", self.name)))
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All artifacts in manifest order.
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `manifest.tsv` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() < 3 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected >=3 fields",
                    lineno + 1
                )));
            }
            let mut params = BTreeMap::new();
            for kv in &fields[3..] {
                if let Some((k, v)) = kv.split_once('=') {
                    let v: u64 = v.parse().map_err(|_| {
                        Error::Artifact(format!("manifest line {}: bad param {kv}", lineno + 1))
                    })?;
                    params.insert(k.to_string(), v);
                }
            }
            artifacts.push(Artifact {
                kind: fields[0].to_string(),
                name: fields[1].to_string(),
                path: dir.join(fields[2]),
                params,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// All artifacts of a kind.
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a Artifact> {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }

    /// The stacking variant with the smallest `n >= depth` (tasks pad the
    /// unused slots with zero weights), or the largest variant if `depth`
    /// exceeds them all (callers then loop in chunks).
    pub fn stack_variant(&self, depth: u32) -> Result<&Artifact> {
        let mut best: Option<&Artifact> = None;
        let mut largest: Option<&Artifact> = None;
        for a in self.of_kind("stack") {
            let n = a.param("n")?;
            if largest.map(|l| n > l.params["n"]).unwrap_or(true) {
                largest = Some(a);
            }
            if n >= depth as u64 && best.map(|b| n < b.params["n"]).unwrap_or(true) {
                best = Some(a);
            }
        }
        best.or(largest)
            .ok_or_else(|| Error::Artifact("no stack artifacts in manifest".into()))
    }
}

/// Default artifacts directory: `$DD_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dd_manifest_{tag}_{}", std::process::id()))
    }

    #[test]
    fn parses_and_selects_variants() {
        let dir = tmp("ok");
        write_manifest(
            &dir,
            "# header\nstack\tstack_n4\tstack_n4.hlo.txt\tn=4\th=100\tw=100\n\
             stack\tstack_n16\tstack_n16.hlo.txt\tn=16\th=100\tw=100\n\
             radec2xy\tradec2xy_m128\tradec2xy_m128.hlo.txt\tm=128\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.stack_variant(3).unwrap().name, "stack_n4");
        assert_eq!(m.stack_variant(4).unwrap().name, "stack_n4");
        assert_eq!(m.stack_variant(5).unwrap().name, "stack_n16");
        // Over the largest: fall back to the largest.
        assert_eq!(m.stack_variant(99).unwrap().name, "stack_n16");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_lines_rejected() {
        let dir = tmp("bad");
        write_manifest(&dir, "stack\tonly_two_fields\n");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "stack\tx\tx.hlo.txt\tn=abc\n");
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
