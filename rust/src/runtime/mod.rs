//! PJRT runtime: load AOT artifacts and execute them on the request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file(artifacts/*.hlo.txt)` → compile →
//! execute. Executables are compiled once per artifact and cached; the
//! hot path is literal marshaling + `execute`.
//!
//! Python never runs here — the HLO text was produced once at build time
//! by `python/compile/aot.py` (see that file for why HLO *text* is the
//! interchange format).
//!
//! The `xla` crate is not available in the offline build, so the real
//! engine is gated behind the `pjrt` cargo feature (see `rust/Cargo.toml`
//! for how to enable it). Without the feature a stub [`PjrtEngine`] with
//! the same API always fails to load — callers that already tolerate
//! missing artifacts (the live driver, `falkon live`, the integration
//! tests) degrade exactly as they do when `make artifacts` has not run.

pub mod artifact;

pub use artifact::{artifacts_dir, Artifact, Manifest};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};

/// A stacking request: raw int16 cutouts plus per-image calibration.
#[derive(Debug, Clone)]
pub struct StackRequest {
    /// `[n, h, w]` raw pixels, row-major.
    pub raw: Vec<i16>,
    /// `[n]` sky levels.
    pub sky: Vec<f32>,
    /// `[n]` calibration gains.
    pub cal: Vec<f32>,
    /// `[n, 2]` (dx, dy) sub-pixel shifts.
    pub shifts: Vec<f32>,
    /// `[n]` coadd weights (0 = padded slot).
    pub weights: Vec<f32>,
    /// Stack depth n (images actually present, before padding).
    pub depth: usize,
}

impl StackRequest {
    /// Validate the request against an (n, h, w) variant shape and pad
    /// it to exactly `n` slots with zero weights.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn padded(&self, n: usize, h: usize, w: usize) -> Result<StackRequest> {
        let d = self.depth;
        if d == 0 || d > n {
            return Err(Error::Runtime(format!("depth {d} not in 1..={n}")));
        }
        let px = h * w;
        if self.raw.len() != d * px
            || self.sky.len() != d
            || self.cal.len() != d
            || self.shifts.len() != d * 2
            || self.weights.len() != d
        {
            return Err(Error::Runtime(format!(
                "stack request shape mismatch: depth {d}, roi {h}x{w}, raw {} sky {} cal {} shifts {} weights {}",
                self.raw.len(), self.sky.len(), self.cal.len(), self.shifts.len(), self.weights.len()
            )));
        }
        let mut out = self.clone();
        out.raw.resize(n * px, 0);
        out.sky.resize(n, 0.0);
        out.cal.resize(n, 0.0);
        out.shifts.resize(n * 2, 0.0);
        out.weights.resize(n, 0.0);
        Ok(out)
    }
}

#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    n: usize,
    h: usize,
    w: usize,
}

#[cfg(feature = "pjrt")]
struct CompiledRadec {
    exe: xla::PjRtLoadedExecutable,
    m: usize,
}

/// The PJRT engine: one CPU client + compiled executables per artifact.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    stacks: HashMap<String, Compiled>,
    radec: Option<CompiledRadec>,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Load the manifest and compile every stacking artifact eagerly, so
    /// the request path never compiles.
    pub fn load(dir: &Path) -> Result<PjrtEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut stacks = HashMap::new();
        for a in manifest.of_kind("stack") {
            let proto = xla::HloModuleProto::from_text_file(
                a.path
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            stacks.insert(
                a.name.clone(),
                Compiled {
                    exe,
                    n: a.param("n")? as usize,
                    h: a.param("h")? as usize,
                    w: a.param("w")? as usize,
                },
            );
        }
        if stacks.is_empty() {
            return Err(Error::Artifact(
                "manifest has no stack artifacts — run `make artifacts`".into(),
            ));
        }
        // The coordinate-transform artifact (the paper's radec2xy phase).
        let mut radec = None;
        if let Some(a) = manifest.of_kind("radec2xy").next() {
            let proto = xla::HloModuleProto::from_text_file(
                a.path
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            radec = Some(CompiledRadec {
                exe: client.compile(&comp)?,
                m: a.param("m")? as usize,
            });
        }
        Ok(PjrtEngine {
            client,
            manifest,
            stacks,
            radec,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<PjrtEngine> {
        Self::load(&artifacts_dir())
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available stack variant depths, ascending.
    pub fn stack_depths(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.stacks.values().map(|c| c.n).collect();
        v.sort_unstable();
        v
    }

    /// ROI geometry (h, w) of the stacking artifacts.
    pub fn roi_shape(&self) -> (usize, usize) {
        let c = self.stacks.values().next().expect("nonempty by load()");
        (c.h, c.w)
    }

    /// Convert up to `m` (ra, dec) coordinates (radians) to tangent-plane
    /// pixel (x, y) via the `radec2xy` artifact — the paper's coordinate
    /// phase, executed before any image I/O. Inputs beyond the artifact's
    /// batch size are processed in chunks; short batches are padded (the
    /// projection is elementwise, so padding is inert).
    pub fn radec2xy(
        &self,
        ra: &[f32],
        dec: &[f32],
        ra0: f32,
        dec0: f32,
        scale: f32,
    ) -> Result<Vec<(f32, f32)>> {
        if ra.len() != dec.len() {
            return Err(Error::Runtime(format!(
                "ra/dec length mismatch: {} vs {}",
                ra.len(),
                dec.len()
            )));
        }
        let compiled = self
            .radec
            .as_ref()
            .ok_or_else(|| Error::Artifact("no radec2xy artifact in manifest".into()))?;
        let m = compiled.m;
        let mut out = Vec::with_capacity(ra.len());
        for (ra_chunk, dec_chunk) in ra.chunks(m).zip(dec.chunks(m)) {
            let n = ra_chunk.len();
            let mut ra_pad = ra_chunk.to_vec();
            let mut dec_pad = dec_chunk.to_vec();
            ra_pad.resize(m, 0.0);
            dec_pad.resize(m, 0.0);
            let result = compiled.exe.execute::<xla::Literal>(&[
                xla::Literal::vec1(&ra_pad),
                xla::Literal::vec1(&dec_pad),
                xla::Literal::scalar(ra0),
                xla::Literal::scalar(dec0),
                xla::Literal::scalar(scale),
            ])?[0][0]
                .to_literal_sync()?;
            let xy = result.to_tuple1()?.to_vec::<f32>()?;
            for i in 0..n {
                out.push((xy[i * 2], xy[i * 2 + 1]));
            }
        }
        Ok(out)
    }

    /// Execute one stacking: picks the smallest variant that fits the
    /// request depth, pads, marshals, runs on PJRT, returns the `[h*w]`
    /// stacked image.
    pub fn stack(&self, req: &StackRequest) -> Result<Vec<f32>> {
        let variant = self.manifest.stack_variant(req.depth as u32)?;
        let compiled = self
            .stacks
            .get(&variant.name)
            .ok_or_else(|| Error::Artifact(format!("uncompiled variant {}", variant.name)))?;
        let (n, h, w) = (compiled.n, compiled.h, compiled.w);
        let padded = req.padded(n, h, w)?;

        // Raw int16 pixels go in as an S16 literal built from bytes (the
        // xla crate has no i16 NativeType, but supports S16 array data).
        let raw_bytes: Vec<u8> = padded.raw.iter().flat_map(|v| v.to_le_bytes()).collect();
        let raw = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S16,
            &[n, h, w],
            &raw_bytes,
        )?;
        let sky = xla::Literal::vec1(&padded.sky);
        let cal = xla::Literal::vec1(&padded.cal);
        let shifts = xla::Literal::vec1(&padded.shifts).reshape(&[n as i64, 2])?;
        let weights = xla::Literal::vec1(&padded.weights);

        let result = compiled
            .exe
            .execute::<xla::Literal>(&[raw, sky, cal, shifts, weights])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Stub engine compiled when the `pjrt` feature is off: same API, always
/// fails to load, so callers take their existing no-artifacts path.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtEngine {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtEngine {
    /// Always fails: PJRT execution requires the `pjrt` feature (which
    /// needs the `xla` crate — see `rust/Cargo.toml`). Manifest problems
    /// are still reported first so diagnostics stay accurate.
    pub fn load(dir: &Path) -> Result<PjrtEngine> {
        let _ = Manifest::load(dir)?;
        Err(Error::Runtime(
            "built without the `pjrt` feature: PJRT compute is unavailable \
             (see rust/Cargo.toml to enable it)"
                .into(),
        ))
    }

    /// Load from the default artifacts directory (always fails — stub).
    pub fn load_default() -> Result<PjrtEngine> {
        Self::load(&artifacts_dir())
    }

    /// PJRT platform name (stub).
    pub fn platform(&self) -> String {
        "unavailable (pjrt feature off)".into()
    }

    /// Available stack variant depths (stub: none).
    pub fn stack_depths(&self) -> Vec<usize> {
        Vec::new()
    }

    /// ROI geometry (stub: zero).
    pub fn roi_shape(&self) -> (usize, usize) {
        (0, 0)
    }

    /// Coordinate transform (stub: always errors).
    pub fn radec2xy(
        &self,
        _ra: &[f32],
        _dec: &[f32],
        _ra0: f32,
        _dec0: f32,
        _scale: f32,
    ) -> Result<Vec<(f32, f32)>> {
        Err(Error::Runtime("pjrt feature off".into()))
    }

    /// Stacking execution (stub: always errors).
    pub fn stack(&self, _req: &StackRequest) -> Result<Vec<f32>> {
        Err(Error::Runtime("pjrt feature off".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_validates_shapes() {
        let req = StackRequest {
            raw: vec![0; 2 * 4],
            sky: vec![0.0; 2],
            cal: vec![1.0; 2],
            shifts: vec![0.0; 4],
            weights: vec![1.0; 2],
            depth: 2,
        };
        let p = req.padded(4, 2, 2).unwrap();
        assert_eq!(p.raw.len(), 16);
        assert_eq!(p.weights, vec![1.0, 1.0, 0.0, 0.0]);
        assert!(req.padded(1, 2, 2).is_err(), "depth beyond variant");
        let mut bad = req.clone();
        bad.sky.pop();
        assert!(bad.padded(4, 2, 2).is_err());
    }
}
