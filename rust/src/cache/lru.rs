//! LRU eviction — the paper's default policy.
//!
//! Implemented as a monotone-timestamp map plus a BTreeMap "recency index"
//! (timestamp → object). Both update and victim selection are O(log n);
//! no unsafe linked-list juggling needed at our scales (≤ tens of
//! thousands of resident objects per executor).

use std::collections::BTreeMap;

use crate::util::fxhash::FxHashMap;

use super::policy::PolicyCore;
use crate::storage::object::ObjectId;

/// Least-recently-used policy state.
#[derive(Debug, Default)]
pub struct Lru {
    clock: u64,
    stamp: FxHashMap<ObjectId, u64>,
    by_stamp: BTreeMap<u64, ObjectId>,
}

impl Lru {
    /// Empty LRU state.
    pub fn new() -> Self {
        Lru::default()
    }

    fn touch(&mut self, id: ObjectId) {
        self.clock += 1;
        if let Some(old) = self.stamp.insert(id, self.clock) {
            self.by_stamp.remove(&old);
        }
        self.by_stamp.insert(self.clock, id);
    }
}

impl PolicyCore for Lru {
    fn on_insert(&mut self, id: ObjectId) {
        self.touch(id);
    }

    fn on_access(&mut self, id: ObjectId) {
        self.touch(id);
    }

    fn on_remove(&mut self, id: ObjectId) {
        if let Some(old) = self.stamp.remove(&id) {
            self.by_stamp.remove(&old);
        }
    }

    fn victim(&mut self) -> Option<ObjectId> {
        self.by_stamp.values().next().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let mut p = Lru::new();
        for i in 0..4 {
            p.on_insert(ObjectId(i));
        }
        p.on_access(ObjectId(0)); // 0 becomes most recent
        assert_eq!(p.victim(), Some(ObjectId(1)));
        p.on_remove(ObjectId(1));
        assert_eq!(p.victim(), Some(ObjectId(2)));
    }

    #[test]
    fn access_reorders() {
        let mut p = Lru::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        p.on_access(ObjectId(1));
        assert_eq!(p.victim(), Some(ObjectId(2)));
    }

    #[test]
    fn empty_has_no_victim() {
        let mut p = Lru::new();
        assert_eq!(p.victim(), None);
        p.on_insert(ObjectId(9));
        p.on_remove(ObjectId(9));
        assert_eq!(p.victim(), None);
    }
}
