//! Random eviction: evict a uniformly random resident object.

use std::collections::HashMap;

use super::policy::PolicyCore;
use crate::storage::object::ObjectId;
use crate::util::rng::Rng;

/// Random policy state: a swap-remove vector for O(1) uniform sampling.
#[derive(Debug)]
pub struct Random {
    ids: Vec<ObjectId>,
    pos: HashMap<ObjectId, usize>,
    rng: Rng,
}

impl Random {
    /// Random policy with a deterministic seed (experiments must replay).
    pub fn new(seed: u64) -> Self {
        Random {
            ids: Vec::new(),
            pos: HashMap::new(),
            rng: Rng::new(seed),
        }
    }
}

impl PolicyCore for Random {
    fn on_insert(&mut self, id: ObjectId) {
        if !self.pos.contains_key(&id) {
            self.pos.insert(id, self.ids.len());
            self.ids.push(id);
        }
    }

    fn on_access(&mut self, _id: ObjectId) {
        // Random ignores accesses.
    }

    fn on_remove(&mut self, id: ObjectId) {
        if let Some(i) = self.pos.remove(&id) {
            let last = self.ids.pop().unwrap();
            if last != id {
                self.ids[i] = last;
                self.pos.insert(last, i);
            }
        }
    }

    fn victim(&mut self) -> Option<ObjectId> {
        if self.ids.is_empty() {
            None
        } else {
            Some(self.ids[self.rng.index(self.ids.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_resident() {
        let mut p = Random::new(1);
        for i in 0..10 {
            p.on_insert(ObjectId(i));
        }
        for _ in 0..100 {
            let v = p.victim().unwrap();
            assert!(v.0 < 10);
        }
    }

    #[test]
    fn removal_maintains_sampling_set() {
        let mut p = Random::new(2);
        for i in 0..5 {
            p.on_insert(ObjectId(i));
        }
        for i in 0..4 {
            p.on_remove(ObjectId(i));
        }
        for _ in 0..20 {
            assert_eq!(p.victim(), Some(ObjectId(4)));
        }
        p.on_remove(ObjectId(4));
        assert_eq!(p.victim(), None);
    }

    #[test]
    fn roughly_uniform() {
        let mut p = Random::new(3);
        for i in 0..4 {
            p.on_insert(ObjectId(i));
        }
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[p.victim().unwrap().0 as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }
}
