//! Per-executor data caches (§3.2.2).
//!
//! Each executor manages its own cache with a local eviction policy and
//! reports content changes to the dispatcher's central index. The paper
//! implements four classic policies — Random, FIFO, LRU, LFU — and runs
//! all experiments with LRU.
//!
//! The cache tracks object *metadata* (ids and sizes); actual bytes live
//! on local disk (live mode) or are implicit (sim mode). Capacity is in
//! bytes, eviction returns the evicted ids so the executor can delete the
//! files and notify the index.

pub mod fifo;
pub mod lfu;
pub mod lru;
pub mod policy;
pub mod random;
pub mod store;

pub use policy::EvictionPolicy;
pub use store::{CacheEvent, DataCache};
