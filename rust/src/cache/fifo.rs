//! FIFO eviction: evict in insertion order, ignoring accesses.

use std::collections::{HashSet, VecDeque};

use super::policy::PolicyCore;
use crate::storage::object::ObjectId;

/// First-in-first-out policy state.
#[derive(Debug, Default)]
pub struct Fifo {
    order: VecDeque<ObjectId>,
    resident: HashSet<ObjectId>,
}

impl Fifo {
    /// Empty FIFO state.
    pub fn new() -> Self {
        Fifo::default()
    }
}

impl PolicyCore for Fifo {
    fn on_insert(&mut self, id: ObjectId) {
        if self.resident.insert(id) {
            self.order.push_back(id);
        }
    }

    fn on_access(&mut self, _id: ObjectId) {
        // FIFO ignores accesses by definition.
    }

    fn on_remove(&mut self, id: ObjectId) {
        self.resident.remove(&id);
        // Lazy removal: stale ids are skipped in `victim`.
    }

    fn victim(&mut self) -> Option<ObjectId> {
        while let Some(&front) = self.order.front() {
            if self.resident.contains(&front) {
                return Some(front);
            }
            self.order.pop_front();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_in_insert_order_despite_access() {
        let mut p = Fifo::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        p.on_access(ObjectId(1)); // must not matter
        assert_eq!(p.victim(), Some(ObjectId(1)));
        p.on_remove(ObjectId(1));
        assert_eq!(p.victim(), Some(ObjectId(2)));
    }

    #[test]
    fn out_of_order_removal_skipped() {
        let mut p = Fifo::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        p.on_insert(ObjectId(3));
        p.on_remove(ObjectId(2));
        p.on_remove(ObjectId(1));
        assert_eq!(p.victim(), Some(ObjectId(3)));
    }

    #[test]
    fn reinsert_does_not_duplicate() {
        let mut p = Fifo::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(1));
        p.on_remove(ObjectId(1));
        assert_eq!(p.victim(), None);
    }
}
