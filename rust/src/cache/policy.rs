//! Eviction-policy selection and the internal policy interface.

use crate::storage::object::ObjectId;

/// Cache eviction policy (§3.2.2: "We implement four well-known cache
/// eviction policies: Random, FIFO, LRU, and LFU").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict a uniformly random resident object.
    Random,
    /// Evict the oldest-inserted object.
    Fifo,
    /// Evict the least-recently-used object (the paper's default).
    Lru,
    /// Evict the least-frequently-used object (ties: least recent).
    Lfu,
}

impl EvictionPolicy {
    /// Parse from config/CLI text.
    pub fn parse(s: &str) -> Option<EvictionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(EvictionPolicy::Random),
            "fifo" => Some(EvictionPolicy::Fifo),
            "lru" => Some(EvictionPolicy::Lru),
            "lfu" => Some(EvictionPolicy::Lfu),
            _ => None,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            EvictionPolicy::Random => "random",
            EvictionPolicy::Fifo => "fifo",
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::Lfu => "lfu",
        }
    }
}

/// Internal interface each policy implements. The store calls these on
/// every mutation; `victim` must return a currently resident object.
pub(crate) trait PolicyCore {
    /// Object inserted into the cache.
    fn on_insert(&mut self, id: ObjectId);
    /// Resident object accessed (cache hit).
    fn on_access(&mut self, id: ObjectId);
    /// Object left the cache (evicted or invalidated).
    fn on_remove(&mut self, id: ObjectId);
    /// Choose the next victim among resident objects.
    fn victim(&mut self) -> Option<ObjectId>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all() {
        for (s, p) in [
            ("random", EvictionPolicy::Random),
            ("FIFO", EvictionPolicy::Fifo),
            ("Lru", EvictionPolicy::Lru),
            ("lfu", EvictionPolicy::Lfu),
        ] {
            assert_eq!(EvictionPolicy::parse(s), Some(p));
        }
        assert_eq!(EvictionPolicy::parse("mru"), None);
    }
}
