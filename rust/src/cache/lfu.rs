//! LFU eviction: evict the least-frequently-accessed object; ties broken
//! by least recency (the common LFU-with-aging-free variant).

use std::collections::{BTreeSet, HashMap};

use super::policy::PolicyCore;
use crate::storage::object::ObjectId;

/// Least-frequently-used policy state.
///
/// Keyed set ordered by (frequency, recency-stamp, id) gives O(log n)
/// updates and victim selection.
#[derive(Debug, Default)]
pub struct Lfu {
    clock: u64,
    meta: HashMap<ObjectId, (u64, u64)>, // id -> (freq, stamp)
    ordered: BTreeSet<(u64, u64, ObjectId)>,
}

impl Lfu {
    /// Empty LFU state.
    pub fn new() -> Self {
        Lfu::default()
    }

    fn bump(&mut self, id: ObjectId, start_freq: u64) {
        self.clock += 1;
        match self.meta.get_mut(&id) {
            Some((freq, stamp)) => {
                self.ordered.remove(&(*freq, *stamp, id));
                *freq += 1;
                *stamp = self.clock;
                self.ordered.insert((*freq, *stamp, id));
            }
            None => {
                self.meta.insert(id, (start_freq, self.clock));
                self.ordered.insert((start_freq, self.clock, id));
            }
        }
    }
}

impl PolicyCore for Lfu {
    fn on_insert(&mut self, id: ObjectId) {
        self.bump(id, 1);
    }

    fn on_access(&mut self, id: ObjectId) {
        self.bump(id, 1);
    }

    fn on_remove(&mut self, id: ObjectId) {
        if let Some((freq, stamp)) = self.meta.remove(&id) {
            self.ordered.remove(&(freq, stamp, id));
        }
    }

    fn victim(&mut self) -> Option<ObjectId> {
        self.ordered.iter().next().map(|&(_, _, id)| id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut p = Lfu::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        p.on_access(ObjectId(1));
        p.on_access(ObjectId(1));
        assert_eq!(p.victim(), Some(ObjectId(2)));
    }

    #[test]
    fn frequency_ties_break_by_recency() {
        let mut p = Lfu::new();
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        // Both freq=1; 1 is older -> victim.
        assert_eq!(p.victim(), Some(ObjectId(1)));
        p.on_access(ObjectId(1)); // now 1 has freq 2
        assert_eq!(p.victim(), Some(ObjectId(2)));
    }

    #[test]
    fn remove_clears_state() {
        let mut p = Lfu::new();
        p.on_insert(ObjectId(1));
        p.on_remove(ObjectId(1));
        assert_eq!(p.victim(), None);
        // Re-insert starts at freq 1 again.
        p.on_insert(ObjectId(1));
        p.on_insert(ObjectId(2));
        p.on_access(ObjectId(2));
        assert_eq!(p.victim(), Some(ObjectId(1)));
    }
}
