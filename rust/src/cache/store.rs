//! The per-executor cache store: capacity accounting + policy dispatch.
//!
//! Tracks resident objects and sizes; on insert, evicts per the configured
//! policy until the new object fits. Emits [`CacheEvent`]s so the executor
//! can mirror changes to local disk (live mode) and notify the central
//! index (loose coherence, §3.2.1).

use crate::util::fxhash::FxHashMap;

use super::fifo::Fifo;
use super::lfu::Lfu;
use super::lru::Lru;
use super::policy::{EvictionPolicy, PolicyCore};
use super::random::Random;
use crate::storage::object::ObjectId;

/// A change to cache contents, to be reported to the index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    /// Object became resident.
    Inserted(ObjectId),
    /// Object was evicted to make room.
    Evicted(ObjectId),
}

enum Policy {
    Random(Random),
    Fifo(Fifo),
    Lru(Lru),
    Lfu(Lfu),
}

impl Policy {
    fn core(&mut self) -> &mut dyn PolicyCore {
        match self {
            Policy::Random(p) => p,
            Policy::Fifo(p) => p,
            Policy::Lru(p) => p,
            Policy::Lfu(p) => p,
        }
    }
}

/// A bounded object cache with pluggable eviction.
pub struct DataCache {
    policy: Policy,
    resident: FxHashMap<ObjectId, u64>,
    capacity: u64,
    used: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl DataCache {
    /// Create a cache with `capacity` bytes and the given policy. The
    /// seed only matters for [`EvictionPolicy::Random`].
    pub fn new(capacity: u64, policy: EvictionPolicy, seed: u64) -> Self {
        let policy = match policy {
            EvictionPolicy::Random => Policy::Random(Random::new(seed)),
            EvictionPolicy::Fifo => Policy::Fifo(Fifo::new()),
            EvictionPolicy::Lru => Policy::Lru(Lru::new()),
            EvictionPolicy::Lfu => Policy::Lfu(Lfu::new()),
        };
        DataCache {
            policy,
            resident: FxHashMap::default(),
            capacity,
            used: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up an object; counts a hit or miss and updates recency state.
    pub fn access(&mut self, id: ObjectId) -> bool {
        if self.resident.contains_key(&id) {
            self.hits += 1;
            self.policy.core().on_access(id);
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Residency check without touching hit/miss/recency state (for
    /// scheduling decisions that shouldn't perturb the cache).
    pub fn contains(&self, id: ObjectId) -> bool {
        self.resident.contains_key(&id)
    }

    /// Insert an object of `bytes`, evicting as needed. Returns the event
    /// list: zero or more `Evicted` followed by `Inserted` (empty if the
    /// object can never fit, i.e. `bytes > capacity`).
    pub fn insert(&mut self, id: ObjectId, bytes: u64) -> Vec<CacheEvent> {
        let mut events = Vec::new();
        if self.resident.contains_key(&id) {
            // Refresh recency; no size change assumed (objects immutable —
            // §3.2.2 "data is not modified after initial creation").
            self.policy.core().on_access(id);
            return events;
        }
        if bytes > self.capacity {
            // Cannot ever fit; the executor will stream it without caching.
            return events;
        }
        while self.used + bytes > self.capacity {
            let victim = self
                .policy
                .core()
                .victim()
                .expect("used > 0 implies a victim exists");
            self.remove(victim);
            self.evictions += 1;
            events.push(CacheEvent::Evicted(victim));
        }
        self.resident.insert(id, bytes);
        self.used += bytes;
        self.policy.core().on_insert(id);
        events.push(CacheEvent::Inserted(id));
        events
    }

    /// Remove an object outright (e.g. executor deallocation).
    pub fn remove(&mut self, id: ObjectId) -> bool {
        if let Some(bytes) = self.resident.remove(&id) {
            self.used -= bytes;
            self.policy.core().on_remove(id);
            true
        } else {
            false
        }
    }

    /// Resident object count.
    pub fn len(&self) -> usize {
        self.resident.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    /// Bytes in use.
    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// Capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }

    /// (hits, misses, evictions) counters.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.evictions)
    }

    /// Iterate resident ids (unspecified order).
    pub fn ids(&self) -> impl Iterator<Item = ObjectId> + '_ {
        self.resident.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(cap: u64, policy: EvictionPolicy) -> DataCache {
        DataCache::new(cap, policy, 7)
    }

    #[test]
    fn never_exceeds_capacity() {
        for policy in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            let mut c = cache(100, policy);
            for i in 0..50 {
                c.insert(ObjectId(i), 30);
                assert!(
                    c.used_bytes() <= 100,
                    "{policy:?} exceeded capacity: {}",
                    c.used_bytes()
                );
            }
        }
    }

    #[test]
    fn lru_semantics_end_to_end() {
        let mut c = cache(3, EvictionPolicy::Lru);
        c.insert(ObjectId(1), 1);
        c.insert(ObjectId(2), 1);
        c.insert(ObjectId(3), 1);
        assert!(c.access(ObjectId(1))); // 1 now MRU
        let ev = c.insert(ObjectId(4), 1);
        assert_eq!(
            ev,
            vec![
                CacheEvent::Evicted(ObjectId(2)),
                CacheEvent::Inserted(ObjectId(4))
            ]
        );
        assert!(c.contains(ObjectId(1)));
        assert!(!c.contains(ObjectId(2)));
    }

    #[test]
    fn oversized_object_rejected() {
        let mut c = cache(100, EvictionPolicy::Lru);
        c.insert(ObjectId(1), 50);
        let ev = c.insert(ObjectId(2), 101);
        assert!(ev.is_empty());
        assert!(c.contains(ObjectId(1)), "resident data must survive");
        assert_eq!(c.used_bytes(), 50);
    }

    #[test]
    fn multi_eviction_for_large_insert() {
        let mut c = cache(100, EvictionPolicy::Fifo);
        for i in 0..4 {
            c.insert(ObjectId(i), 25);
        }
        let ev = c.insert(ObjectId(99), 75);
        let evicted = ev
            .iter()
            .filter(|e| matches!(e, CacheEvent::Evicted(_)))
            .count();
        assert_eq!(evicted, 3);
        assert_eq!(c.used_bytes(), 25 + 75);
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = cache(10, EvictionPolicy::Lru);
        assert!(!c.access(ObjectId(1)));
        c.insert(ObjectId(1), 1);
        assert!(c.access(ObjectId(1)));
        assert!(c.access(ObjectId(1)));
        let (h, m, e) = c.stats();
        assert_eq!((h, m, e), (2, 1, 0));
    }

    #[test]
    fn reinsert_is_noop_event_wise() {
        let mut c = cache(10, EvictionPolicy::Lru);
        c.insert(ObjectId(1), 5);
        let ev = c.insert(ObjectId(1), 5);
        assert!(ev.is_empty());
        assert_eq!(c.used_bytes(), 5);
    }
}
