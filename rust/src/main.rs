//! `falkon` — the data-diffusion CLI.
//!
//! Subcommands:
//!
//! * `falkon sim`   — run a simulated experiment (micro-benchmark or
//!   stacking workload) and print the metrics.
//! * `falkon live`  — run a live mini-cluster on real files (and real
//!   PJRT stacking when artifacts are present).
//! * `falkon sweep` — regenerate a figure's data series (same runners the
//!   benches use).
//! * `falkon info`  — show config defaults, Table 1/2 presets, artifact
//!   manifest status.

use datadiffusion::analysis::figures;
use datadiffusion::config::{presets, Config};
use datadiffusion::coordinator::task::{Task, TaskId};
use datadiffusion::driver::live::LiveCluster;
use datadiffusion::driver::sim::SimDriver;
use datadiffusion::index::IndexBackend;
use datadiffusion::provisioner::AllocationPolicy;
use datadiffusion::replication::PlacementPolicy;
use datadiffusion::runtime::{artifacts_dir, Manifest};
use datadiffusion::scheduler::DispatchPolicy;
use datadiffusion::storage::live::LiveStore;
use datadiffusion::storage::object::{DataFormat, ObjectId};
use datadiffusion::util::cli::{help_if_requested, Args, OptSpec};
use datadiffusion::util::csv::results_dir;
use datadiffusion::util::units::{fmt_bps, fmt_bytes, fmt_secs};
use datadiffusion::workloads::astro;
use datadiffusion::workloads::bursty::{self, BurstSpec, DemandShape};

fn main() {
    datadiffusion::util::logging::init();
    let args = Args::from_env(&["help", "read-write", "no-caching", "gz", "list"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let specs = [
        OptSpec { name: "cpus", value: "N", help: "CPU count (stacking sims)", default: "128" },
        OptSpec { name: "nodes", value: "N", help: "node count (micro/live)", default: "4" },
        OptSpec { name: "locality", value: "L", help: "Table 2 data locality", default: "30" },
        OptSpec { name: "scale", value: "F", help: "workload scale (0,1]", default: "0.02" },
        OptSpec { name: "policy", value: "NAME", help: "dispatch policy", default: "max-compute-util" },
        OptSpec { name: "index", value: "BACKEND", help: "cache-location index (central|chord)", default: "central" },
        OptSpec { name: "shards", value: "N", help: "dispatcher shard count for sim/live runs, 0 = one per core (sweep --figure shards instead takes a comma-separated list)", default: "1" },
        OptSpec { name: "sites", value: "N", help: "split the testbed into N federation sites (sweep --figure federation instead takes a comma-separated list)", default: "" },
        OptSpec { name: "threads", value: "N", help: "sim-engine worker threads for multi-site runs, 0 = one per core (sweep --figure scale instead takes a comma-separated list)", default: "1" },
        OptSpec { name: "placement", value: "MODE", help: "federation placement (affinity|home|random), needs --sites >= 2", default: "" },
        OptSpec { name: "provisioner", value: "POLICY", help: "elastic pool: one-at-a-time|all-at-once|adaptive", default: "" },
        OptSpec { name: "replication", value: "POLICY", help: "data diffusion: least-loaded|hash-spread|co-locate", default: "" },
        OptSpec { name: "max-replicas", value: "N", help: "per-object replica ceiling (with --replication)", default: "" },
        OptSpec { name: "staging-budget", value: "F", help: "source egress budget (0,1] gating background staging (1.0 = off)", default: "1.0" },
        OptSpec { name: "share-policy", value: "NAME", help: "transfer share policy (binary|weighted)", default: "binary" },
        OptSpec { name: "class-weights", value: "F,S,P", help: "foreground,staging,prestage fair-share weights (implies --share-policy weighted)", default: "" },
        OptSpec { name: "workload", value: "NAME", help: "sim workload (stacking|bursty)", default: "stacking" },
        OptSpec { name: "shape", value: "NAME", help: "bursty demand shape (square|sine)", default: "square" },
        OptSpec { name: "tasks", value: "N", help: "task count (live: 64, bursty sim: 512)", default: "" },
        OptSpec { name: "objects", value: "N", help: "distinct objects (live: 16, bursty sim: 64)", default: "" },
        OptSpec { name: "workdir", value: "DIR", help: "live-mode working dir", default: "/tmp/falkon-live" },
        OptSpec { name: "figure", value: "N", help: "figure to sweep (2,3,4,5,8,9,10,11,12,13,drp,diffusion,qos,shards,scale,federation)", default: "11" },
        OptSpec { name: "list", value: "", help: "sweep: list available figures and exit", default: "" },
        OptSpec { name: "config", value: "FILE", help: "TOML config (see configs/)", default: "" },
        OptSpec { name: "gz", value: "", help: "compressed (GZ) store format", default: "" },
        OptSpec { name: "read-write", value: "", help: "read+write variant", default: "" },
        OptSpec { name: "no-caching", value: "", help: "disable data diffusion", default: "" },
    ];
    help_if_requested(&args, "falkon", "data diffusion coordinator", &specs);

    let code = match cmd {
        "sim" => cmd_sim(&args),
        "live" => cmd_live(&args),
        "sweep" => cmd_sweep(&args),
        "info" => cmd_info(),
        other => {
            eprintln!("usage: falkon <sim|live|sweep|info> [--help]");
            if !other.is_empty() {
                eprintln!("unknown subcommand: {other}");
            }
            2
        }
    };
    std::process::exit(code);
}

fn cmd_sim(args: &Args) -> i32 {
    let cpus: usize = args.num_or("cpus", 128);
    let locality: f64 = args.num_or("locality", 30.0);
    let scale: f64 = args.num_or("scale", 0.02);
    let caching = !args.flag("no-caching");
    let format = if args.flag("gz") { DataFormat::Gz } else { DataFormat::Fit };
    let Some(backend) = IndexBackend::parse(&args.str_or("index", "central")) else {
        eprintln!("error: --index expects central|chord");
        return 2;
    };

    let mut cfg = if caching {
        presets::stacking(cpus)
    } else {
        presets::stacking_gpfs_baseline(cpus)
    };
    // A config file (e.g. configs/paper_testbed.toml) overrides presets.
    if let Some(path) = args.get("config") {
        match Config::from_file(path) {
            Ok(file_cfg) => cfg = file_cfg,
            Err(e) => {
                eprintln!("error loading {path}: {e}");
                return 1;
            }
        }
    }
    // CLI flags win over presets and config file.
    cfg.index.backend = backend;
    if apply_shards_flag(args, &mut cfg).is_err() {
        return 2;
    }
    if apply_sites_flags(args, &mut cfg).is_err() {
        return 2;
    }
    if apply_threads_flag(args, &mut cfg).is_err() {
        return 2;
    }
    if let Some(p) = args.get("provisioner") {
        let Some(policy) = AllocationPolicy::parse(p) else {
            eprintln!("error: --provisioner expects one-at-a-time|all-at-once|adaptive");
            return 2;
        };
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = policy;
        cfg.provisioner.max_executors = cfg.provisioner.max_executors.min(cfg.testbed.nodes);
    }
    // Whether elasticity came from the flag or a config file, reject a
    // pool that could never allocate (the sim driver asserts on it).
    if cfg.provisioner.enabled && (cfg.testbed.nodes == 0 || cfg.provisioner.max_executors == 0) {
        eprintln!("error: elastic pool needs testbed.nodes >= 1 and provisioner.max_executors >= 1");
        return 2;
    }
    if apply_replication_flags(args, &mut cfg).is_err() {
        return 2;
    }

    let workload = args.str_or("workload", "stacking");
    let (spec, catalog, label) = match workload.as_str() {
        "bursty" => {
            let Some(shape) = DemandShape::parse(&args.str_or("shape", "square")) else {
                eprintln!("error: --shape expects square|sine");
                return 2;
            };
            let bspec = BurstSpec {
                shape,
                tasks: args.num_or("tasks", 512),
                objects: args.num_or("objects", 64),
                ..BurstSpec::default()
            };
            let w = bursty::generate(&bspec, cfg.seed);
            let label = format!(
                "bursty({:?}) | {} tasks over {} objects, horizon {}",
                shape,
                bspec.tasks,
                bspec.objects,
                fmt_secs(w.horizon_s)
            );
            (w.spec, w.catalog, label)
        }
        "stacking" => {
            let row = astro::row_for_locality(locality);
            let w = astro::generate(&cfg, row, format, caching, scale, cfg.seed);
            let label = format!(
                "locality {} | {} objects over {} files",
                row.locality, w.objects, w.files
            );
            (w.spec, w.catalog, label)
        }
        other => {
            eprintln!("error: --workload expects stacking|bursty, got {other}");
            return 2;
        }
    };
    println!(
        "sim: {label} | {} CPUs | {} | caching={} | index={} | provisioner={} | replication={}",
        cpus,
        format.label(),
        caching,
        cfg.index.backend.label(),
        if cfg.provisioner.enabled {
            cfg.provisioner.policy.label()
        } else {
            "static"
        },
        replication_label(&cfg)
    );
    let mut out = SimDriver::new(cfg, spec, catalog).run();
    print_outcome_common(
        out.metrics.tasks_done,
        out.makespan_s,
        out.time_per_task_per_cpu(cpus),
        &mut out.metrics,
    );
    print_pool_timeline(&out.metrics);
    println!(
        "  sim-engine: {} events in {} ({:.0} ev/s)",
        out.events,
        fmt_secs(out.wall_s),
        out.events as f64 / out.wall_s.max(1e-9)
    );
    0
}

/// Apply `--shards N` (dispatcher shard count for sim/live runs;
/// 0 resolves to one shard per available core, matching
/// `coordinator.shards = 0` in config files).
fn apply_shards_flag(args: &Args, cfg: &mut Config) -> Result<(), ()> {
    if let Some(s) = args.get("shards") {
        match s.parse::<usize>() {
            Ok(0) => {
                cfg.coordinator.shards = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            }
            Ok(n) => cfg.coordinator.shards = n,
            Err(_) => {
                eprintln!("error: --shards expects an integer (0 = one shard per core)");
                return Err(());
            }
        }
    }
    Ok(())
}

/// Apply `--threads N` (parallel sim-engine worker threads for
/// multi-site runs; 0 resolves to one thread per available core,
/// matching `sim.threads = 0` in config files).
fn apply_threads_flag(args: &Args, cfg: &mut Config) -> Result<(), ()> {
    if let Some(s) = args.get("threads") {
        match s.parse::<usize>() {
            Ok(0) => {
                cfg.sim.threads = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            }
            Ok(n) => cfg.sim.threads = n,
            Err(_) => {
                eprintln!("error: --threads expects an integer (0 = one thread per core)");
                return Err(());
            }
        }
    }
    Ok(())
}

/// Apply `--sites N` / `--placement MODE` (multi-cluster federation:
/// splits the testbed into N near-equal contiguous sites with default
/// WAN parameters; `[[site]]` tables in a config file take the same
/// path with explicit per-site shapes).
fn apply_sites_flags(args: &Args, cfg: &mut Config) -> Result<(), ()> {
    if let Some(s) = args.get("sites") {
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => cfg.split_into_sites(n),
            _ => {
                eprintln!("error: --sites expects an integer >= 1");
                return Err(());
            }
        }
    }
    if let Some(p) = args.get("placement") {
        let Some(mode) = datadiffusion::federation::PlacementMode::parse(p) else {
            eprintln!("error: --placement expects affinity|home|random");
            return Err(());
        };
        cfg.federation.placement = mode;
    }
    Ok(())
}

/// Apply `--replication <policy>` / `--max-replicas N` /
/// `--staging-budget F` / `--share-policy NAME` / `--class-weights F,S,P`
/// to the config (the first flag enables the manager; config files can
/// also enable it; `--class-weights` implies the weighted share policy).
fn apply_replication_flags(args: &Args, cfg: &mut Config) -> Result<(), ()> {
    if let Some(p) = args.get("replication") {
        let Some(policy) = PlacementPolicy::parse(p) else {
            eprintln!("error: --replication expects least-loaded|hash-spread|co-locate");
            return Err(());
        };
        cfg.replication.enabled = true;
        cfg.replication.policy = policy;
    }
    if let Some(n) = args.get("max-replicas") {
        match n.parse::<usize>() {
            Ok(n) if n >= 1 => cfg.replication.max_replicas = n,
            _ => {
                eprintln!("error: --max-replicas expects an integer >= 1");
                return Err(());
            }
        }
    }
    if let Some(b) = args.get("staging-budget") {
        match b.parse::<f64>() {
            Ok(v) if v > 0.0 && v <= 1.0 => cfg.transfer.staging_budget = v,
            _ => {
                eprintln!("error: --staging-budget expects a number in (0, 1]");
                return Err(());
            }
        }
    }
    if let Some(p) = args.get("share-policy") {
        let Some(kind) = datadiffusion::transfer::SharePolicyKind::parse(p) else {
            eprintln!("error: --share-policy expects binary|weighted");
            return Err(());
        };
        cfg.transfer.share_policy = kind;
    }
    if let Some(w) = args.get("class-weights") {
        let Some(weights) = datadiffusion::transfer::ClassWeights::parse(w) else {
            eprintln!(
                "error: --class-weights expects three positive numbers \
                 \"foreground,staging,prestage\" (e.g. 1.0,0.25,0.1)"
            );
            return Err(());
        };
        cfg.transfer.class_weights = weights;
        cfg.transfer.share_policy = datadiffusion::transfer::SharePolicyKind::Weighted;
    }
    Ok(())
}

/// Display label for the replication setting.
fn replication_label(cfg: &Config) -> String {
    if cfg.replication.enabled {
        format!(
            "{} (max {})",
            cfg.replication.policy.label(),
            cfg.replication.max_replicas
        )
    } else {
        "off".into()
    }
}

/// Allocated-vs-demand summary of an elastic run (no-op for static pools).
fn print_pool_timeline(m: &datadiffusion::coordinator::metrics::Metrics) {
    if m.pool_timeline.is_empty() {
        return;
    }
    println!(
        "  provisioning: {} allocation requests | {} joined | {} released | peak pool {} | idle {:.0} exec-s | alloc-wait {:.0} exec-s",
        m.alloc_requests,
        m.executors_joined,
        m.executors_released,
        m.peak_executors,
        m.idle_exec_s,
        m.alloc_wait_s
    );
    println!(
        "  {:>10} {:>10} {:>8} {:>8} {:>10}",
        "t", "allocated", "pending", "queued", "window-hit"
    );
    // Sample the timeline evenly: enough rows to see growth and decay
    // without drowning the summary.
    let n = m.pool_timeline.len();
    let stride = n.div_ceil(16);
    let mut prev = m.pool_timeline[0];
    for (i, s) in m.pool_timeline.iter().enumerate() {
        if i % stride == 0 || i + 1 == n {
            println!(
                "  {:>10} {:>10} {:>8} {:>8} {:>9.1}%",
                fmt_secs(s.t),
                s.allocated,
                s.pending,
                s.queued,
                s.window_hit_ratio(&prev) * 100.0
            );
            prev = *s;
        }
    }
}

fn cmd_live(args: &Args) -> i32 {
    let nodes: usize = args.num_or("nodes", 4);
    let n_tasks: u64 = args.num_or("tasks", 64);
    let n_objects: u64 = args.num_or("objects", 16);
    let workdir = std::path::PathBuf::from(args.str_or("workdir", "/tmp/falkon-live"));
    let format = if args.flag("gz") { DataFormat::Gz } else { DataFormat::Fit };
    let policy = DispatchPolicy::parse(&args.str_or("policy", "max-compute-util"))
        .unwrap_or(DispatchPolicy::MaxComputeUtil);
    let Some(backend) = IndexBackend::parse(&args.str_or("index", "central")) else {
        eprintln!("error: --index expects central|chord");
        return 2;
    };

    let _ = std::fs::remove_dir_all(&workdir);
    let mut store = match LiveStore::create(workdir.join("gpfs"), format) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    for i in 0..n_objects {
        if let Err(e) = store.populate(ObjectId(i), 100 * 100) {
            eprintln!("error: {e}");
            return 1;
        }
    }

    // Verify the artifact manifest loads before wiring PJRT in.
    let artifacts = match Manifest::load(&artifacts_dir()) {
        Ok(m) => {
            println!("artifacts: {} entries in {}", m.artifacts.len(), artifacts_dir().display());
            Some(artifacts_dir())
        }
        Err(e) => {
            eprintln!("note: running without PJRT compute ({e})");
            None
        }
    };
    let depth = if artifacts.is_some() { 8 } else { 1 };
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|i| Task::stacking(TaskId(i), ObjectId(i % n_objects), depth, 4 * 100 * 100))
        .collect();

    let mut cfg = Config::with_nodes(nodes);
    cfg.scheduler.policy = policy;
    cfg.index.backend = backend;
    if apply_shards_flag(args, &mut cfg).is_err() {
        return 2;
    }
    if let Some(p) = args.get("provisioner") {
        let Some(pol) = AllocationPolicy::parse(p) else {
            eprintln!("error: --provisioner expects one-at-a-time|all-at-once|adaptive");
            return 2;
        };
        cfg.provisioner.enabled = true;
        cfg.provisioner.policy = pol;
        cfg.provisioner.min_executors = 0;
        cfg.provisioner.max_executors = nodes;
        // Wall-clock scale: a GRAM4-style 40 s allocation latency would
        // dwarf a mini-cluster demo.
        cfg.provisioner.allocation_latency_s = 0.25;
        cfg.provisioner.poll_interval_s = 0.05;
        cfg.provisioner.idle_release_s = 2.0;
    }
    if apply_replication_flags(args, &mut cfg).is_err() {
        return 2;
    }
    if cfg.replication.enabled {
        // Wall-clock scale, like the provisioner defaults above.
        cfg.replication.evaluate_interval_s = cfg.replication.evaluate_interval_s.min(0.1);
        cfg.replication.demand_threshold = cfg.replication.demand_threshold.min(1.0);
    }
    println!(
        "live: {nodes} executors | {n_tasks} stacking tasks over {n_objects} objects | {} | {} | index={} | provisioner={} | replication={}",
        format.label(),
        policy.label(),
        backend.label(),
        if cfg.provisioner.enabled { cfg.provisioner.policy.label() } else { "static" },
        replication_label(&cfg)
    );
    match LiveCluster::new(cfg, store, workdir.join("work"), artifacts).run(tasks) {
        Ok(mut out) => {
            print_outcome_common(
                out.metrics.tasks_done,
                out.makespan_s,
                out.makespan_s * nodes as f64 / out.metrics.tasks_done.max(1) as f64,
                &mut out.metrics,
            );
            print_pool_timeline(&out.metrics);
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Figure registry for `falkon sweep --list`.
const FIGURES: &[(&str, &str)] = &[
    ("2", "index backends measured: central vs chord lookup cost on scheduled runs (CSV)"),
    ("3", "aggregate read throughput vs node count, 100 MB files"),
    ("4", "aggregate read+write throughput vs node count"),
    ("5", "file-size sweep at 64 nodes (throughput + task rate)"),
    ("8", "time/stack vs CPUs at locality 1.38"),
    ("9", "time/stack vs CPUs at locality 30"),
    ("10", "cache-hit ratio vs locality at 128 CPUs"),
    ("11", "time/stack vs locality at 128 CPUs (the default sweep)"),
    ("12", "aggregate I/O throughput split by source at 128 CPUs"),
    ("13", "per-task data movement by source at 128 CPUs"),
    ("drp", "dynamic provisioning: the three allocation policies on bursty runs (CSVs)"),
    ("diffusion", "demand-driven replication on/off vs cache-node count (CSV)"),
    ("qos", "share-policy axis off/binary/weighted: foreground p50/p90/p99 under saturating staging (--tasks = bursts of `nodes` tasks, CSV)"),
    ("shards", "dispatch-core shard scaling: drain throughput, batches and steals vs shard count (CSV)"),
    ("scale", "simulator scalability: wall-clock, events/sec and peak RSS over an executors x tasks grid (CSV)"),
    ("federation", "multi-site federation: affinity vs always-home vs random placement over a site-count x WAN-bandwidth x skew grid (CSV)"),
];

/// `falkon sweep --list`: enumerate the available figures.
fn sweep_list() -> i32 {
    println!("available figures (falkon sweep --figure <id>):");
    for (id, desc) in FIGURES {
        println!("  {id:<10} {desc}");
    }
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let fig_arg = args.str_or("figure", "11");
    if args.flag("list") || fig_arg == "list" {
        return sweep_list();
    }
    if fig_arg == "drp" {
        return sweep_drp(args);
    }
    if fig_arg == "diffusion" {
        return sweep_diffusion(args);
    }
    if fig_arg == "qos" {
        return sweep_qos(args);
    }
    if fig_arg == "shards" {
        return sweep_shards(args);
    }
    if fig_arg == "scale" {
        return sweep_scale(args);
    }
    if fig_arg == "federation" {
        return sweep_federation(args);
    }
    let Ok(fig) = fig_arg.parse::<u32>() else {
        eprintln!("unknown figure {fig_arg}; see `falkon sweep --list`");
        return 2;
    };
    let scale: f64 = args.num_or("scale", figures::env_scale());
    match fig {
        2 => {
            let rows = figures::fig2_measured(&[4, 16, 64], figures::env_tpn());
            match figures::emit_fig2_measured(&rows, &results_dir()) {
                Ok(p) => println!("wrote {}", p.display()),
                Err(e) => {
                    eprintln!("error writing CSV: {e}");
                    return 1;
                }
            }
        }
        3 | 4 => {
            let rw = fig == 4;
            let rows = figures::fig3_fig4(rw, &[1, 2, 4, 8, 16, 32, 64], figures::env_tpn());
            println!("{:<48} {:>6} {:>14}", "config", "nodes", "throughput");
            for r in rows {
                println!("{:<48} {:>6} {:>14}", r.config, r.nodes, fmt_bps(r.bps));
            }
        }
        5 => {
            let rows = figures::fig5(&datadiffusion::workloads::microbench::FILE_SIZES, figures::env_tpn());
            println!("{:<44} {:>4} {:>10} {:>14} {:>10}", "config", "rw", "size", "throughput", "tasks/s");
            for r in rows {
                println!(
                    "{:<44} {:>4} {:>10} {:>14} {:>10.1}",
                    r.config,
                    if r.read_write { "rw" } else { "r" },
                    fmt_bytes(r.file_bytes),
                    fmt_bps(r.bps),
                    r.tasks_per_s
                );
            }
        }
        8 | 9 => {
            let loc = if fig == 8 { 1.38 } else { 30.0 };
            let rows = figures::fig8_fig9(loc, &[2, 4, 8, 16, 32, 64, 128], scale);
            println!("{:<24} {:>6} {:>16} {:>10}", "config", "cpus", "time/stack/cpu", "hit%");
            for r in rows {
                println!(
                    "{:<24} {:>6} {:>16} {:>9.1}%",
                    r.config,
                    r.cpus,
                    fmt_secs(r.time_per_stack_s),
                    r.hit_ratio * 100.0
                );
            }
        }
        10 | 11 | 12 | 13 => {
            let rows = figures::fig11_sweep(128, scale);
            println!(
                "{:<24} {:>8} {:>14} {:>8} {:>8} {:>12} {:>12} {:>12}",
                "config", "locality", "time/stack", "hit%", "ideal%", "local", "c2c", "gpfs"
            );
            for r in rows {
                let m = &r.outcome.metrics;
                println!(
                    "{:<24} {:>8} {:>14} {:>7.1}% {:>7.1}% {:>12} {:>12} {:>12}",
                    r.config,
                    r.locality,
                    fmt_secs(r.time_per_stack_s),
                    r.hit_ratio * 100.0,
                    astro::ideal_hit_ratio(r.locality) * 100.0,
                    fmt_bytes(m.local_bytes),
                    fmt_bytes(m.c2c_bytes),
                    fmt_bytes(m.gpfs_bytes),
                );
            }
        }
        other => {
            eprintln!("unknown figure {other}; see `falkon sweep --list`");
            return 2;
        }
    }
    0
}

/// The QoS figure: foreground tail latency under saturating staging
/// load across the share-policy axis — off (no metering), binary
/// (start-time deferral) and weighted (per-class fair shares) — same
/// emitter as the `fig_qos` bench. `--nodes` caps the node-count list.
/// NOTE: unlike the other sweeps, `--tasks` here is the number of task
/// *bursts* per run — each burst is `nodes` tasks, so a run schedules
/// nodes × tasks tasks (the burst structure, not the raw count, is what
/// saturates the holder).
fn sweep_qos(args: &Args) -> i32 {
    let max_nodes: usize = args.num_or("nodes", 16);
    let bursts: usize = args.num_or("tasks", 20);
    let nodes_list: Vec<usize> = [4usize, 8, 16, 32]
        .into_iter()
        .filter(|&n| n <= max_nodes.max(4))
        .collect();
    let rows = figures::fig_qos(&nodes_list, bursts);
    match figures::emit_qos(&rows, &results_dir()) {
        Ok(p) => {
            println!(
                "\nreading the figure: unmetered ('off') staging shares each holder's egress\n\
                 1:1 with the foreground fetches queued on it, stretching the burst tail;\n\
                 'binary' defers staging mid-burst and drains it in the gaps (stop-start);\n\
                 'weighted' admits staging throttled at its class weight, so foreground p99\n\
                 stays at binary's level while staging throughput stays strictly smoother\n\
                 than stop-start deferral.\nwrote {}",
                p.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

/// The shard-scaling figure: dispatch throughput vs dispatcher shard
/// count through `ShardedCore::drain_all` on one bursty hot-set
/// workload (same emitter as the `dispatch_throughput` bench).
/// `--shards` here is a comma-separated list of shard counts to sweep;
/// `--tasks` and `--nodes` size the drained workload.
fn sweep_shards(args: &Args) -> i32 {
    let tasks: u64 = args.num_or("tasks", 4096);
    let executors: usize = args.num_or("nodes", 32);
    let shards: Vec<usize> = args.num_list_or("shards", &[1, 2, 4, 8]);
    if shards.iter().any(|&n| n == 0) {
        eprintln!("error: --shards expects shard counts >= 1");
        return 2;
    }
    let rows = figures::fig_shard_scaling(&shards, tasks, executors);
    match figures::emit_shard_scaling(&rows, &results_dir()) {
        Ok(p) => {
            println!(
                "\nreading the figure: one dispatcher loop is the decision-rate ceiling the\n\
                 paper's §3.1 task rates push against; sharding the core lets each shard\n\
                 batch its own ready queue against its own idle set, and bounded stealing\n\
                 keeps starved shards fed, so drain throughput scales with shard count\n\
                 until cores run out.\nwrote {}",
                p.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

/// The simulator-scalability figure: wall-clock, events/sec, and peak
/// RSS for full data-aware runs over an (executors × tasks) grid (same
/// emitter as the `fig_scale` bench). `--nodes` and `--tasks` are
/// comma-separated grid axes; pass them smallest-first so the
/// peak-RSS high-water column reads as per-cell peaks. `--sites`
/// splits each cell's testbed into N federation sites and `--threads`
/// is a comma-separated engine-thread axis (0 = one per core); the
/// speedup column in each row is relative to the cell's first thread
/// count.
fn sweep_scale(args: &Args) -> i32 {
    let nodes: Vec<usize> = args.num_list_or("nodes", &[64, 256, 1024]);
    let tasks: Vec<u64> = args.num_list_or("tasks", &[10_000]);
    if nodes.is_empty() || tasks.is_empty() {
        eprintln!("error: --nodes and --tasks expect comma-separated positive integers");
        return 2;
    }
    let sites: usize = args.num_or("sites", 1);
    let threads: Vec<usize> = args
        .num_list_or("threads", &[1])
        .into_iter()
        .map(|n| {
            if n == 0 {
                std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
            } else {
                n
            }
        })
        .collect();
    let rows = figures::fig_scale(&nodes, &tasks, sites, &threads);
    match figures::emit_scale(&rows, &results_dir()) {
        Ok(p) => {
            println!(
                "\nreading the figure: each cell is a full data-aware run (dispatch, index,\n\
                 cache, flow network); events/sec holding near-flat as executors grow is\n\
                 the calendar event queue and per-component flow refill doing their job —\n\
                 per-event cost independent of cluster size.\nwrote {}",
                p.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

/// The federation figure: ship-task vs ship-data placement over a
/// (site count × WAN bandwidth × origin skew) grid, all three placement
/// modes per cell (same emitter as the `fig_federation` bench).
/// `--sites` is a comma-separated list of site counts to sweep;
/// `--nodes` is the total executor count split across the sites;
/// `--tasks` is tasks-per-node; `--threads` sets the engine thread
/// count every cell runs at (0 = one per core — outcomes are
/// thread-count invariant, only wall-clock changes).
fn sweep_federation(args: &Args) -> i32 {
    let nodes: usize = args.num_or("nodes", 16);
    let tpn: usize = args.num_or("tasks", 8);
    let sites: Vec<usize> = args.num_list_or("sites", &[2, 4]);
    if sites.is_empty() || sites.iter().any(|&n| n == 0) {
        eprintln!("error: --sites expects a comma-separated list of site counts >= 1");
        return 2;
    }
    let threads = match args.str_or("threads", "1").parse::<usize>() {
        Ok(0) => std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
        Ok(n) => n,
        Err(_) => {
            eprintln!("error: --threads expects an integer (0 = one thread per core)");
            return 2;
        }
    };
    let rows = figures::fig_federation(&sites, &[0.25, 1.0], &[0.0, 0.8], nodes, tpn, threads);
    match figures::emit_federation(&rows, &results_dir()) {
        Ok(p) => {
            println!(
                "\nreading the figure: the baselines run tasks where they originate (home)\n\
                 or anywhere (random) and ship 32 MB inputs over the shared WAN links;\n\
                 affinity ships the task to the site already caching its input, so it\n\
                 wins on makespan AND WAN bytes at every multi-site cell — and the gap\n\
                 widens as the WAN thins or the origin skew concentrates load.\nwrote {}",
                p.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

/// The data-diffusion figure: aggregate read throughput + hit ratio vs.
/// cache-node count with demand-driven replication on and off, measured
/// on elastic bursty runs (same emitter as the `fig_diffusion` bench).
/// `--nodes` caps the sweep's node-count list; `--tasks` sets tasks per
/// node.
fn sweep_diffusion(args: &Args) -> i32 {
    let max_nodes: usize = args.num_or("nodes", 16);
    let tpn: usize = args.num_or("tasks", 48);
    let nodes_list: Vec<usize> = [2usize, 4, 8, 16, 32, 64]
        .into_iter()
        .filter(|&n| n <= max_nodes.max(2))
        .collect();
    let rows = figures::fig_diffusion(&nodes_list, tpn);
    match figures::emit_diffusion(&rows, &results_dir()) {
        Ok(p) => {
            println!(
                "\nreading the figure: replication-off leans on the surviving holders after\n\
                 every churn (peer fetches on the task critical path); replication-on\n\
                 pre-stages joiners and widens hot replica sets, so the local hit ratio\n\
                 recovers and aggregate read bandwidth scales with the cache-node count —\n\
                 the paper's data-diffusion claim on measured runs.\nwrote {}",
                p.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

/// The DRP figure: all three allocation policies through real elastic
/// scheduled runs, with CSVs for external plotting (same emitter as the
/// `fig_drp` bench).
fn sweep_drp(args: &Args) -> i32 {
    let nodes: usize = args.num_or("nodes", 16);
    let tasks: u64 = args.num_or("tasks", 400);
    let rows = figures::fig_drp(nodes, tasks);
    match figures::emit_drp(&rows, &results_dir()) {
        Ok((p, tp)) => {
            println!(
                "\nreading the figure: all-at-once reaches the demand fastest but pays the most\n\
                 idle executor-seconds; one-at-a-time trickles grants through the allocation\n\
                 latency; adaptive tracks the backlog with few requests — the trade §3.1\n\
                 motivates, measured on scheduled runs.\nwrote {} and {}",
                p.display(),
                tp.display()
            );
            0
        }
        Err(e) => {
            eprintln!("error writing CSV: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    println!("Table 1 testbed presets:");
    for p in presets::TABLE1 {
        println!(
            "  {:<12} {:>3} nodes | {:<22} | {} | {}",
            p.name, p.nodes, p.processors, p.memory, p.network
        );
    }
    println!("\nTable 2 workloads:");
    for row in astro::TABLE2 {
        println!(
            "  locality {:>5}: {:>7} objects in {:>7} files (ideal hit ratio {:>5.1}%)",
            row.locality,
            row.objects,
            row.files,
            astro::ideal_hit_ratio(row.locality) * 100.0
        );
    }
    let dir = artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("\nartifacts ({}):", dir.display());
            for a in &m.artifacts {
                println!("  {:<16} {:?} {}", a.name, a.kind, a.path.display());
            }
        }
        Err(e) => println!("\nartifacts: {e}"),
    }
    0
}

fn print_outcome_common(
    tasks: u64,
    makespan: f64,
    per_task_cpu: f64,
    m: &mut datadiffusion::coordinator::metrics::Metrics,
) {
    use datadiffusion::transfer::TransferClass;
    println!("  tasks: {tasks} | makespan {} | time/task/cpu {}", fmt_secs(makespan), fmt_secs(per_task_cpu));
    if m.tasks_done > 0 {
        println!(
            "  task latency: p50 {} | p90 {} | p99 {} | mean {}",
            fmt_secs(m.task_latency_p50()),
            fmt_secs(m.task_latency_p90()),
            fmt_secs(m.task_latency_p99()),
            fmt_secs(m.task_latency.mean())
        );
    }
    println!(
        "  hits: local {} ({:.1}%), cache-to-cache {}, persistent {}",
        m.cache_hits,
        m.local_hit_ratio() * 100.0,
        m.peer_hits,
        m.gpfs_misses
    );
    println!(
        "  bytes: local {} | c2c {} | GPFS read {} | GPFS write {}",
        fmt_bytes(m.local_bytes),
        fmt_bytes(m.c2c_bytes),
        fmt_bytes(m.gpfs_bytes),
        fmt_bytes(m.gpfs_write_bytes)
    );
    println!(
        "  aggregate: read {} | read+write {} | {:.1} tasks/s",
        fmt_bps(m.read_throughput_bps()),
        fmt_bps(m.rw_throughput_bps()),
        m.task_rate()
    );
    if m.index_lookups > 0 {
        println!(
            "  index: {} lookups | {} hops | {} stabilization msgs | {} update msgs | charged {}",
            m.index_lookups,
            m.index_hops,
            m.stabilization_msgs,
            m.index_update_msgs,
            fmt_secs(m.index_cost_s)
        );
    }
    if m.class_bytes.iter().any(|&b| b > 0) {
        let cell = |c: TransferClass| {
            format!(
                "{} {} @ {}",
                c.label(),
                fmt_bytes(m.class_bytes[c.index()]),
                fmt_bps(m.class_mean_rate_bps(c))
            )
        };
        println!(
            "  transfer classes: {} | {} | {}",
            cell(TransferClass::Foreground),
            cell(TransferClass::Staging),
            cell(TransferClass::Prestage)
        );
    }
    if m.wan_bytes > 0 || m.cross_site_tasks > 0 {
        println!(
            "  federation: {} over the WAN | {} tasks placed off-origin",
            fmt_bytes(m.wan_bytes),
            m.cross_site_tasks
        );
    }
    if m.replicas_created > 0 || m.replica_bytes_staged > 0 || m.staging_deferred > 0 {
        println!(
            "  replication: {} replicas staged ({}) | {} replica hits | {} dropped on decay | {} stagings deferred",
            m.replicas_created,
            fmt_bytes(m.replica_bytes_staged),
            m.replica_hits,
            m.replicas_dropped,
            m.staging_deferred
        );
    }
    // Per-shard dispatcher loops (live `--shards >= 2`): wall-clock busy
    // time summed over shard loops plus the worst report burst drained
    // under one core lock — the serialization the shard threads removed.
    if m.dispatch_loop_busy_s > 0.0 {
        let burst = m.report_queue_peaks.iter().copied().max().unwrap_or(0);
        println!(
            "  dispatcher: {} busy across {} shard loops | peak report burst {} | {} steals ({} tasks)",
            fmt_secs(m.dispatch_loop_busy_s),
            m.report_queue_peaks.len(),
            burst,
            m.dispatch_steals,
            m.dispatch_stolen_tasks
        );
    }
}
