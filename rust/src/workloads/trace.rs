//! Task-trace record / replay (TSV).
//!
//! Lets an experiment's exact task stream be saved and re-run (e.g. to
//! compare policies on identical workloads, or to ship a repro case).
//!
//! Format, one task per line:
//!
//! ```text
//! # arrival  task_id  kind  depth_or_cpu  output_bytes  input,input,...
//! 0.000000   17       stack 30            40000         churn12,churn13
//! ```

use std::io::Write;
use std::path::Path;

use crate::coordinator::task::{Task, TaskId, TaskKind};
use crate::error::{Error, Result};
use crate::storage::object::ObjectId;

/// Serialize (arrival, task) pairs to a TSV file.
pub fn record(path: &Path, tasks: &[(f64, Task)]) -> Result<()> {
    let mut out = String::new();
    out.push_str("# arrival\ttask_id\tkind\tdepth_or_cpu\toutput_bytes\tinputs\n");
    for (arrival, t) in tasks {
        let (kind, knum) = match t.kind {
            TaskKind::Synthetic { cpu_s } => ("synthetic", cpu_s.to_string()),
            TaskKind::Stack { stack_depth } => ("stack", stack_depth.to_string()),
        };
        let inputs: Vec<String> = t.inputs.iter().map(|o| o.0.to_string()).collect();
        out.push_str(&format!(
            "{arrival}\t{}\t{kind}\t{knum}\t{}\t{}\n",
            t.id.0,
            t.output_bytes,
            inputs.join(",")
        ));
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())?;
    Ok(())
}

/// Load a trace back.
pub fn replay(path: &Path) -> Result<Vec<(f64, Task)>> {
    let text = std::fs::read_to_string(path)?;
    let mut tasks = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        // Trim only line endings: a task with no inputs ends in a tab
        // that full trim() would eat, corrupting the field count.
        let line = line.trim_end_matches(['\r', '\n']);
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        if fields.len() != 6 {
            return Err(Error::Workload(format!(
                "trace line {}: expected 6 fields, got {}",
                lineno + 1,
                fields.len()
            )));
        }
        let bad = |what: &str| Error::Workload(format!("trace line {}: bad {what}", lineno + 1));
        let arrival: f64 = fields[0].parse().map_err(|_| bad("arrival"))?;
        let id: u64 = fields[1].parse().map_err(|_| bad("task_id"))?;
        let output_bytes: u64 = fields[4].parse().map_err(|_| bad("output_bytes"))?;
        let kind = match fields[2] {
            "synthetic" => TaskKind::Synthetic {
                cpu_s: fields[3].parse().map_err(|_| bad("cpu_s"))?,
            },
            "stack" => TaskKind::Stack {
                stack_depth: fields[3].parse().map_err(|_| bad("stack_depth"))?,
            },
            other => return Err(bad(&format!("kind {other:?}"))),
        };
        let inputs: Vec<ObjectId> = if fields[5].is_empty() {
            Vec::new()
        } else {
            fields[5]
                .split(',')
                .map(|s| s.parse::<u64>().map(ObjectId).map_err(|_| bad("inputs")))
                .collect::<Result<Vec<_>>>()?
        };
        tasks.push((
            arrival,
            Task {
                id: TaskId(id),
                inputs,
                output_bytes,
                kind,
            },
        ));
    }
    Ok(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tasks = vec![
            (0.0, Task::with_inputs(TaskId(1), vec![ObjectId(7)])),
            (1.5, Task::read_write(TaskId(2), ObjectId(8), 100)),
            (2.25, Task::stacking(TaskId(3), ObjectId(9), 30, 40_000)),
            (3.0, Task::with_inputs(TaskId(4), vec![])),
        ];
        let path = std::env::temp_dir().join(format!("dd_trace_{}.tsv", std::process::id()));
        record(&path, &tasks).unwrap();
        let back = replay(&path).unwrap();
        assert_eq!(back.len(), 4);
        for ((a0, t0), (a1, t1)) in tasks.iter().zip(&back) {
            assert_eq!(a0, a1);
            assert_eq!(t0, t1);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn malformed_trace_errors() {
        let path = std::env::temp_dir().join(format!("dd_trace_bad_{}.tsv", std::process::id()));
        std::fs::write(&path, "0.0\tnot_a_number\tstack\t1\t0\t1\n").unwrap();
        assert!(replay(&path).is_err());
        std::fs::write(&path, "0.0\t1\tbogus_kind\t1\t0\t1\n").unwrap();
        assert!(replay(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
