//! Deterministic synthetic sky data for live runs.
//!
//! Gives every object id reproducible calibration parameters and every
//! (file, slot) a reproducible cutout, so live executions can be checked
//! end-to-end (the same stacking request always produces the same image,
//! byte-for-byte, regardless of which executor served the data).

use crate::storage::object::ObjectId;
use crate::util::rng::Rng;

/// Per-image calibration parameters (the SKY and CAL variables of §5.2,
/// plus the sub-pixel shift the interpolation phase corrects).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ImageParams {
    /// Sky background level.
    pub sky: f32,
    /// Calibration gain.
    pub cal: f32,
    /// Horizontal sub-pixel offset in [0, 1).
    pub dx: f32,
    /// Vertical sub-pixel offset in [0, 1).
    pub dy: f32,
}

/// Deterministic calibration parameters for cutout `slot` of `file`.
pub fn params_for(file: ObjectId, slot: u32) -> ImageParams {
    let mut rng = Rng::new(file.0.wrapping_mul(0x5851_F42D_4C95_7F2D) ^ slot as u64);
    ImageParams {
        sky: rng.range_f64(10.0, 100.0) as f32,
        cal: rng.range_f64(0.5, 2.0) as f32,
        dx: rng.next_f64() as f32,
        dy: rng.next_f64() as f32,
    }
}

/// Extract `depth` cutouts of `h*w` int16 pixels from a file payload
/// (starting after the 16-byte header), wrapping if the payload is
/// smaller than `depth*h*w` — live test stores may be scaled down.
pub fn cutouts_from_payload(pixels: &[i16], depth: usize, h: usize, w: usize) -> Vec<i16> {
    let px = h * w;
    let mut out = Vec::with_capacity(depth * px);
    if pixels.is_empty() {
        out.resize(depth * px, 0);
        return out;
    }
    for k in 0..depth * px {
        out.push(pixels[k % pixels.len()]);
    }
    out
}

/// Assemble the full stacking request inputs for one task in live mode.
///
/// Returns (raw, sky, cal, shifts, weights) vectors sized for `depth`.
pub fn stack_inputs(
    file: ObjectId,
    pixels: &[i16],
    depth: usize,
    h: usize,
    w: usize,
) -> (Vec<i16>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let raw = cutouts_from_payload(pixels, depth, h, w);
    let mut sky = Vec::with_capacity(depth);
    let mut cal = Vec::with_capacity(depth);
    let mut shifts = Vec::with_capacity(depth * 2);
    for slot in 0..depth as u32 {
        let p = params_for(file, slot);
        sky.push(p.sky);
        cal.push(p.cal);
        shifts.push(p.dx);
        shifts.push(p.dy);
    }
    let weights = vec![1.0; depth];
    (raw, sky, cal, shifts, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_deterministic_and_distinct() {
        let a = params_for(ObjectId(5), 0);
        let b = params_for(ObjectId(5), 0);
        assert_eq!(a, b);
        let c = params_for(ObjectId(5), 1);
        assert_ne!(a, c);
        assert!((10.0..100.0).contains(&a.sky));
        assert!((0.5..2.0).contains(&a.cal));
        assert!((0.0..1.0).contains(&a.dx));
    }

    #[test]
    fn cutouts_wrap_small_payloads() {
        let pixels: Vec<i16> = (0..10).collect();
        let c = cutouts_from_payload(&pixels, 2, 2, 3);
        assert_eq!(c.len(), 12);
        assert_eq!(c[..10], pixels[..]);
        assert_eq!(c[10], 0);
        assert_eq!(c[11], 1);
    }

    #[test]
    fn stack_inputs_shapes() {
        let pixels: Vec<i16> = (0..100).collect();
        let (raw, sky, cal, shifts, weights) = stack_inputs(ObjectId(1), &pixels, 4, 5, 5);
        assert_eq!(raw.len(), 4 * 25);
        assert_eq!(sky.len(), 4);
        assert_eq!(cal.len(), 4);
        assert_eq!(shifts.len(), 8);
        assert_eq!(weights, vec![1.0; 4]);
    }

    #[test]
    fn empty_payload_zero_fills() {
        let c = cutouts_from_payload(&[], 1, 2, 2);
        assert_eq!(c, vec![0; 4]);
    }
}

/// Deterministic sky coordinates (radians) for an object — inputs to the
/// radec2xy phase in live mode. Clustered near the tangent point
/// (0.15, 0.0) used by the e2e driver.
pub fn radec_for(file: ObjectId) -> (f32, f32) {
    let mut rng = Rng::new(file.0 ^ 0x5EC7_0A11);
    (
        (0.15 + rng.range_f64(-0.05, 0.05)) as f32,
        rng.range_f64(-0.05, 0.05) as f32,
    )
}
