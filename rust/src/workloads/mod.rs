//! Workload generators.
//!
//! * [`microbench`] — the §4.3 micro-benchmark matrix: 8 configurations ×
//!   {read, read+write} × node counts × file sizes.
//! * [`astro`] — the §5 stacking workloads derived from SDSS DR5
//!   (Table 2): locality 1 → 30 over 111,700 → 790 files.
//! * [`bursty`] — time-varying (sine / square-burst) demand for the
//!   dynamic-provisioning experiments (`fig_drp`).
//! * [`sky`] — deterministic synthetic image/cutout data for live runs.
//! * [`trace`] — record/replay of task traces (TSV).

pub mod astro;
pub mod bursty;
pub mod microbench;
pub mod sky;
pub mod trace;
