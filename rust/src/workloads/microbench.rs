//! §4.3 micro-benchmark workload generator.
//!
//! "We measured performance for eight configurations, two variants (read
//! and read+write), seven node counts (1..64), and eight file sizes (1B
//! .. 1GB), for a total of 896 experiments."
//!
//! Configurations (1) and (2) are analytic models (see
//! [`crate::analysis::model`]); (3)–(8) are generated here as
//! [`SimWorkloadSpec`]s over the simulated testbed.

use crate::config::Config;
use crate::coordinator::task::{Task, TaskId};
use crate::driver::sim::SimWorkloadSpec;
use crate::scheduler::DispatchPolicy;
use crate::storage::object::{Catalog, DataFormat, ObjectId};

/// The eight §4.3 configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MbConfig {
    /// (1) analytic local-disk model (no simulation).
    ModelLocalDisk,
    /// (2) analytic GPFS model (no simulation).
    ModelGpfs,
    /// (3) Falkon, first-available (no caching, no hints).
    FirstAvailable,
    /// (4) = (3) + sandbox wrapper (mkdir/symlink/rmdir on GPFS).
    FirstAvailableWrapper,
    /// (5) first-cache-available, 0% locality.
    FirstCacheAvail0,
    /// (6) first-cache-available, 100% locality (warm caches).
    FirstCacheAvail100,
    /// (7) max-compute-util, 0% locality.
    MaxComputeUtil0,
    /// (8) max-compute-util, 100% locality (warm caches).
    MaxComputeUtil100,
}

impl MbConfig {
    /// All simulated configurations (3)–(8).
    pub const SIMULATED: [MbConfig; 6] = [
        MbConfig::FirstAvailable,
        MbConfig::FirstAvailableWrapper,
        MbConfig::FirstCacheAvail0,
        MbConfig::FirstCacheAvail100,
        MbConfig::MaxComputeUtil0,
        MbConfig::MaxComputeUtil100,
    ];

    /// Figure label, matching the paper's legend.
    pub fn label(&self) -> &'static str {
        match self {
            MbConfig::ModelLocalDisk => "Model (local disk)",
            MbConfig::ModelGpfs => "Model (persistent storage)",
            MbConfig::FirstAvailable => "Falkon (first-available)",
            MbConfig::FirstAvailableWrapper => "Falkon (first-available) + Wrapper",
            MbConfig::FirstCacheAvail0 => "Falkon (first-cache-available; 0% locality)",
            MbConfig::FirstCacheAvail100 => "Falkon (first-cache-available; 100% locality)",
            MbConfig::MaxComputeUtil0 => "Falkon (max-compute-util; 0% locality)",
            MbConfig::MaxComputeUtil100 => "Falkon (max-compute-util; 100% locality)",
        }
    }

    /// Whether caches are warm at t=0.
    pub fn warm(&self) -> bool {
        matches!(self, MbConfig::FirstCacheAvail100 | MbConfig::MaxComputeUtil100)
    }

    /// Whether data diffusion (caching) is enabled.
    pub fn caching(&self) -> bool {
        !matches!(
            self,
            MbConfig::FirstAvailable | MbConfig::FirstAvailableWrapper
        )
    }

    /// Dispatch policy for the configuration.
    pub fn policy(&self) -> DispatchPolicy {
        match self {
            MbConfig::FirstCacheAvail0 | MbConfig::FirstCacheAvail100 => {
                DispatchPolicy::FirstCacheAvailable
            }
            MbConfig::MaxComputeUtil0 | MbConfig::MaxComputeUtil100 => {
                DispatchPolicy::MaxComputeUtil
            }
            _ => DispatchPolicy::FirstAvailable,
        }
    }
}

/// One generated micro-benchmark experiment, ready to simulate.
pub struct MbExperiment {
    /// Testbed + policy configuration.
    pub config: Config,
    /// The workload.
    pub spec: SimWorkloadSpec,
    /// Object catalog (stored sizes).
    pub catalog: Catalog,
    /// Total payload bytes the tasks read (for throughput math).
    pub read_bytes: u64,
    /// Total payload bytes written.
    pub write_bytes: u64,
}

/// Generate the §4.3 experiment for one (config, nodes, file size,
/// read-or-read+write) cell.
///
/// `tasks_per_node` controls workload length (the paper ran enough tasks
/// to reach steady state; 8/node keeps sims fast while saturating).
pub fn generate(
    mb: MbConfig,
    nodes: usize,
    file_bytes: u64,
    read_write: bool,
    tasks_per_node: usize,
) -> MbExperiment {
    assert!(
        !matches!(mb, MbConfig::ModelLocalDisk | MbConfig::ModelGpfs),
        "configurations (1)/(2) are analytic; use analysis::model"
    );
    let mut config = Config::with_nodes(nodes);
    config.scheduler.policy = mb.policy();
    config.scheduler.wrapper = matches!(mb, MbConfig::FirstAvailableWrapper);

    let n_tasks = (nodes * tasks_per_node) as u64;
    let mut catalog = Catalog::new();
    let mut tasks = Vec::with_capacity(n_tasks as usize);
    let mut prewarm = Vec::new();

    if mb.warm() {
        // 100% locality: one object per node, resident before t=0; each
        // node's tasks re-read objects already somewhere in cache. The
        // paper repeats the 0%-workload 4× over warmed caches; we issue
        // tasks over the warmed set round-robin.
        for node in 0..nodes {
            let obj = ObjectId(node as u64);
            catalog.insert(obj, file_bytes);
            prewarm.push((node, obj));
        }
        for i in 0..n_tasks {
            let obj = ObjectId(i % nodes as u64);
            tasks.push((
                0.0,
                if read_write {
                    Task::read_write(TaskId(i), obj, file_bytes)
                } else {
                    Task::with_inputs(TaskId(i), vec![obj])
                },
            ));
        }
    } else {
        // 0% locality: every task reads a distinct file (no re-use).
        for i in 0..n_tasks {
            let obj = ObjectId(i);
            catalog.insert(obj, file_bytes);
            tasks.push((
                0.0,
                if read_write {
                    Task::read_write(TaskId(i), obj, file_bytes)
                } else {
                    Task::with_inputs(TaskId(i), vec![obj])
                },
            ));
        }
    }

    let read_bytes = n_tasks * file_bytes;
    let write_bytes = if read_write { n_tasks * file_bytes } else { 0 };
    let spec = SimWorkloadSpec {
        tasks,
        caching: mb.caching(),
        format: DataFormat::Fit,
        expansion: 1.0,
        prewarm,
    };
    MbExperiment {
        config,
        spec,
        catalog,
        read_bytes,
        write_bytes,
    }
}

/// The paper's file-size sweep (Fig 5): 1B → 1GB.
pub const FILE_SIZES: [u64; 8] = [
    1,
    1_000,
    10_000,
    100_000,
    1_000_000,
    10_000_000,
    100_000_000,
    1_000_000_000,
];

/// The paper's node-count sweep (Figs 3–4).
pub const NODE_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    #[test]
    fn cold_config_has_unique_objects() {
        let e = generate(MbConfig::MaxComputeUtil0, 4, MB, false, 8);
        assert_eq!(e.catalog.len(), 32);
        assert!(e.spec.prewarm.is_empty());
        assert!(e.spec.caching);
        assert_eq!(e.read_bytes, 32 * MB);
        assert_eq!(e.write_bytes, 0);
    }

    #[test]
    fn warm_config_prewarms_each_node() {
        let e = generate(MbConfig::MaxComputeUtil100, 4, MB, true, 8);
        assert_eq!(e.catalog.len(), 4);
        assert_eq!(e.spec.prewarm.len(), 4);
        assert_eq!(e.write_bytes, 32 * MB);
    }

    #[test]
    fn wrapper_config_sets_wrapper_flag() {
        let e = generate(MbConfig::FirstAvailableWrapper, 2, MB, false, 2);
        assert!(e.config.scheduler.wrapper);
        assert!(!e.spec.caching);
    }

    #[test]
    #[should_panic(expected = "analytic")]
    fn model_configs_rejected() {
        let _ = generate(MbConfig::ModelGpfs, 2, MB, false, 2);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(
            MbConfig::MaxComputeUtil100.label(),
            "Falkon (max-compute-util; 100% locality)"
        );
    }
}
