//! §5 astronomy stacking workloads (Table 2).
//!
//! The working set derives from an SDSS DR5 quasar search (the Figure 6
//! SQL query): 154,345 objects per band in 111,700 files, each file 2 MB
//! compressed / 6 MB uncompressed. Table 2 defines nine workloads whose
//! *data locality* — average objects per file — ranges from 1 to 30.
//!
//! A workload is one stacking task per object; tasks touching the same
//! file exhibit the locality the data-aware scheduler exploits.

use crate::config::Config;
use crate::coordinator::task::{Task, TaskId};
use crate::driver::sim::SimWorkloadSpec;
use crate::storage::object::{Catalog, DataFormat, ObjectId};
use crate::util::rng::Rng;

/// One Table 2 row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadRow {
    /// Data locality (objects per file, on average).
    pub locality: f64,
    /// Number of objects (= tasks).
    pub objects: u64,
    /// Number of distinct files.
    pub files: u64,
}

/// The paper's Table 2, verbatim.
pub const TABLE2: [WorkloadRow; 9] = [
    WorkloadRow { locality: 1.0, objects: 111_700, files: 111_700 },
    WorkloadRow { locality: 1.38, objects: 154_345, files: 111_699 },
    WorkloadRow { locality: 2.0, objects: 97_999, files: 49_000 },
    WorkloadRow { locality: 3.0, objects: 88_857, files: 29_620 },
    WorkloadRow { locality: 4.0, objects: 76_575, files: 19_145 },
    WorkloadRow { locality: 5.0, objects: 60_590, files: 12_120 },
    WorkloadRow { locality: 10.0, objects: 46_480, files: 4_650 },
    WorkloadRow { locality: 20.0, objects: 40_460, files: 2_025 },
    WorkloadRow { locality: 30.0, objects: 23_695, files: 790 },
];

/// Look up the Table 2 row closest to a requested locality.
pub fn row_for_locality(locality: f64) -> WorkloadRow {
    *TABLE2
        .iter()
        .min_by(|a, b| {
            (a.locality - locality)
                .abs()
                .partial_cmp(&(b.locality - locality).abs())
                .unwrap()
        })
        .expect("TABLE2 nonempty")
}

/// A generated stacking workload.
pub struct AstroWorkload {
    /// The Table 2 row it instantiates (possibly scaled).
    pub row: WorkloadRow,
    /// Objects actually generated (after scaling).
    pub objects: u64,
    /// Files actually generated.
    pub files: u64,
    /// The workload spec to simulate.
    pub spec: SimWorkloadSpec,
    /// Stored-size catalog for the files.
    pub catalog: Catalog,
}

/// Generate a Table 2 workload.
///
/// * `row` — which locality row;
/// * `format` — GZ (2 MB stored, ×3 expansion) or FIT (6 MB stored);
/// * `caching` — data diffusion on, or the GPFS-only baseline;
/// * `scale` — subsampling factor in (0, 1] so CI-speed sims keep the
///   objects:files ratio (locality) intact;
/// * `seed` — task-order shuffle seed (object queries arrive in no
///   particular file order, which is what makes locality non-trivial).
pub fn generate(
    cfg: &Config,
    row: WorkloadRow,
    format: DataFormat,
    caching: bool,
    scale: f64,
    seed: u64,
) -> AstroWorkload {
    generate_bands(cfg, row, format, caching, scale, seed, 1)
}

/// Multi-band variant of [`generate`].
///
/// SDSS images every area of sky in five bands (u, g, r, i, z; §5.1:
/// "154,345 objects *per band* ... stored in 111,700 files per band").
/// With `bands > 1` each stacking task reads one file **per band** — a
/// multi-input task that exercises the scheduler's byte-weighted executor
/// choice and the executor's sequential fetch pipeline. Band files are
/// disjoint id ranges (`band * files + file`), as on disk.
pub fn generate_bands(
    cfg: &Config,
    row: WorkloadRow,
    format: DataFormat,
    caching: bool,
    scale: f64,
    seed: u64,
    bands: u32,
) -> AstroWorkload {
    assert!((1..=5).contains(&bands), "SDSS has 5 bands");
    assert!(scale > 0.0 && scale <= 1.0, "scale in (0,1]");
    let files = ((row.files as f64 * scale).round() as u64).max(1);
    let objects = ((row.objects as f64 * scale).round() as u64).max(files);

    let (stored, expansion) = match format {
        DataFormat::Gz => (cfg.app.gz_bytes, cfg.app.fit_bytes as f64 / cfg.app.gz_bytes as f64),
        DataFormat::Fit => (cfg.app.fit_bytes, 1.0),
    };

    let mut catalog = Catalog::new();
    for b in 0..bands as u64 {
        for f in 0..files {
            catalog.insert(ObjectId(b * files + f), stored);
        }
    }

    // Object -> file assignment: object i lives in file i % files (in
    // every band), giving each file ~locality objects. Task order is
    // shuffled so consecutive tasks do not trivially share files.
    let mut order: Vec<u64> = (0..objects).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);

    let depth = row.locality.round().max(1.0) as u32;
    let tasks: Vec<(f64, Task)> = order
        .iter()
        .enumerate()
        .map(|(i, &obj)| {
            let inputs: Vec<ObjectId> = (0..bands as u64)
                .map(|b| ObjectId(b * files + obj % files))
                .collect();
            let mut t = Task::stacking(TaskId(i as u64), inputs[0], depth, cfg.app.output_bytes);
            t.inputs = inputs;
            (0.0, t)
        })
        .collect();

    AstroWorkload {
        row,
        objects,
        files: files * bands as u64,
        spec: SimWorkloadSpec {
            tasks,
            caching,
            format,
            expansion,
            prewarm: Vec::new(),
        },
        catalog,
    }
}

/// Ideal cache-hit ratio for a locality (Fig 10's reference line):
/// each file is accessed `locality` times — one cold miss, the rest hits.
pub fn ideal_hit_ratio(locality: f64) -> f64 {
    if locality <= 1.0 {
        0.0
    } else {
        1.0 - 1.0 / locality
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        assert_eq!(TABLE2.len(), 9);
        assert_eq!(TABLE2[1].objects, 154_345);
        assert_eq!(TABLE2[8].files, 790);
        // Locality ≈ objects / files for every row.
        for row in &TABLE2 {
            let implied = row.objects as f64 / row.files as f64;
            assert!(
                (implied - row.locality).abs() / row.locality < 0.35,
                "row {row:?} implied locality {implied}"
            );
        }
    }

    #[test]
    fn generator_preserves_locality_under_scaling() {
        let cfg = Config::with_nodes(4);
        let row = TABLE2[6]; // locality 10
        let w = generate(&cfg, row, DataFormat::Gz, true, 0.01, 42);
        let implied = w.objects as f64 / w.files as f64;
        assert!((implied - 10.0).abs() < 1.0, "implied={implied}");
        assert_eq!(w.spec.tasks.len(), w.objects as usize);
        assert_eq!(w.catalog.len(), w.files as usize);
    }

    #[test]
    fn gz_format_sets_expansion() {
        let cfg = Config::with_nodes(2);
        let w = generate(&cfg, TABLE2[0], DataFormat::Gz, true, 0.001, 1);
        assert!((w.spec.expansion - 3.0).abs() < 1e-9);
        let w = generate(&cfg, TABLE2[0], DataFormat::Fit, true, 0.001, 1);
        assert!((w.spec.expansion - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = Config::with_nodes(2);
        let a = generate(&cfg, TABLE2[3], DataFormat::Gz, true, 0.01, 7);
        let b = generate(&cfg, TABLE2[3], DataFormat::Gz, true, 0.01, 7);
        assert_eq!(a.spec.tasks.len(), b.spec.tasks.len());
        for (x, y) in a.spec.tasks.iter().zip(&b.spec.tasks) {
            assert_eq!(x.1.inputs, y.1.inputs);
        }
    }

    #[test]
    fn ideal_hit_ratio_formula() {
        assert_eq!(ideal_hit_ratio(1.0), 0.0);
        assert!((ideal_hit_ratio(3.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((ideal_hit_ratio(30.0) - 29.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn multi_band_tasks_read_one_file_per_band() {
        let cfg = Config::with_nodes(4);
        let w = generate_bands(&cfg, TABLE2[6], DataFormat::Gz, true, 0.01, 3, 5);
        assert_eq!(w.catalog.len() as u64, w.files, "5 bands of files");
        for (_, t) in &w.spec.tasks {
            assert_eq!(t.inputs.len(), 5);
            // All five inputs map to the same per-band file offset.
            let base = t.inputs[0].0;
            let per_band = w.files / 5;
            for (b, obj) in t.inputs.iter().enumerate() {
                assert_eq!(obj.0, base + b as u64 * per_band);
            }
        }
    }

    #[test]
    fn multi_band_workload_completes_in_sim() {
        use crate::driver::sim::SimDriver;
        let cfg = Config::with_nodes(8);
        let w = generate_bands(&cfg, TABLE2[8], DataFormat::Gz, true, 0.01, 3, 5);
        let n = w.spec.tasks.len() as u64;
        let out = SimDriver::new(cfg, w.spec, w.catalog).run();
        assert_eq!(out.metrics.tasks_done, n);
        // Five inputs per task -> five resolutions per task.
        let m = &out.metrics;
        assert_eq!(m.cache_hits + m.peer_hits + m.gpfs_misses, 5 * n);
    }

    #[test]
    fn closest_row_lookup() {
        assert_eq!(row_for_locality(1.4).locality, 1.38);
        assert_eq!(row_for_locality(26.0).locality, 30.0);
    }
}
