//! Bursty / sine demand workload generator.
//!
//! The paper's evaluation holds the executor pool static; its companion
//! (Raicu et al., "Data Diffusion: Dynamic Resource Provision and
//! Data-Aware Scheduling") evaluates exactly the opposite regime —
//! demand that rises and falls so the provisioner has something to
//! track. This generator produces that regime deterministically: task
//! arrivals follow a time-varying rate λ(t) (sine swell or square
//! bursts), drawing inputs uniformly from a fixed object population so
//! caches warm up during a burst and the post-churn hit-ratio recovery
//! is observable in the [`crate::coordinator::metrics::PoolSample`]
//! timeline.
//!
//! Arrival times come from integrating λ(t) with a fixed step and
//! emitting a task whenever the accumulated intensity crosses 1 — no
//! randomness in the *times*, so runs replay identically; only the
//! object choice uses the seeded [`Rng`].

use crate::coordinator::task::{Task, TaskId, TaskKind};
use crate::driver::sim::SimWorkloadSpec;
use crate::storage::object::{Catalog, ObjectId};
use crate::util::rng::Rng;

/// Shape of the demand curve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DemandShape {
    /// Smooth swell: λ(t) = base + (peak−base) · ½(1 − cos(2πt/period)).
    Sine,
    /// On/off bursts: λ = peak for the first `duty` fraction of each
    /// period, `base` for the rest.
    Square,
}

impl DemandShape {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<DemandShape> {
        match s.to_ascii_lowercase().as_str() {
            "sine" => Some(DemandShape::Sine),
            "square" | "bursts" => Some(DemandShape::Square),
            _ => None,
        }
    }
}

/// Parameters of a bursty workload.
#[derive(Debug, Clone)]
pub struct BurstSpec {
    /// Demand shape.
    pub shape: DemandShape,
    /// Total tasks to emit.
    pub tasks: u64,
    /// Distinct objects drawn uniformly (smaller = more cache reuse).
    pub objects: u64,
    /// Stored size of every object, bytes.
    pub object_bytes: u64,
    /// Demand period, seconds.
    pub period_s: f64,
    /// Arrival-rate floor, tasks/s.
    pub base_rate: f64,
    /// Arrival rate at the crest, tasks/s.
    pub peak_rate: f64,
    /// Square shape only: fraction of each period spent at peak.
    pub duty: f64,
    /// CPU seconds each task burns after its input is resolved. This is
    /// what makes demand *mean* something: with zero compute a single
    /// executor absorbs any realistic arrival rate and the provisioner
    /// never has a reason to grow.
    pub task_cpu_s: f64,
}

impl Default for BurstSpec {
    fn default() -> Self {
        BurstSpec {
            shape: DemandShape::Square,
            tasks: 512,
            objects: 64,
            object_bytes: crate::util::units::MB,
            period_s: 150.0,
            base_rate: 0.0,
            peak_rate: 8.0,
            duty: 0.3,
            task_cpu_s: 1.0,
        }
    }
}

/// A generated bursty workload, ready to simulate.
#[derive(Debug, Clone)]
pub struct BurstyWorkload {
    /// The workload spec (caching on, uncompressed data).
    pub spec: SimWorkloadSpec,
    /// Object catalog.
    pub catalog: Catalog,
    /// Arrival time of the last task, seconds.
    pub horizon_s: f64,
}

/// Instantaneous arrival rate at time `t`, tasks/s.
pub fn rate_at(spec: &BurstSpec, t: f64) -> f64 {
    let period = spec.period_s.max(1e-9);
    match spec.shape {
        DemandShape::Sine => {
            let swell = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * t / period).cos());
            spec.base_rate + (spec.peak_rate - spec.base_rate) * swell
        }
        DemandShape::Square => {
            let phase = (t / period).fract();
            if phase < spec.duty.clamp(0.0, 1.0) {
                spec.peak_rate
            } else {
                spec.base_rate
            }
        }
    }
}

/// Generate the workload. Deterministic per (spec, seed).
pub fn generate(spec: &BurstSpec, seed: u64) -> BurstyWorkload {
    // The demand curve must actually emit: a square wave with zero duty
    // and zero base, or a non-positive peak, would loop forever.
    let emits = match spec.shape {
        DemandShape::Sine => spec.peak_rate > 0.0 || spec.base_rate > 0.0,
        DemandShape::Square => {
            spec.base_rate > 0.0 || (spec.peak_rate > 0.0 && spec.duty > 0.0)
        }
    };
    assert!(
        emits,
        "demand curve never emits a task: {:?} with base {} / peak {} / duty {}",
        spec.shape, spec.base_rate, spec.peak_rate, spec.duty
    );
    let mut rng = Rng::new(seed);
    let objects = spec.objects.max(1);
    let mut catalog = Catalog::new();
    for i in 0..objects {
        catalog.insert(ObjectId(i), spec.object_bytes.max(1));
    }

    let dt = (spec.period_s / 1000.0).clamp(1e-3, 1.0);
    let mut tasks: Vec<(f64, Task)> = Vec::with_capacity(spec.tasks as usize);
    let mut acc = 0.0;
    let mut t = 0.0;
    while (tasks.len() as u64) < spec.tasks {
        // Backstop against degenerate-but-emitting specs (e.g. a peak of
        // 1e-9 tasks/s): fail loudly rather than spinning for minutes.
        assert!(
            t < 1e8,
            "bursty generator emitted only {}/{} tasks by t=1e8 s — rate too low",
            tasks.len(),
            spec.tasks
        );
        acc += rate_at(spec, t).max(0.0) * dt;
        while acc >= 1.0 && (tasks.len() as u64) < spec.tasks {
            acc -= 1.0;
            let id = TaskId(tasks.len() as u64);
            let obj = ObjectId(rng.below(objects));
            let mut task = Task::with_inputs(id, vec![obj]);
            task.kind = TaskKind::Synthetic {
                cpu_s: spec.task_cpu_s.max(0.0),
            };
            tasks.push((t, task));
        }
        t += dt;
    }
    let horizon_s = tasks.last().map(|(t, _)| *t).unwrap_or(0.0);
    BurstyWorkload {
        spec: SimWorkloadSpec::new(tasks),
        catalog,
        horizon_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_exactly_the_requested_tasks_in_time_order() {
        let spec = BurstSpec::default();
        let w = generate(&spec, 7);
        assert_eq!(w.spec.tasks.len() as u64, spec.tasks);
        let mut last = 0.0;
        for (t, task) in &w.spec.tasks {
            assert!(*t >= last, "arrivals must be nondecreasing");
            last = *t;
            assert!(w.catalog.size(task.inputs[0]).is_some());
        }
        assert!((w.horizon_s - last).abs() < 1e-12);
    }

    #[test]
    fn square_bursts_leave_a_quiet_lull() {
        let spec = BurstSpec {
            shape: DemandShape::Square,
            tasks: 200,
            period_s: 100.0,
            base_rate: 0.0,
            peak_rate: 4.0,
            duty: 0.25,
            ..BurstSpec::default()
        };
        let w = generate(&spec, 1);
        // No arrival may land in the off-phase of a period.
        for (t, _) in &w.spec.tasks {
            let phase = (t / spec.period_s).fract();
            assert!(
                phase <= spec.duty + 0.02,
                "arrival at t={t} (phase {phase}) during the lull"
            );
        }
        // The workload spans more than one period (so churn can happen).
        assert!(w.horizon_s > spec.period_s);
    }

    #[test]
    fn sine_concentrates_arrivals_at_the_crest() {
        let spec = BurstSpec {
            shape: DemandShape::Sine,
            tasks: 400,
            period_s: 100.0,
            base_rate: 0.5,
            peak_rate: 8.0,
            ..BurstSpec::default()
        };
        let w = generate(&spec, 3);
        // Crest half of the period (phase 0.25..0.75) gets most arrivals.
        let crest = w
            .spec
            .tasks
            .iter()
            .filter(|(t, _)| {
                let phase = (t / spec.period_s).fract();
                (0.25..0.75).contains(&phase)
            })
            .count();
        assert!(
            crest as f64 > 0.6 * w.spec.tasks.len() as f64,
            "crest got only {crest}/{}",
            w.spec.tasks.len()
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = BurstSpec::default();
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a.spec.tasks, b.spec.tasks);
        let c = generate(&spec, 43);
        assert!(
            a.spec
                .tasks
                .iter()
                .zip(c.spec.tasks.iter())
                .any(|((_, x), (_, y))| x.inputs != y.inputs),
            "different seeds should draw different objects"
        );
    }

    #[test]
    fn shape_parse() {
        assert_eq!(DemandShape::parse("sine"), Some(DemandShape::Sine));
        assert_eq!(DemandShape::parse("Square"), Some(DemandShape::Square));
        assert_eq!(DemandShape::parse("triangle"), None);
    }
}
