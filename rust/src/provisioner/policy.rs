//! Dynamic resource provisioning policies.
//!
//! Falkon's DRP grows the executor pool in response to wait-queue
//! pressure and releases executors after an idle timeout. The allocation
//! policies mirror those described for Falkon's provisioner: one-at-a-time
//! conservative growth, all-at-once aggressive growth, and an additive
//! adaptive middle ground.

/// How aggressively to grow the executor pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocationPolicy {
    /// Request one executor per provisioning round.
    OneAtATime,
    /// Request everything up to the configured maximum immediately.
    AllAtOnce,
    /// Grow toward `ceil(queued / queue_per_executor)` total executors,
    /// i.e. growth proportional to backlog (already-allocated and
    /// in-flight requests count against the target).
    Adaptive,
}

impl AllocationPolicy {
    /// Parse from config text.
    pub fn parse(s: &str) -> Option<AllocationPolicy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "one-at-a-time" => Some(AllocationPolicy::OneAtATime),
            "all-at-once" => Some(AllocationPolicy::AllAtOnce),
            "adaptive" => Some(AllocationPolicy::Adaptive),
            _ => None,
        }
    }

    /// Display label (CLI/figure naming, kebab-case).
    pub fn label(&self) -> &'static str {
        match self {
            AllocationPolicy::OneAtATime => "one-at-a-time",
            AllocationPolicy::AllAtOnce => "all-at-once",
            AllocationPolicy::Adaptive => "adaptive",
        }
    }

    /// How many additional executors to request, given the backlog and
    /// the remaining headroom.
    pub fn grow_by(
        &self,
        queued: usize,
        allocated: usize,
        max: usize,
        queue_per_executor: usize,
    ) -> usize {
        let headroom = max.saturating_sub(allocated);
        if headroom == 0 || queued == 0 {
            return 0;
        }
        match self {
            AllocationPolicy::OneAtATime => 1,
            AllocationPolicy::AllAtOnce => headroom,
            AllocationPolicy::Adaptive => {
                let want_total = queued.div_ceil(queue_per_executor.max(1));
                want_total.saturating_sub(allocated).min(headroom)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_at_a_time_is_conservative() {
        let p = AllocationPolicy::OneAtATime;
        assert_eq!(p.grow_by(100, 0, 64, 4), 1);
        assert_eq!(p.grow_by(100, 64, 64, 4), 0);
        assert_eq!(p.grow_by(0, 0, 64, 4), 0);
    }

    #[test]
    fn all_at_once_takes_headroom() {
        let p = AllocationPolicy::AllAtOnce;
        assert_eq!(p.grow_by(1, 10, 64, 4), 54);
    }

    #[test]
    fn adaptive_scales_with_backlog() {
        let p = AllocationPolicy::Adaptive;
        assert_eq!(p.grow_by(16, 0, 64, 4), 4);
        assert_eq!(p.grow_by(1000, 0, 64, 4), 64);
        assert_eq!(p.grow_by(3, 0, 64, 4), 1);
    }

    #[test]
    fn parse_names() {
        assert_eq!(
            AllocationPolicy::parse("all-at-once"),
            Some(AllocationPolicy::AllAtOnce)
        );
        assert_eq!(
            AllocationPolicy::parse("one_at_a_time"),
            Some(AllocationPolicy::OneAtATime)
        );
        assert_eq!(AllocationPolicy::parse("nope"), None);
        assert_eq!(AllocationPolicy::Adaptive.label(), "adaptive");
        for p in [
            AllocationPolicy::OneAtATime,
            AllocationPolicy::AllAtOnce,
            AllocationPolicy::Adaptive,
        ] {
            assert_eq!(AllocationPolicy::parse(p.label()), Some(p), "round-trip");
        }
    }
}
