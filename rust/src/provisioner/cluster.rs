//! Simulated cluster provider (the GRAM4 + batch-scheduler stand-in).
//!
//! Allocation requests complete after a configurable latency (GRAM4 job
//! submission + LRM scheduling were tens of seconds on the paper's
//! testbed). The provider owns the pool of node ids and guarantees an id
//! is never double-allocated.

use std::collections::BTreeSet;

/// A pending allocation request.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingAllocation {
    /// When the executors come up.
    pub ready_at: f64,
    /// The node ids being brought up.
    pub nodes: Vec<usize>,
}

/// Simulated GRAM4-like provider.
#[derive(Debug)]
pub struct ClusterProvider {
    free: BTreeSet<usize>,
    latency_s: f64,
}

impl ClusterProvider {
    /// Provider over `total_nodes` nodes with the given allocation latency.
    pub fn new(total_nodes: usize, latency_s: f64) -> Self {
        ClusterProvider::with_range(0..total_nodes, latency_s)
    }

    /// Provider over an explicit node-id range (a federation site's
    /// executor slice; `with_range(0..n, l)` ≡ `new(n, l)`).
    pub fn with_range(range: std::ops::Range<usize>, latency_s: f64) -> Self {
        ClusterProvider {
            free: range.collect(),
            latency_s,
        }
    }

    /// Nodes still available.
    pub fn free_nodes(&self) -> usize {
        self.free.len()
    }

    /// Request `count` nodes at time `now`; grants as many as exist
    /// (possibly fewer), becoming ready after the allocation latency.
    pub fn allocate(&mut self, now: f64, count: usize) -> PendingAllocation {
        let nodes: Vec<usize> = self.free.iter().take(count).copied().collect();
        for n in &nodes {
            self.free.remove(n);
        }
        PendingAllocation {
            ready_at: now + self.latency_s,
            nodes,
        }
    }

    /// Return a node to the pool.
    pub fn release(&mut self, node: usize) {
        self.free.insert(node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_has_latency_and_unique_ids() {
        let mut c = ClusterProvider::new(4, 40.0);
        let a = c.allocate(10.0, 2);
        assert_eq!(a.ready_at, 50.0);
        assert_eq!(a.nodes, vec![0, 1]);
        let b = c.allocate(10.0, 5); // only 2 left
        assert_eq!(b.nodes, vec![2, 3]);
        assert_eq!(c.free_nodes(), 0);
    }

    #[test]
    fn release_recycles() {
        let mut c = ClusterProvider::new(2, 1.0);
        let a = c.allocate(0.0, 2);
        c.release(a.nodes[0]);
        let b = c.allocate(5.0, 1);
        assert_eq!(b.nodes, vec![0]);
    }
}
