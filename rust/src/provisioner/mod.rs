//! Dynamic resource provisioner (DRP, §3.1).
//!
//! Manages the creation and deletion of executors: watches wait-queue
//! pressure, requests node allocations from a (simulated GRAM4-like)
//! cluster provider with realistic allocation latency, and releases
//! executors that sit idle past a timeout. The paper's experiments hold
//! the pool static ("we will address dynamic provisioning in future
//! work") — our benches do too — but the mechanism is implemented and
//! tested, and `examples/quickstart.rs` exercises it.

pub mod cluster;
pub mod policy;

pub use cluster::ClusterProvider;
pub use policy::AllocationPolicy;

use crate::config::ProvisionerConfig;

/// A provisioning decision for the driver to execute.
#[derive(Debug, Clone, PartialEq)]
pub enum ProvisionAction {
    /// Ask the cluster for `count` more executors.
    Allocate {
        /// Number of executors to request.
        count: usize,
    },
    /// Release these idle executors back to the cluster.
    Release {
        /// Executor ids to release.
        executors: Vec<usize>,
    },
}

/// Tracks idle spans and produces allocate/release actions.
#[derive(Debug)]
pub struct Provisioner {
    cfg: ProvisionerConfig,
    allocated: usize,
    pending: usize,
    idle_since: Vec<(usize, f64)>, // (executor, idle-start time)
}

impl Provisioner {
    /// New provisioner.
    pub fn new(cfg: ProvisionerConfig) -> Self {
        Provisioner {
            cfg,
            allocated: 0,
            pending: 0,
            idle_since: Vec::new(),
        }
    }

    /// Currently allocated (live) executor count.
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Requested-but-not-yet-live executor count.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// An allocation request completed; executors are live.
    pub fn on_allocated(&mut self, count: usize) {
        self.pending = self.pending.saturating_sub(count);
        self.allocated += count;
    }

    /// Executor became idle at time `now` (candidate for release).
    pub fn note_idle(&mut self, executor: usize, now: f64) {
        if !self.idle_since.iter().any(|&(e, _)| e == executor) {
            self.idle_since.push((executor, now));
        }
    }

    /// Executor got work again; cancel its idle clock.
    pub fn note_busy(&mut self, executor: usize) {
        self.idle_since.retain(|&(e, _)| e != executor);
    }

    /// Executor released (driver confirmed).
    pub fn on_released(&mut self, executor: usize) {
        self.allocated = self.allocated.saturating_sub(1);
        self.note_busy(executor);
    }

    /// Evaluate the provisioning policy. `queued` is the current wait
    /// queue length; `now` is the current time.
    pub fn evaluate(&mut self, queued: usize, now: f64) -> Vec<ProvisionAction> {
        let mut actions = Vec::new();

        // Growth: queue pressure, bounded by max and in-flight requests.
        let effective = self.allocated + self.pending;
        let grow = self.cfg.policy.grow_by(
            queued,
            effective,
            self.cfg.max_executors,
            self.cfg.queue_per_executor,
        );
        if grow > 0 {
            self.pending += grow;
            actions.push(ProvisionAction::Allocate { count: grow });
        }

        // Shrink: idle past the timeout, but never below min_executors.
        let min = self.cfg.min_executors;
        let mut releasable: Vec<usize> = self
            .idle_since
            .iter()
            .filter(|&&(_, t0)| now - t0 >= self.cfg.idle_release_s)
            .map(|&(e, _)| e)
            .collect();
        let can_release = self.allocated.saturating_sub(min);
        releasable.truncate(can_release);
        if !releasable.is_empty() && queued == 0 {
            self.idle_since.retain(|(e, _)| !releasable.contains(e));
            actions.push(ProvisionAction::Release {
                executors: releasable,
            });
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProvisionerConfig;

    fn cfg() -> ProvisionerConfig {
        ProvisionerConfig {
            policy: AllocationPolicy::Adaptive,
            min_executors: 1,
            max_executors: 8,
            allocation_latency_s: 40.0,
            idle_release_s: 60.0,
            queue_per_executor: 2,
        }
    }

    #[test]
    fn grows_under_pressure() {
        let mut p = Provisioner::new(cfg());
        let actions = p.evaluate(10, 0.0);
        assert_eq!(actions, vec![ProvisionAction::Allocate { count: 5 }]);
        // Pending requests suppress duplicate growth.
        let actions = p.evaluate(10, 1.0);
        assert!(actions.is_empty());
        p.on_allocated(5);
        assert_eq!(p.allocated(), 5);
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn respects_max() {
        let mut p = Provisioner::new(cfg());
        let a = p.evaluate(1000, 0.0);
        assert_eq!(a, vec![ProvisionAction::Allocate { count: 8 }]);
    }

    #[test]
    fn releases_after_idle_timeout_only_when_quiet() {
        let mut p = Provisioner::new(cfg());
        p.on_allocated(3);
        p.note_idle(0, 0.0);
        p.note_idle(1, 0.0);
        p.note_idle(2, 0.0);
        // Too early.
        assert!(p.evaluate(0, 30.0).is_empty());
        // Past timeout: release down to min (1), i.e. 2 executors.
        let a = p.evaluate(0, 61.0);
        match &a[..] {
            [ProvisionAction::Release { executors }] => assert_eq!(executors.len(), 2),
            other => panic!("unexpected {other:?}"),
        }
        // Queue pressure blocks release.
        let mut p = Provisioner::new(cfg());
        p.on_allocated(2);
        p.note_idle(0, 0.0);
        let a = p.evaluate(5, 100.0);
        assert!(matches!(a[0], ProvisionAction::Allocate { .. }));
    }

    #[test]
    fn busy_cancels_idle_clock() {
        let mut p = Provisioner::new(cfg());
        p.on_allocated(2);
        p.note_idle(0, 0.0);
        p.note_busy(0);
        assert!(p.evaluate(0, 100.0).is_empty());
    }
}
