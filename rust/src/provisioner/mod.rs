//! Dynamic resource provisioner (DRP, §3.1).
//!
//! Manages the creation and deletion of executors: watches wait-queue
//! pressure, requests node allocations from a (simulated GRAM4-like)
//! cluster provider with realistic allocation latency, and releases
//! executors that sit idle past a timeout. Since the elastic-pool
//! refactor this is no longer a side-car: both drivers run it on the
//! dispatch path when `provisioner.enabled` is set —
//!
//! * [`crate::driver::sim::SimDriver`] evaluates it on a periodic
//!   `ProvisionTick` event, grants arrive through `AllocReady` events
//!   after the provider's allocation latency, and executors join/leave
//!   the [`crate::coordinator::core::FalkonCore`] (and its
//!   [`crate::index::DataIndex`] backend) *mid-run*;
//! * [`crate::driver::live::LiveCluster`] does the same on wall-clock
//!   time, spawning and reaping real executor threads.
//!
//! The demand signal is the wait queue's high-water mark since the last
//! evaluation ([`crate::scheduler::queue::WaitQueue::take_peak`]); the
//! release signal is per-executor quiescence tracked via
//! [`Provisioner::note_idle`]/[`Provisioner::note_busy`]. The three
//! [`AllocationPolicy`] variants are compared on real scheduled runs by
//! `falkon sweep --figure drp` (see `crate::analysis::figures::fig_drp`).

pub mod cluster;
pub mod policy;

pub use cluster::ClusterProvider;
pub use policy::AllocationPolicy;

use crate::config::ProvisionerConfig;
use crate::util::fxhash::FxHashMap;

/// A provisioning decision for the driver to execute.
#[derive(Debug, Clone, PartialEq)]
pub enum ProvisionAction {
    /// Ask the cluster for `count` more executors.
    Allocate {
        /// Number of executors to request.
        count: usize,
    },
    /// Release these idle executors back to the cluster.
    Release {
        /// Executor ids to release.
        executors: Vec<usize>,
    },
}

/// Tracks idle spans and produces allocate/release actions.
#[derive(Debug)]
pub struct Provisioner {
    cfg: ProvisionerConfig,
    allocated: usize,
    pending: usize,
    // FxHashMap like the rest of the dispatch-adjacent state: note_idle /
    // note_busy run per executor per evaluation round.
    idle_since: FxHashMap<usize, f64>, // executor -> idle-start time
}

impl Provisioner {
    /// New provisioner.
    pub fn new(cfg: ProvisionerConfig) -> Self {
        Provisioner {
            cfg,
            allocated: 0,
            pending: 0,
            idle_since: FxHashMap::default(),
        }
    }

    /// Currently allocated (live) executor count.
    pub fn allocated(&self) -> usize {
        self.allocated
    }

    /// Requested-but-not-yet-live executor count.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// An allocation request completed; executors are live.
    pub fn on_allocated(&mut self, count: usize) {
        self.pending = self.pending.saturating_sub(count);
        self.allocated += count;
    }

    /// An allocation request was short-granted (the cluster had fewer
    /// free nodes than asked): forget the shortfall so it does not block
    /// future growth forever.
    pub fn cancel_pending(&mut self, count: usize) {
        self.pending = self.pending.saturating_sub(count);
    }

    /// Executor became idle at time `now` (candidate for release).
    pub fn note_idle(&mut self, executor: usize, now: f64) {
        self.idle_since.entry(executor).or_insert(now);
    }

    /// Executor got work again; cancel its idle clock.
    pub fn note_busy(&mut self, executor: usize) {
        self.idle_since.remove(&executor);
    }

    /// Executor released (driver confirmed).
    pub fn on_released(&mut self, executor: usize) {
        self.allocated = self.allocated.saturating_sub(1);
        self.note_busy(executor);
    }

    /// Evaluate the provisioning policy. `queued` is the current wait
    /// queue length (or its high-water mark since the last evaluation);
    /// `now` is the current time.
    pub fn evaluate(&mut self, queued: usize, now: f64) -> Vec<ProvisionAction> {
        let mut actions = Vec::new();

        // Growth: queue pressure, bounded by max and in-flight requests.
        let effective = self.allocated + self.pending;
        let grow = self.cfg.policy.grow_by(
            queued,
            effective,
            self.cfg.max_executors,
            self.cfg.queue_per_executor,
        );
        if grow > 0 {
            self.pending += grow;
            actions.push(ProvisionAction::Allocate { count: grow });
        }

        // Shrink: idle past the timeout, but never below min_executors.
        // Longest-idle first (ties to the lower id) so release order is
        // deterministic regardless of hash-map iteration order.
        let min = self.cfg.min_executors;
        let mut candidates: Vec<(f64, usize)> = self
            .idle_since
            .iter()
            .filter(|&(_, &t0)| now - t0 >= self.cfg.idle_release_s)
            .map(|(&e, &t0)| (t0, e))
            .collect();
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let can_release = self.allocated.saturating_sub(min);
        candidates.truncate(can_release);
        let releasable: Vec<usize> = candidates.into_iter().map(|(_, e)| e).collect();
        if !releasable.is_empty() && queued == 0 {
            for e in &releasable {
                self.idle_since.remove(e);
            }
            actions.push(ProvisionAction::Release {
                executors: releasable,
            });
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProvisionerConfig;

    fn cfg() -> ProvisionerConfig {
        ProvisionerConfig {
            policy: AllocationPolicy::Adaptive,
            min_executors: 1,
            max_executors: 8,
            allocation_latency_s: 40.0,
            idle_release_s: 60.0,
            queue_per_executor: 2,
            ..ProvisionerConfig::default()
        }
    }

    #[test]
    fn grows_under_pressure() {
        let mut p = Provisioner::new(cfg());
        let actions = p.evaluate(10, 0.0);
        assert_eq!(actions, vec![ProvisionAction::Allocate { count: 5 }]);
        // Pending requests suppress duplicate growth.
        let actions = p.evaluate(10, 1.0);
        assert!(actions.is_empty());
        p.on_allocated(5);
        assert_eq!(p.allocated(), 5);
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn respects_max() {
        let mut p = Provisioner::new(cfg());
        let a = p.evaluate(1000, 0.0);
        assert_eq!(a, vec![ProvisionAction::Allocate { count: 8 }]);
    }

    #[test]
    fn releases_after_idle_timeout_only_when_quiet() {
        let mut p = Provisioner::new(cfg());
        p.on_allocated(3);
        p.note_idle(0, 0.0);
        p.note_idle(1, 0.0);
        p.note_idle(2, 0.0);
        // Too early.
        assert!(p.evaluate(0, 30.0).is_empty());
        // Past timeout: release down to min (1), i.e. 2 executors.
        let a = p.evaluate(0, 61.0);
        match &a[..] {
            [ProvisionAction::Release { executors }] => {
                assert_eq!(executors, &[0, 1], "longest-idle first, id tiebreak")
            }
            other => panic!("unexpected {other:?}"),
        }
        // Queue pressure blocks release.
        let mut p = Provisioner::new(cfg());
        p.on_allocated(2);
        p.note_idle(0, 0.0);
        let a = p.evaluate(5, 100.0);
        assert!(matches!(a[0], ProvisionAction::Allocate { .. }));
    }

    #[test]
    fn busy_cancels_idle_clock() {
        let mut p = Provisioner::new(cfg());
        p.on_allocated(2);
        p.note_idle(0, 0.0);
        p.note_busy(0);
        assert!(p.evaluate(0, 100.0).is_empty());
    }

    #[test]
    fn repeated_note_idle_keeps_first_timestamp() {
        let mut p = Provisioner::new(cfg());
        p.on_allocated(2);
        p.note_idle(0, 0.0);
        p.note_idle(0, 59.0); // must not reset the clock
        let a = p.evaluate(0, 61.0);
        assert!(
            matches!(&a[..], [ProvisionAction::Release { executors }] if executors == &[0]),
            "unexpected {a:?}"
        );
    }

    #[test]
    fn cancel_pending_unblocks_growth() {
        let mut p = Provisioner::new(cfg());
        let _ = p.evaluate(16, 0.0); // pending = 8 (cap)
        assert_eq!(p.pending(), 8);
        p.on_allocated(3); // short grant: only 3 of 8 came up
        p.cancel_pending(5);
        assert_eq!(p.pending(), 0);
        assert_eq!(p.allocated(), 3);
        let a = p.evaluate(16, 1.0);
        assert_eq!(a, vec![ProvisionAction::Allocate { count: 5 }]);
    }
}
