//! # Data Diffusion
//!
//! A production-quality reproduction of **"Accelerating Large-Scale Data
//! Exploration through Data Diffusion"** (Raicu, Zhao, Foster, Szalay;
//! 2008) — dynamic resource provisioning + per-executor data caching +
//! data-aware task scheduling, in the three-layer Rust / JAX / Pallas
//! architecture:
//!
//! * **Layer 3 (this crate)** — the Falkon-style coordinator: wait queue,
//!   dispatcher with the paper's four scheduling policies, centralized
//!   cache-location index, executor caches (Random/FIFO/LRU/LFU), dynamic
//!   resource provisioner, and the simulated + live execution substrates.
//! * **Layer 2 (`python/compile/model.py`)** — the astronomy image
//!   stacking compute graph in JAX, AOT-lowered to HLO text once at build
//!   time.
//! * **Layer 1 (`python/compile/kernels/stacking.py`)** — the
//!   calibrate + shift + coadd hot loop as a Pallas kernel.
//!
//! The Rust binary executes the AOT artifacts through PJRT
//! ([`runtime`]); Python never runs on the request path.
//!
//! ## Map
//!
//! | Paper section | Module |
//! |---|---|
//! | §3.1 Falkon dispatcher | [`coordinator`] |
//! | Sharded, batched dispatch core (`--shards`, work stealing, `--figure shards`) | [`coordinator::sharded`] |
//! | Per-shard dispatcher threads in the live driver (per-shard report channels, cross-thread steals) | [`driver::live`] |
//! | §3.2.2 eviction + dispatch policies | [`cache`], [`scheduler`] |
//! | §3.2.3 centralized index, P-RLS | [`index`] |
//! | §3.1 DRP (elastic pools, both drivers) | [`provisioner`], [`driver`] |
//! | Demand-driven replication ("data diffusion" proper) | [`replication`] |
//! | Metered transfer plane (classes, share policies, weighted fair shares) | [`transfer`] |
//! | Weighted max-min flow network (per-class flow weights) | [`sim::flownet`] |
//! | DRP demand-response figure (`--figure drp`) | [`analysis::figures`], [`workloads::bursty`] |
//! | Diffusion figure (`--figure diffusion`, replication on/off) | [`analysis::figures`] |
//! | QoS figure (`--figure qos`, share policy off/binary/weighted) | [`analysis::figures`] |
//! | Simulator scalability figure (`--figure scale`, events/sec, peak RSS) | [`analysis::figures`], [`sim::engine`] |
//! | Multi-cluster federation: site topology, WAN fabric, affinity placement (`--figure federation`, Pilot-Data) | [`federation`] |
//! | Parallel event execution across sites (`--threads`, conservative lookahead, deterministic merge) | [`sim::parallel`] |
//! | §4 testbed + storage | [`storage`], [`sim`] |
//! | §4.3 micro-benchmarks | [`workloads::microbench`], [`analysis`] |
//! | §5 stacking application | [`workloads::astro`], [`runtime`] |
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod analysis;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod driver;
pub mod error;
pub mod federation;
pub mod index;
pub mod provisioner;
pub mod replication;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod storage;
pub mod transfer;
pub mod util;
pub mod workloads;

pub use config::Config;
pub use error::{Error, Result};
