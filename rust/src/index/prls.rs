//! Analytic P-RLS model for the Figure 2 comparison.
//!
//! The paper compares its centralized in-memory index against the
//! peer-to-peer replica location service measured by Chervenak et al.
//! [35]: lookup latency grows from 0.5 ms at 1 node to ~3 ms at 15 nodes,
//! and they extrapolate with a logarithmic best fit. Aggregate throughput
//! is `nodes / latency(nodes)` (each node serves lookups at `1/latency`).
//!
//! The paper's conclusion — P-RLS needs >32K nodes to match the ~4.18M
//! lookups/s of one in-memory hash table — is exactly what
//! [`crossover_nodes`] computes, given our *measured* hash-table rate
//! (see `rust/benches/fig2_index.rs`).

/// Chervenak et al.'s measured (nodes, latency-seconds) datapoints,
/// as read off the paper's description: 0.5 ms at 1 node rising to
/// ~3 ms at 15 nodes.
pub const MEASURED: &[(u32, f64)] = &[
    (1, 0.00050),
    (2, 0.00091),
    (3, 0.00124),
    (4, 0.00147),
    (5, 0.00165),
    (6, 0.00180),
    (7, 0.00193),
    (8, 0.00204),
    (9, 0.00214),
    (10, 0.00223),
    (11, 0.00231),
    (12, 0.00238),
    (13, 0.00245),
    (14, 0.00251),
    (15, 0.00300),
];

/// Logarithmic model `latency(n) = a + b·ln(n)` fit to [`MEASURED`] by
/// least squares.
#[derive(Debug, Clone, Copy)]
pub struct PrlsModel {
    /// Intercept (latency at 1 node), seconds.
    pub a: f64,
    /// Log coefficient, seconds per ln(node).
    pub b: f64,
}

impl PrlsModel {
    /// Least-squares fit of `lat = a + b ln(n)` to the measured points.
    pub fn fit() -> PrlsModel {
        let n = MEASURED.len() as f64;
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
        for &(nodes, lat) in MEASURED {
            let x = (nodes as f64).ln();
            sx += x;
            sy += lat;
            sxx += x * x;
            sxy += x * lat;
        }
        let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        let a = (sy - b * sx) / n;
        PrlsModel { a, b }
    }

    /// Predicted lookup latency (seconds) at `nodes` nodes.
    pub fn latency(&self, nodes: u64) -> f64 {
        self.a + self.b * (nodes.max(1) as f64).ln()
    }

    /// Predicted aggregate throughput (lookups/s): every node resolves
    /// lookups at `1/latency`.
    pub fn aggregate_throughput(&self, nodes: u64) -> f64 {
        nodes as f64 / self.latency(nodes)
    }

    /// Smallest power-of-two node count whose aggregate P-RLS throughput
    /// exceeds `central_rate` (lookups/s), scanning up to 2^30.
    pub fn crossover_nodes(&self, central_rate: f64) -> Option<u64> {
        for exp in 0..=30 {
            let n = 1u64 << exp;
            if self.aggregate_throughput(n) >= central_rate {
                return Some(n);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_matches_endpoints() {
        let m = PrlsModel::fit();
        // Paper quotes 0.5 ms at 1 node and ~3 ms at 15 nodes.
        assert!((m.latency(1) - 0.0005).abs() < 3e-4, "a={}", m.a);
        assert!((m.latency(15) - 0.003).abs() < 5e-4);
        // And "from 0.5 ms with 1 node to 15 ms with 1M nodes".
        let lat_1m = m.latency(1_000_000);
        assert!((0.008..0.020).contains(&lat_1m), "lat(1M)={lat_1m}");
    }

    #[test]
    fn throughput_grows_with_nodes() {
        let m = PrlsModel::fit();
        assert!(m.aggregate_throughput(16) > m.aggregate_throughput(1));
        assert!(m.aggregate_throughput(1 << 20) > m.aggregate_throughput(1 << 10));
    }

    #[test]
    fn paper_crossover_reproduced() {
        // Paper: "P-RLS would need more than 32K nodes to achieve an
        // aggregate throughput similar to that of an in-memory hash
        // table, which is 4.18M lookups/sec".
        let m = PrlsModel::fit();
        let crossover = m.crossover_nodes(4.18e6).unwrap();
        assert!(
            crossover > 32_768 && crossover <= 131_072,
            "crossover={crossover}"
        );
    }
}
