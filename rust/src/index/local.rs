//! Per-executor local index (§3.2.1).
//!
//! "each executor maintains a local index to record the location of its
//! cached data objects" — in live mode this maps object ids to cache-file
//! paths; in sim mode it mirrors the cache's resident set. Kept separate
//! from [`crate::cache::DataCache`] because the cache owns *policy* while
//! the index owns *location* (path on local disk).

use std::collections::HashMap;
use std::path::PathBuf;

use crate::storage::object::ObjectId;

/// Local object → path index.
#[derive(Debug, Default)]
pub struct LocalIndex {
    paths: HashMap<ObjectId, PathBuf>,
}

impl LocalIndex {
    /// Empty index.
    pub fn new() -> Self {
        LocalIndex::default()
    }

    /// Record where an object lives on local disk.
    pub fn insert(&mut self, obj: ObjectId, path: PathBuf) {
        self.paths.insert(obj, path);
    }

    /// Forget an object (after eviction).
    pub fn remove(&mut self, obj: ObjectId) -> Option<PathBuf> {
        self.paths.remove(&obj)
    }

    /// Local path of a cached object.
    pub fn get(&self, obj: ObjectId) -> Option<&PathBuf> {
        self.paths.get(&obj)
    }

    /// Number of indexed objects.
    pub fn len(&self) -> usize {
        self.paths.len()
    }

    /// Whether the index is empty.
    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut idx = LocalIndex::new();
        idx.insert(ObjectId(1), PathBuf::from("/cache/obj1.fits"));
        assert_eq!(
            idx.get(ObjectId(1)),
            Some(&PathBuf::from("/cache/obj1.fits"))
        );
        assert_eq!(
            idx.remove(ObjectId(1)),
            Some(PathBuf::from("/cache/obj1.fits"))
        );
        assert!(idx.get(ObjectId(1)).is_none());
        assert!(idx.is_empty());
    }
}
