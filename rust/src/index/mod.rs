//! Cache-location indices (§3.2.1, §3.2.3).
//!
//! * [`central`] — the dispatcher's centralized in-memory index mapping
//!   every cached data object to the executors holding it. The paper
//!   argues (Fig 2) this beats a distributed index until ~32K nodes.
//! * [`local`] — the per-executor local index over its own cache.
//! * [`prls`] — the analytic P-RLS (peer-to-peer replica location
//!   service) model from Chervenak et al.'s measurements, used to
//!   regenerate Figure 2's comparison.
//! * [`dht`] — a Chord ring (consistent hashing + finger-table routing)
//!   with measured hop counts, the paper's other distributed-index
//!   candidate.

pub mod central;
pub mod dht;
pub mod local;
pub mod prls;

pub use central::CentralIndex;
pub use local::LocalIndex;
