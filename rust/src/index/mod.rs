//! Cache-location indices (§3.2.1, §3.2.3) behind one pluggable trait.
//!
//! The dispatcher needs to answer one question on every scheduling
//! decision: *which executors hold a cached copy of this object?* The
//! paper (§3.2.3 / Fig 2) argues a centralized in-memory index answers it
//! faster than any distributed design until ~32K nodes — but the seed
//! code could only make that argument with closed-form models, because
//! the live scheduling stack was hard-wired to [`CentralIndex`].
//!
//! This module now defines the [`DataIndex`] trait — the index *service
//! interface* the scheduler, coordinator, and drivers program against —
//! plus two interchangeable backends:
//!
//! * [`central`] — the dispatcher's centralized in-memory index
//!   ([`CentralIndex`]): one hash table, zero routing hops, per-lookup
//!   cost calibrated to the paper's 0.25–1 µs measurements.
//! * [`chord`] — a stateful distributed backend ([`ChordIndex`]): the
//!   object→locations map is partitioned over a Chord ring of the
//!   registered executors, every lookup is *routed* through real finger
//!   tables ([`dht::ChordRing`]), and [`DataIndex::lookup_cost`] charges
//!   the measured hop count at the fitted per-hop latency.
//!
//! Two analytic companions back the Figure 2 curves:
//!
//! * [`prls`] — the P-RLS (peer-to-peer replica location service) log-fit
//!   model from Chervenak et al.'s measurements.
//! * [`dht`] — the Chord routing structure itself (consistent hashing +
//!   finger tables) with measured hop counts, shared by [`chord`].
//!
//! [`local`] is the per-executor index over its own cache and is not part
//! of the pluggable surface (it models node-local state, not the
//! dispatcher's global view).
//!
//! ## Contract
//!
//! A backend must never change *placement*, only *cost*: for identical
//! insert/remove histories, [`DataIndex::locations`] must return the same
//! executors in the same (ascending) order on every backend, so the four
//! dispatch policies make byte-identical decisions regardless of which
//! index is configured (property-tested in `tests/proptest_invariants.rs`).
//! What differs is [`DataIndex::lookup_cost`]: the simulated latency and
//! routing hops a real deployment of that design would pay, which the
//! simulation driver charges into the event timeline and both drivers
//! account in [`crate::coordinator::metrics::Metrics`].
//!
//! Cost has a second axis since the metered-transfer-plane refactor:
//! **control traffic** ([`ControlTraffic`], drained through
//! [`DataIndex::take_control_traffic`]). Lookups meter the data plane;
//! membership churn *and index updates* meter the control plane — Chord
//! charges O(log²N) stabilization messages per join/leave, stale-finger
//! misroutes on the lookups issued before its finger tables repair, and
//! **batched update routing**: `insert`/`remove`/handoff records queue
//! under their ring owner and each owner's batch flushes as one routed
//! message train (O(log N) measured hops), so same-owner records within
//! a harvest window share a single message — while the centralized
//! index charges nothing (its "overlay" is one process). Both drivers harvest this into
//! `Metrics::stabilization_msgs` / `Metrics::index_update_msgs`, so a
//! churning elastic pool shows the distributed design's full
//! maintenance bill next to its routing bill.
//!
//! ### Multi-holder hint ranking
//!
//! With demand-driven replication ([`crate::replication`]) an object
//! routinely has several holders, so *ranking* matters, not just
//! membership. Backends still return locations sorted ascending —
//! ranking is deliberately **not** the index's job, because any
//! backend-specific order would leak into placement and break the
//! invariance contract above. Instead the scheduler layer ranks:
//! [`crate::scheduler::decision::SchedView::hints_for`] rotates each
//! holder list by the task id before shipping it, and score ties in
//! `best_holder` (replicas of a task's inputs) rotate the same way, so
//! consecutive tasks fan out across copies — deterministic, replayable,
//! and identical on every backend. Executors that find every hinted copy
//! gone (§3.2.2 stale hints) re-resolve against the index and are
//! charged one extra [`DataIndex::lookup_cost`], on both drivers.
//!
//! Adding a new backend (hierarchical, gossip, replicated, …) is a
//! one-file change: implement [`DataIndex`], extend [`IndexBackend`] and
//! [`build`].

pub mod central;
pub mod chord;
pub mod dht;
pub mod local;
pub mod prls;

pub use central::{CentralIndex, ExecutorId};
pub use chord::ChordIndex;
pub use local::LocalIndex;

use crate::storage::object::ObjectId;

/// Simulated cost of the index lookups behind one scheduling action.
///
/// Returned by [`DataIndex::lookup_cost`] and accumulated per dispatch
/// order; the sim driver charges `latency_s` into the event timeline and
/// both drivers fold the counters into the run metrics.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct LookupCost {
    /// Simulated wall time the lookup(s) take, seconds.
    pub latency_s: f64,
    /// Routing hops traversed (0 for the centralized index).
    pub hops: u32,
    /// Number of index lookups performed.
    pub lookups: u32,
}

impl LookupCost {
    /// The free lookup (data-unaware policies never consult the index).
    pub const ZERO: LookupCost = LookupCost {
        latency_s: 0.0,
        hops: 0,
        lookups: 0,
    };

    /// Fold another cost into this one.
    pub fn accumulate(&mut self, other: LookupCost) {
        self.latency_s += other.latency_s;
        self.hops += other.hops;
        self.lookups += other.lookups;
    }
}

/// Control-plane traffic an index backend accumulated since it was last
/// harvested: the overlay-maintenance cost of *membership and updates*,
/// as opposed to the per-lookup cost in [`LookupCost`].
///
/// The centralized backend has no control plane and always reports zero.
/// The Chord backend charges three things:
///
/// * O(log²N) **stabilization** messages per membership change (each
///   join/leave triggers successor/finger repair across the ring);
/// * **stale-finger misroutes** on the lookups issued between a
///   membership change and the next `fix_fingers` round (those also
///   surface as extra hops/latency in the affected [`LookupCost`]s —
///   `latency_s` here covers only the control messages, so harvesting
///   never double-charges);
/// * **update traffic**: every `insert`/`remove` queues a record update
///   under the object's owner node, and a membership change queues
///   every location record whose ring owner moved (under its *new*
///   owner) — at harvest each owner's pending batch flushes as one
///   message train *routed to that owner* (O(log N) hops, measured on
///   the real finger tables), so `update_msgs` counts messages, not
///   records, and same-owner records piggyback on a single train.
///
/// Drivers drain this via [`crate::coordinator::core::FalkonCore::take_index_control`]
/// and fold it into [`crate::coordinator::metrics::Metrics`].
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ControlTraffic {
    /// Stabilization messages exchanged for membership maintenance.
    pub stabilization_msgs: u64,
    /// Lookups that misrouted through a stale finger since the last
    /// harvest (their extra hop is charged in the lookup's own cost).
    pub misroutes: u64,
    /// Update messages: the routed per-owner trains carrying batched
    /// insert/evict record updates and partition-handoff records
    /// (messages, not records — same-owner records share a train).
    pub update_msgs: u64,
    /// Simulated wall time behind the stabilization and update
    /// messages, seconds.
    pub latency_s: f64,
}

impl ControlTraffic {
    /// Whether nothing was charged.
    pub fn is_zero(&self) -> bool {
        self.stabilization_msgs == 0 && self.misroutes == 0 && self.update_msgs == 0
    }
}

/// The pluggable cache-location index service.
///
/// Object-safe so the coordinator can own a `Box<dyn DataIndex>` chosen
/// at configuration time. `Send` because the live driver's coordinator
/// may run on a spawned thread.
///
/// Implementations must keep [`locations`](DataIndex::locations) sorted
/// ascending and deduplicated — schedulers rely on that for deterministic
/// tie-breaking — and must return identical contents for identical
/// update histories (see the module docs: backends change cost, never
/// placement).
pub trait DataIndex: Send {
    /// Record that `exec` now caches `obj`.
    fn insert(&mut self, obj: ObjectId, exec: ExecutorId);

    /// Record that `exec` evicted `obj`.
    fn remove(&mut self, obj: ObjectId, exec: ExecutorId);

    /// All executors currently holding `obj`, ascending (empty if none).
    fn locations(&self, obj: ObjectId) -> &[ExecutorId];

    /// Whether a specific executor holds `obj`.
    fn holds(&self, exec: ExecutorId, obj: ObjectId) -> bool;

    /// Objects cached on one executor, ascending.
    fn objects_of(&self, exec: ExecutorId) -> &[ObjectId];

    /// A newly provisioned executor joined the cluster. Distributed
    /// backends grow their overlay here; the centralized index ignores it.
    fn executor_joined(&mut self, _exec: ExecutorId) {}

    /// Remove an executor entirely (released by the provisioner); returns
    /// the objects whose only copy may have been lost.
    fn drop_executor(&mut self, exec: ExecutorId) -> Vec<ObjectId>;

    /// Number of distinct objects with at least one location.
    fn len(&self) -> usize;

    /// Whether the index holds no locations at all.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total (object, executor) location entries.
    fn entries(&self) -> usize;

    /// Lifetime (inserts, lookups) counters for the Fig 2 bench.
    fn op_counts(&self) -> (u64, u64);

    /// Simulated cost of resolving the locations of `obj` once, from the
    /// dispatcher's vantage point. Pure accounting: the data itself is
    /// returned by [`locations`](DataIndex::locations) without delay.
    fn lookup_cost(&self, obj: ObjectId) -> LookupCost;

    /// Drain the control-plane traffic accumulated since the last call
    /// (stabilization messages from membership changes, stale-finger
    /// misroutes). Backends without a control plane — the centralized
    /// index — keep the default zero-cost implementation.
    fn take_control_traffic(&mut self) -> ControlTraffic {
        ControlTraffic::default()
    }

    /// Human-readable backend name (figure labels, CLI output).
    fn backend(&self) -> &'static str;
}

/// Index backend selector (config / CLI `--index central|chord`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IndexBackend {
    /// Centralized in-memory hash table at the dispatcher (the paper's
    /// design and the default).
    #[default]
    Central,
    /// Chord DHT partitioned over the executors, with routed lookups.
    Chord,
}

impl IndexBackend {
    /// Parse from config/CLI text.
    pub fn parse(s: &str) -> Option<IndexBackend> {
        match s.to_ascii_lowercase().as_str() {
            "central" | "centralized" => Some(IndexBackend::Central),
            "chord" | "dht" => Some(IndexBackend::Chord),
            _ => None,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            IndexBackend::Central => "central",
            IndexBackend::Chord => "chord",
        }
    }
}

/// Build the configured index backend.
///
/// `seed` keys the Chord ring placement so runs stay deterministic.
pub fn build(cfg: &crate::config::IndexConfig, seed: u64) -> Box<dyn DataIndex> {
    match cfg.backend {
        IndexBackend::Central => Box::new(CentralIndex::with_cost(cfg.central_lookup_s)),
        IndexBackend::Chord => Box::new(ChordIndex::new(
            dht::DhtModel {
                hop_latency_s: cfg.hop_latency_s,
                proc_s: cfg.hop_proc_s,
            },
            seed,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_and_label() {
        assert_eq!(IndexBackend::parse("central"), Some(IndexBackend::Central));
        assert_eq!(IndexBackend::parse("Chord"), Some(IndexBackend::Chord));
        assert_eq!(IndexBackend::parse("dht"), Some(IndexBackend::Chord));
        assert_eq!(IndexBackend::parse("p2p"), None);
        assert_eq!(IndexBackend::Chord.label(), "chord");
    }

    #[test]
    fn lookup_cost_accumulates() {
        let mut c = LookupCost::ZERO;
        c.accumulate(LookupCost {
            latency_s: 0.5e-6,
            hops: 0,
            lookups: 1,
        });
        c.accumulate(LookupCost {
            latency_s: 4.4e-4,
            hops: 2,
            lookups: 1,
        });
        assert_eq!(c.hops, 2);
        assert_eq!(c.lookups, 2);
        assert!((c.latency_s - 4.405e-4).abs() < 1e-12);
    }

    #[test]
    fn build_selects_backend() {
        let cfg = crate::config::IndexConfig::default();
        let idx = build(&cfg, 1);
        assert_eq!(idx.backend(), "central");
        let chord_cfg = crate::config::IndexConfig {
            backend: IndexBackend::Chord,
            ..Default::default()
        };
        let idx = build(&chord_cfg, 1);
        assert_eq!(idx.backend(), "chord");
    }
}
