//! The centralized cache-location index.
//!
//! An in-memory hash table in the dispatcher recording, for every cached
//! object, which executors hold a copy (§3.2.3: ~200 B/entry in the
//! paper's Java implementation; 1–3 µs inserts, 0.25–1 µs lookups, upper
//! bound ~4M lookups/s). Executors report cache changes after each task
//! ("loosely coherent"); the scheduler reads it on every decision.
//!
//! Location sets are small sorted `Vec`s — an object rarely lives on more
//! than a few executors, and sorted order gives deterministic scheduling.
//!
//! The centralized design has **no control plane**: membership changes
//! mutate one in-process hash table, so it keeps the trait's default
//! zero [`super::ControlTraffic`] — the baseline the Chord backend's
//! stabilization/misroute charges are compared against.

use crate::util::fxhash::FxHashMap;

use super::{DataIndex, LookupCost};
use crate::storage::object::ObjectId;

/// Executor identifier (dense, assigned by the coordinator).
pub type ExecutorId = usize;

/// Central object → locations index plus the reverse map.
#[derive(Debug, Default)]
pub struct CentralIndex {
    locations: FxHashMap<ObjectId, Vec<ExecutorId>>,
    by_executor: FxHashMap<ExecutorId, Vec<ObjectId>>,
    inserts: u64,
    lookups: std::cell::Cell<u64>,
    /// Simulated per-lookup service time charged by [`DataIndex::lookup_cost`]
    /// (0 when the index is used as a raw data structure).
    lookup_s: f64,
}

impl CentralIndex {
    /// Empty index with free lookups (raw data-structure use).
    pub fn new() -> Self {
        CentralIndex::default()
    }

    /// Empty index charging `lookup_s` seconds of simulated service time
    /// per lookup (§3.2.3 measures 0.25–1 µs at 1M–8M entries).
    pub fn with_cost(lookup_s: f64) -> Self {
        CentralIndex {
            lookup_s,
            ..CentralIndex::default()
        }
    }

    /// Record that `exec` now caches `obj`.
    pub fn insert(&mut self, obj: ObjectId, exec: ExecutorId) {
        self.inserts += 1;
        let locs = self.locations.entry(obj).or_default();
        if let Err(pos) = locs.binary_search(&exec) {
            locs.insert(pos, exec);
        }
        let objs = self.by_executor.entry(exec).or_default();
        if let Err(pos) = objs.binary_search(&obj) {
            objs.insert(pos, obj);
        }
    }

    /// Record that `exec` evicted `obj`.
    pub fn remove(&mut self, obj: ObjectId, exec: ExecutorId) {
        if let Some(locs) = self.locations.get_mut(&obj) {
            if let Ok(pos) = locs.binary_search(&exec) {
                locs.remove(pos);
            }
            if locs.is_empty() {
                self.locations.remove(&obj);
            }
        }
        if let Some(objs) = self.by_executor.get_mut(&exec) {
            if let Ok(pos) = objs.binary_search(&obj) {
                objs.remove(pos);
            }
        }
    }

    /// All executors currently holding `obj` (empty slice if none).
    pub fn locations(&self, obj: ObjectId) -> &[ExecutorId] {
        self.lookups.set(self.lookups.get() + 1);
        self.locations.get(&obj).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Whether a specific executor holds `obj`.
    pub fn holds(&self, exec: ExecutorId, obj: ObjectId) -> bool {
        self.lookups.set(self.lookups.get() + 1);
        self.locations
            .get(&obj)
            .map(|locs| locs.binary_search(&exec).is_ok())
            .unwrap_or(false)
    }

    /// Objects cached on one executor.
    pub fn objects_of(&self, exec: ExecutorId) -> &[ObjectId] {
        self.by_executor
            .get(&exec)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Remove an executor entirely (released by the provisioner); returns
    /// the objects whose only copy may have been lost.
    pub fn drop_executor(&mut self, exec: ExecutorId) -> Vec<ObjectId> {
        let objs = self.by_executor.remove(&exec).unwrap_or_default();
        let mut orphaned = Vec::new();
        for obj in &objs {
            if let Some(locs) = self.locations.get_mut(obj) {
                if let Ok(pos) = locs.binary_search(&exec) {
                    locs.remove(pos);
                }
                if locs.is_empty() {
                    self.locations.remove(obj);
                    orphaned.push(*obj);
                }
            }
        }
        orphaned
    }

    /// Number of distinct objects with at least one location.
    pub fn len(&self) -> usize {
        self.locations.len()
    }

    /// Whether the index is empty.
    pub fn is_empty(&self) -> bool {
        self.locations.is_empty()
    }

    /// Total (object, executor) location entries.
    pub fn entries(&self) -> usize {
        self.locations.values().map(|v| v.len()).sum()
    }

    /// Lifetime (inserts, lookups) counters for the Fig 2 bench.
    pub fn op_counts(&self) -> (u64, u64) {
        (self.inserts, self.lookups.get())
    }

    /// Iterate `(object, replica count)` over every indexed object
    /// (order unspecified; the Chord backend sums over this to price the
    /// partition handoff a membership change implies). Not counted as
    /// lookups — this is introspection, not the service path.
    pub fn iter_counts(&self) -> impl Iterator<Item = (ObjectId, usize)> + '_ {
        self.locations.iter().map(|(o, v)| (*o, v.len()))
    }
}

impl DataIndex for CentralIndex {
    fn insert(&mut self, obj: ObjectId, exec: ExecutorId) {
        CentralIndex::insert(self, obj, exec);
    }

    fn remove(&mut self, obj: ObjectId, exec: ExecutorId) {
        CentralIndex::remove(self, obj, exec);
    }

    fn locations(&self, obj: ObjectId) -> &[ExecutorId] {
        CentralIndex::locations(self, obj)
    }

    fn holds(&self, exec: ExecutorId, obj: ObjectId) -> bool {
        CentralIndex::holds(self, exec, obj)
    }

    fn objects_of(&self, exec: ExecutorId) -> &[ObjectId] {
        CentralIndex::objects_of(self, exec)
    }

    fn drop_executor(&mut self, exec: ExecutorId) -> Vec<ObjectId> {
        CentralIndex::drop_executor(self, exec)
    }

    fn len(&self) -> usize {
        CentralIndex::len(self)
    }

    fn entries(&self) -> usize {
        CentralIndex::entries(self)
    }

    fn op_counts(&self) -> (u64, u64) {
        CentralIndex::op_counts(self)
    }

    fn lookup_cost(&self, _obj: ObjectId) -> LookupCost {
        LookupCost {
            latency_s: self.lookup_s,
            hops: 0,
            lookups: 1,
        }
    }

    fn backend(&self) -> &'static str {
        "central"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_lookup_remove() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 3);
        idx.insert(ObjectId(1), 5);
        idx.insert(ObjectId(1), 3); // duplicate: no-op
        assert_eq!(idx.locations(ObjectId(1)), &[3, 5]);
        assert!(idx.holds(5, ObjectId(1)));
        idx.remove(ObjectId(1), 3);
        assert_eq!(idx.locations(ObjectId(1)), &[5]);
        idx.remove(ObjectId(1), 5);
        assert!(idx.locations(ObjectId(1)).is_empty());
        assert_eq!(idx.len(), 0);
    }

    #[test]
    fn reverse_map_tracks() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(3), 1);
        assert_eq!(idx.objects_of(0), &[ObjectId(1), ObjectId(2)]);
        assert_eq!(idx.objects_of(1), &[ObjectId(3)]);
        idx.remove(ObjectId(1), 0);
        assert_eq!(idx.objects_of(0), &[ObjectId(2)]);
    }

    #[test]
    fn drop_executor_reports_orphans() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 0); // only on 0 -> orphaned
        idx.insert(ObjectId(2), 0);
        idx.insert(ObjectId(2), 1); // survives on 1
        let orphans = idx.drop_executor(0);
        assert_eq!(orphans, vec![ObjectId(1)]);
        assert_eq!(idx.locations(ObjectId(2)), &[1]);
        assert!(idx.objects_of(0).is_empty());
    }

    #[test]
    fn entries_counts_replicas() {
        let mut idx = CentralIndex::new();
        idx.insert(ObjectId(1), 0);
        idx.insert(ObjectId(1), 1);
        idx.insert(ObjectId(2), 0);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.entries(), 3);
    }
}
