//! Chord-style DHT model — the distributed-index alternative of §3.2.3.
//!
//! The paper: "a more distributed index might perform and scale better.
//! Such an index could be implemented using the peer-to-peer replica
//! location service (P-RLS) or distributed hash table (DHT) [Chord]."
//! [`super::prls`] models P-RLS analytically from Chervenak et al.'s
//! measurements; this module implements the **Chord routing structure**
//! itself (consistent hashing + finger tables) so hop counts are
//! *computed, not assumed*, and the latency model rests on them.
//!
//! The model is deliberately protocol-accurate where it matters to the
//! figure — ring placement, finger construction, greedy
//! closest-preceding-finger routing, O(log N) hops — and analytic where
//! it does not: churn is not simulated message-by-message, but its
//! *cost* is modeled ([`DhtModel::stabilization_msgs`] messages per
//! membership change, a [`DhtModel::stale_window`] of misroute-prone
//! lookups after each), which [`super::chord::ChordIndex`] charges into
//! the metered index control plane.

use crate::storage::object::ObjectId;

/// 64-bit ring positions via SplitMix64 of the key.
#[inline]
fn ring_hash(key: u64) -> u64 {
    let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Clockwise distance from `a` to `b` on the 2^64 ring.
#[inline]
fn ring_distance(a: u64, b: u64) -> u64 {
    b.wrapping_sub(a)
}

/// A Chord ring of `n` nodes with full finger tables.
pub struct ChordRing {
    /// Sorted node ring positions.
    ring: Vec<u64>,
    /// fingers[i][k] = ring index of the node succeeding
    /// `ring[i] + 2^k` (k in 0..64).
    fingers: Vec<Vec<u32>>,
}

impl ChordRing {
    /// Build a ring of `n` nodes (deterministic placement from `seed`).
    pub fn new(n: usize, seed: u64) -> ChordRing {
        assert!(n >= 1);
        let mut ring: Vec<u64> = (0..n as u64).map(|i| ring_hash(seed ^ i)).collect();
        ring.sort_unstable();
        ring.dedup();
        let m = ring.len();
        let mut fingers = Vec::with_capacity(m);
        for &pos in &ring {
            let mut f = Vec::with_capacity(64);
            for k in 0..64u32 {
                let target = pos.wrapping_add(1u64.wrapping_shl(k));
                f.push(Self::successor_of(&ring, target) as u32);
            }
            fingers.push(f);
        }
        ChordRing { ring, fingers }
    }

    /// Ring index of the first node at or clockwise-after `key`.
    fn successor_of(ring: &[u64], key: u64) -> usize {
        match ring.binary_search(&key) {
            Ok(i) => i,
            Err(i) => i % ring.len(),
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Whether the ring is empty (never: `new` requires n ≥ 1).
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The node responsible for an object.
    pub fn owner(&self, obj: ObjectId) -> usize {
        Self::successor_of(&self.ring, ring_hash(obj.0 ^ 0x0B1E_C7))
    }

    /// Ring *position* (node hash) of the object's owner — a stable node
    /// identity comparable across rebuilds, unlike the ring index.
    /// [`super::chord::ChordIndex`] diffs this before/after a membership
    /// change to price the per-owner partition handoff.
    pub fn owner_pos(&self, obj: ObjectId) -> u64 {
        self.ring[self.owner(obj)]
    }

    /// Route a lookup for `obj` starting at node `start` using greedy
    /// closest-preceding-finger forwarding. Returns (owner, hops).
    pub fn route(&self, start: usize, obj: ObjectId) -> (usize, u32) {
        let key = ring_hash(obj.0 ^ 0x0B1E_C7);
        let owner = Self::successor_of(&self.ring, key);
        let mut cur = start;
        let mut hops = 0u32;
        while cur != owner {
            // Forward to the finger that gets closest to (but not past)
            // the key — Chord's closest-preceding-finger rule. Fingers are
            // scanned high-to-low; the largest jump that does not
            // overshoot wins.
            let cur_pos = self.ring[cur];
            let goal = ring_distance(cur_pos, key);
            let mut next = None;
            for k in (0..64).rev() {
                let cand = self.fingers[cur][k] as usize;
                if cand == cur {
                    continue;
                }
                let d = ring_distance(cur_pos, self.ring[cand]);
                // 1..=goal: moves forward without passing the key's
                // successor region.
                if d >= 1 && d <= goal {
                    next = Some(cand);
                    break;
                }
            }
            // No finger strictly progresses: the owner is our successor.
            cur = next.unwrap_or(owner);
            hops += 1;
            debug_assert!(hops as usize <= 2 * 64, "routing diverged");
        }
        (owner, hops)
    }

    /// Mean lookup hop count over a key sample, from a rotating start
    /// node (the classic Chord metric; expected ≈ ½·log2 N). Sequential
    /// rotation samples every start node evenly at any ring size (a
    /// fixed stride would alias whenever it divides the ring size).
    pub fn mean_hops(&self, samples: u64) -> f64 {
        let mut total = 0u64;
        for i in 0..samples {
            let (_, hops) = self.route(
                i as usize % self.len(),
                ObjectId(i.wrapping_mul(0x9E37_79B9)),
            );
            total += hops as u64;
        }
        total as f64 / samples as f64
    }
}

/// Latency/throughput model on top of the measured hop counts.
#[derive(Debug, Clone, Copy)]
pub struct DhtModel {
    /// One-way per-hop network latency, seconds (LAN: ~0.1–0.5 ms).
    pub hop_latency_s: f64,
    /// Local processing per hop (hash + finger lookup), seconds.
    pub proc_s: f64,
}

impl Default for DhtModel {
    fn default() -> Self {
        // GigE LAN RTT ~0.2 ms one-way + light per-hop processing: in the
        // same regime as the paper's 1–2 ms dispatcher-executor latency.
        DhtModel {
            hop_latency_s: 0.0002,
            proc_s: 0.00002,
        }
    }
}

impl DhtModel {
    /// Stabilization messages charged per membership change on an
    /// overlay of `n` nodes: Chord repairs successors and finger tables
    /// with O(log²N) messages per join or leave (each of the O(log N)
    /// fingers is re-resolved by an O(log N)-hop lookup).
    pub fn stabilization_msgs(n: usize) -> u64 {
        let l = (n.max(2) as f64).log2().ceil() as u64;
        l * l
    }

    /// Number of lookups after a membership change that risk one
    /// stale-finger misroute before the periodic `fix_fingers` round
    /// repairs the tables: one per finger level, O(log N).
    pub fn stale_window(n: usize) -> u32 {
        (n.max(2) as f64).log2().ceil() as u32
    }

    /// Expected lookup latency on a ring of `n` nodes (measured hops).
    pub fn lookup_latency_s(&self, ring: &ChordRing) -> f64 {
        let hops = ring.mean_hops(2_000);
        hops * (self.hop_latency_s + self.proc_s)
    }

    /// Aggregate throughput: every node issues/serves lookups
    /// concurrently; each lookup occupies `hops` node-steps, so the
    /// system completes `n / hops` lookups per unit of per-hop time.
    pub fn aggregate_lookups_per_s(&self, ring: &ChordRing) -> f64 {
        let hops = ring.mean_hops(2_000).max(0.01);
        ring.len() as f64 / (hops * (self.hop_latency_s + self.proc_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stabilization_model_grows_logarithmically() {
        // O(log²N): 16 nodes → 16 msgs, 1024 nodes → 100 msgs.
        assert_eq!(DhtModel::stabilization_msgs(1), 1);
        assert_eq!(DhtModel::stabilization_msgs(2), 1);
        assert_eq!(DhtModel::stabilization_msgs(16), 16);
        assert_eq!(DhtModel::stabilization_msgs(1024), 100);
        assert!(DhtModel::stabilization_msgs(1024) < DhtModel::stabilization_msgs(16) * 64);
        assert_eq!(DhtModel::stale_window(2), 1);
        assert_eq!(DhtModel::stale_window(64), 6);
    }

    #[test]
    fn routing_reaches_owner() {
        let ring = ChordRing::new(64, 42);
        for i in 0..500u64 {
            let obj = ObjectId(i);
            let (owner, hops) = ring.route((i % 64) as usize, obj);
            assert_eq!(owner, ring.owner(obj));
            assert!(hops <= 16, "hops={hops} too many for 64 nodes");
        }
    }

    #[test]
    fn hops_scale_logarithmically() {
        let small = ChordRing::new(16, 7).mean_hops(2_000);
        let large = ChordRing::new(1024, 7).mean_hops(2_000);
        // ~½ log2: 2 vs 5. Allow slack but require clear log-like growth.
        assert!(small < large, "hops must grow with ring size");
        assert!(
            large < small * 4.0,
            "growth must be sub-linear: {small} -> {large} (64x nodes)"
        );
        assert!((1.0..4.0).contains(&small), "16-node hops={small}");
        assert!((3.0..8.0).contains(&large), "1024-node hops={large}");
    }

    #[test]
    fn owner_is_deterministic_and_balanced() {
        let ring = ChordRing::new(32, 1);
        let mut counts = vec![0u32; ring.len()];
        for i in 0..3200u64 {
            counts[ring.owner(ObjectId(i))] += 1;
        }
        let max = *counts.iter().max().unwrap();
        // Consistent hashing without virtual nodes is skewed, but no node
        // should own more than ~20% of the space at 32 nodes.
        assert!(max < 640, "load too skewed: max={max}/3200");
        assert_eq!(ring.owner(ObjectId(5)), ring.owner(ObjectId(5)));
    }

    #[test]
    fn single_node_ring_is_zero_hops() {
        let ring = ChordRing::new(1, 9);
        let (owner, hops) = ring.route(0, ObjectId(123));
        assert_eq!((owner, hops), (0, 0));
    }

    #[test]
    fn throughput_grows_with_nodes_but_latency_too() {
        let model = DhtModel::default();
        let small = ChordRing::new(16, 3);
        let large = ChordRing::new(4096, 3);
        assert!(model.lookup_latency_s(&large) > model.lookup_latency_s(&small));
        assert!(
            model.aggregate_lookups_per_s(&large) > model.aggregate_lookups_per_s(&small) * 10.0
        );
    }
}
