//! Stateful Chord-backed distributed index (§3.2.3's "other candidate").
//!
//! The object→locations map is partitioned by consistent hashing over a
//! [`ChordRing`] whose nodes are the registered executors: the ring
//! successor of `hash(obj)` *owns* that object's location records, as in
//! Chord-based replica location services. Every lookup is **routed** —
//! the query enters the overlay at a rotating executor (sampling the hop
//! distribution the way `ChordRing::mean_hops` does) and follows
//! closest-preceding-finger forwarding to the owner, so the hop count in
//! [`DataIndex::lookup_cost`] is measured on real finger tables, not
//! assumed from the ½·log₂N law.
//!
//! Content-wise the backend is lossless: the full location map is kept in
//! a [`CentralIndex`] (the union of what every owner node would store),
//! which guarantees the scheduler sees byte-identical placement
//! information on either backend — the trait contract. What changes is
//! *cost*: each resolved object charges `hops × (hop_latency + proc)`
//! seconds, the same per-hop model the analytic Figure 2 curves use, so
//! measured scheduled runs and closed-form curves are directly
//! comparable.
//!
//! Membership churn is *real* — the elastic drivers register and
//! deregister executors mid-run under the dynamic provisioner — and
//! since the metered-transfer-plane refactor it is no longer free:
//! every membership change charges [`DhtModel::stabilization_msgs`]
//! (O(log²N)) control messages, and the next
//! [`DhtModel::stale_window`] (O(log N)) routed lookups each pay one
//! **stale-finger misroute** — an extra hop into [`LookupCost`], because
//! until `fix_fingers` repairs the tables a finger can point at a node
//! that no longer owns the range. The rebuild itself is still instant
//! (contents never lag — the trait's placement contract), only the
//! *cost* of convergence is charged; drivers drain it through
//! [`DataIndex::take_control_traffic`] into the run metrics.
//!
//! **Updates are metered too** (the last free operation fell with the
//! weighted-shares refactor), and since the sharded-dispatch refactor
//! they are **batched per owner**: every `insert`/`remove`/handoff
//! *record* destined for the same ring owner piggybacks onto one
//! control message per owner per flush — a real deployment coalesces
//! same-destination updates rather than routing each record separately,
//! and sharded dispatch would otherwise multiply per-record traffic.
//! Records accumulate in a per-owner pending set
//! ([`ChordIndex::update_batching`] exposes the records/trains ratio)
//! and drain on a **size/age threshold**
//! ([`ChordIndex::set_flush_policy`]): queueing the record that fills
//! the bounded buffer flushes inline, and a batch that has been seen by
//! `flush_age` control-traffic harvests flushes then — the default age
//! of 1 drains every harvest, while a larger age deliberately delays
//! billing to grow bigger trains. A flush routes one message train per
//! pending owner — O(log N) measured hops on the real finger tables,
//! charged as control messages — so `update_msgs` keeps its *messages,
//! not records* semantics. A membership change queues every
//! location record whose owner moved (grouped under its **new** owner),
//! and a deregistration's purge queues one eviction record per object
//! the departing executor held. The centralized index pays none of
//! this: updates mutate one in-process hash table.

use std::cell::Cell;
use std::collections::BTreeMap;

use super::central::{CentralIndex, ExecutorId};
use super::dht::{ChordRing, DhtModel};
use super::{ControlTraffic, DataIndex, LookupCost};
use crate::storage::object::ObjectId;

/// Distributed cache-location index over a Chord overlay of executors.
pub struct ChordIndex {
    /// Ground-truth location map (union of all per-owner partitions).
    store: CentralIndex,
    /// Per-hop cost model.
    model: DhtModel,
    /// Ring placement seed (deterministic runs).
    seed: u64,
    /// Number of executors currently in the overlay.
    members: usize,
    /// The routing overlay; rebuilt on membership change. Always at least
    /// one node so routing is defined even before registration.
    ring: ChordRing,
    /// Monotone query counter — rotates the overlay entry point.
    queries: Cell<u64>,
    /// Total hops across all routed lookups (metrics/bench readout).
    routed_hops: Cell<u64>,
    /// Total routed lookups.
    routed_lookups: Cell<u64>,
    /// Stabilization messages charged since the last harvest.
    pending_stab_msgs: u64,
    /// Routed update / partition-handoff messages charged since the
    /// last harvest.
    pending_update_msgs: u64,
    /// Update records queued per owner ring position, awaiting the next
    /// flush: owner position → (record count, representative object).
    /// A `BTreeMap` so flush order is deterministic regardless of the
    /// order records were queued in; the representative is the smallest
    /// queued object id for the same reason (store iteration order is
    /// not deterministic).
    pending_updates: BTreeMap<u64, (u64, ObjectId)>,
    /// Monotone update counter — rotates the overlay entry point for
    /// routed update trains (separate from `queries` so update routing
    /// never perturbs the lookup-side hop statistics).
    update_queries: u64,
    /// Lifetime count of record updates queued (inserts, evictions,
    /// handoff records).
    batched_records: u64,
    /// Lifetime count of per-owner message trains flushed.
    batched_trains: u64,
    /// Records currently queued across all pending owner batches.
    pending_record_total: u64,
    /// Harvests the oldest unflushed batch has survived.
    pending_age: u32,
    /// Size threshold: queueing the record that reaches this total
    /// force-flushes inline (a real buffer is bounded).
    flush_records: u64,
    /// Age threshold, in control-traffic harvests: a pending batch
    /// flushes once it has been seen by this many harvests. 1 (the
    /// default) flushes at the first harvest after queueing — the
    /// pre-threshold behavior.
    flush_age: u32,
    /// Stale-finger misroutes charged since the last harvest.
    pending_misroutes: Cell<u64>,
    /// Lookups left in the current post-rebuild stale window: each pays
    /// one misroute hop until `fix_fingers` would have repaired the
    /// tables.
    stale_lookups: Cell<u32>,
}

impl ChordIndex {
    /// Empty index with the given per-hop cost model and ring seed.
    pub fn new(model: DhtModel, seed: u64) -> ChordIndex {
        ChordIndex {
            store: CentralIndex::new(),
            model,
            seed,
            members: 0,
            ring: ChordRing::new(1, seed),
            queries: Cell::new(0),
            routed_hops: Cell::new(0),
            routed_lookups: Cell::new(0),
            pending_stab_msgs: 0,
            pending_update_msgs: 0,
            pending_updates: BTreeMap::new(),
            update_queries: 0,
            batched_records: 0,
            batched_trains: 0,
            pending_record_total: 0,
            pending_age: 0,
            flush_records: 1024,
            flush_age: 1,
            pending_misroutes: Cell::new(0),
            stale_lookups: Cell::new(0),
        }
    }

    /// Convenience: an index whose overlay already has `nodes` executors
    /// (one ring build, not `nodes` incremental rebuilds).
    pub fn with_nodes(nodes: usize, model: DhtModel, seed: u64) -> ChordIndex {
        let mut idx = ChordIndex::new(model, seed);
        idx.members = nodes;
        idx.rebuild_ring();
        idx
    }

    /// Executors currently in the overlay.
    pub fn overlay_size(&self) -> usize {
        self.members
    }

    /// (routed lookups, total hops) since construction.
    pub fn routing_counts(&self) -> (u64, u64) {
        (self.routed_lookups.get(), self.routed_hops.get())
    }

    /// Mean hops per routed lookup so far (NaN before the first lookup).
    pub fn mean_hops(&self) -> f64 {
        self.routed_hops.get() as f64 / self.routed_lookups.get() as f64
    }

    /// Lifetime (record updates queued, per-owner message trains
    /// flushed). The ratio `records / trains` is the control traffic the
    /// per-owner piggybacking saves over routing each record separately.
    pub fn update_batching(&self) -> (u64, u64) {
        (self.batched_records, self.batched_trains)
    }

    /// Tune the batch flush policy: a pending batch drains when it holds
    /// `max_records` records (inline, at queue time) or once `max_age`
    /// control-traffic harvests have seen it — whichever trips first.
    /// Defaults (1024 records, age 1) flush every harvest like the
    /// pre-threshold code; a larger age trades billing latency for
    /// bigger trains.
    pub fn set_flush_policy(&mut self, max_records: u64, max_age: u32) {
        self.flush_records = max_records.max(1);
        self.flush_age = max_age.max(1);
    }

    /// Records queued and not yet billed to a message train.
    pub fn pending_update_records(&self) -> u64 {
        self.pending_record_total
    }

    /// Rebuild the overlay for the current membership, charging the
    /// stabilization traffic the change costs a real deployment, queueing
    /// the partition handoff for every record whose ring owner moved, and
    /// opening the stale-finger window the next lookups pay through.
    fn rebuild_ring(&mut self) {
        let old = std::mem::replace(&mut self.ring, ChordRing::new(self.members.max(1), self.seed));
        self.pending_stab_msgs += DhtModel::stabilization_msgs(self.members.max(1));
        // Per-owner partition handoff: ownership is a function of the
        // ring, so a membership change relocates every record whose
        // owner position moved. Moved records queue under their *new*
        // owner and piggyback on that owner's next update train.
        let moved: Vec<(ObjectId, usize)> = self
            .store
            .iter_counts()
            .filter(|&(obj, _)| old.owner_pos(obj) != self.ring.owner_pos(obj))
            .collect();
        for (obj, replicas) in moved {
            for _ in 0..replicas {
                self.queue_update(obj);
            }
        }
        self.stale_lookups.set(if self.members > 1 {
            DhtModel::stale_window(self.members)
        } else {
            0
        });
    }

    /// Route one query for `obj` from the rotating entry node; returns
    /// the measured hop count.
    fn route_query(&self, obj: ObjectId) -> u32 {
        let q = self.queries.get();
        self.queries.set(q + 1);
        // Sequential rotation: stride 1 is co-prime with every ring size,
        // so entry points are sampled evenly (a fixed stride like 31
        // would collapse onto one node whenever 31 | ring size).
        let entry = (q as usize) % self.ring.len();
        let (_, hops) = self.ring.route(entry, obj);
        self.routed_lookups.set(self.routed_lookups.get() + 1);
        self.routed_hops.set(self.routed_hops.get() + hops as u64);
        hops
    }

    /// Queue one record update for `obj` under its current ring owner.
    /// Same-owner records batch into one routed message train at the
    /// next control-traffic flush; the store mutation itself is always
    /// immediate (placement never lags — the trait contract).
    fn queue_update(&mut self, obj: ObjectId) {
        self.batched_records += 1;
        self.pending_record_total += 1;
        let owner = self.ring.owner_pos(obj);
        let slot = self.pending_updates.entry(owner).or_insert((0, obj));
        slot.0 += 1;
        // Deterministic representative for the train's route whatever
        // order records were queued in.
        if obj < slot.1 {
            slot.1 = obj;
        }
        // Size threshold: a bounded buffer flushes when full, however
        // young the batch is.
        if self.pending_record_total >= self.flush_records {
            self.flush_updates();
        }
    }

    /// Flush the pending per-owner batches: one routed message *train*
    /// per owner, entered at the rotating update entry point and charged
    /// its measured hops as control messages — however many records
    /// piggybacked on it. Separate rotation counter from lookups so
    /// update routing never perturbs `mean_hops`.
    fn flush_updates(&mut self) {
        self.pending_record_total = 0;
        self.pending_age = 0;
        if self.pending_updates.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut self.pending_updates);
        for (_, (_, rep)) in pending {
            let entry = (self.update_queries as usize) % self.ring.len();
            self.update_queries += 1;
            let (_, hops) = self.ring.route(entry, rep);
            self.pending_update_msgs += hops as u64;
            self.batched_trains += 1;
        }
    }
}

impl DataIndex for ChordIndex {
    fn insert(&mut self, obj: ObjectId, exec: ExecutorId) {
        // The record update must reach the object's ring owner: it
        // queues under that owner and shares the owner's next routed
        // message train, billed to the control plane at flush (placement
        // stays backend-invariant — only the charged cost differs).
        self.queue_update(obj);
        self.store.insert(obj, exec);
    }

    fn remove(&mut self, obj: ObjectId, exec: ExecutorId) {
        self.queue_update(obj);
        self.store.remove(obj, exec);
    }

    fn locations(&self, obj: ObjectId) -> &[ExecutorId] {
        self.store.locations(obj)
    }

    fn holds(&self, exec: ExecutorId, obj: ObjectId) -> bool {
        self.store.holds(exec, obj)
    }

    fn objects_of(&self, exec: ExecutorId) -> &[ObjectId] {
        self.store.objects_of(exec)
    }

    fn executor_joined(&mut self, _exec: ExecutorId) {
        self.members += 1;
        self.rebuild_ring();
    }

    fn drop_executor(&mut self, exec: ExecutorId) -> Vec<ObjectId> {
        if self.members > 0 {
            self.members -= 1;
            self.rebuild_ring();
        }
        // The purge is a batch of eviction updates: one record removal
        // per object the departing executor held, queued under the
        // record's owner like any other update.
        let held: Vec<ObjectId> = self.store.objects_of(exec).to_vec();
        for obj in held {
            self.queue_update(obj);
        }
        self.store.drop_executor(exec)
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn entries(&self) -> usize {
        self.store.entries()
    }

    fn op_counts(&self) -> (u64, u64) {
        self.store.op_counts()
    }

    fn lookup_cost(&self, obj: ObjectId) -> LookupCost {
        let mut hops = self.route_query(obj);
        // Stale-finger window: lookups issued between a membership change
        // and the next fix_fingers round risk forwarding through a finger
        // that no longer owns its range — one extra (misrouted) hop,
        // charged into this lookup's own cost.
        let stale = self.stale_lookups.get();
        if stale > 0 && self.members > 1 {
            self.stale_lookups.set(stale - 1);
            self.pending_misroutes.set(self.pending_misroutes.get() + 1);
            hops += 1;
        }
        LookupCost {
            latency_s: hops as f64 * (self.model.hop_latency_s + self.model.proc_s),
            hops,
            lookups: 1,
        }
    }

    fn take_control_traffic(&mut self) -> ControlTraffic {
        // Age threshold: a pending batch rides out `flush_age - 1`
        // harvests unbilled (batching delay), then drains.
        if !self.pending_updates.is_empty() {
            self.pending_age += 1;
            if self.pending_age >= self.flush_age {
                self.flush_updates();
            }
        }
        let msgs = std::mem::take(&mut self.pending_stab_msgs);
        let updates = std::mem::take(&mut self.pending_update_msgs);
        let misroutes = self.pending_misroutes.take();
        ControlTraffic {
            stabilization_msgs: msgs,
            misroutes,
            update_msgs: updates,
            // One control message costs one overlay hop; misroute latency
            // already landed in the affected lookups' own costs.
            latency_s: (msgs + updates) as f64 * (self.model.hop_latency_s + self.model.proc_s),
        }
    }

    fn backend(&self) -> &'static str {
        "chord"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chord(nodes: usize) -> ChordIndex {
        ChordIndex::with_nodes(nodes, DhtModel::default(), 42)
    }

    #[test]
    fn content_matches_central_semantics() {
        let mut idx = chord(8);
        idx.insert(ObjectId(1), 3);
        idx.insert(ObjectId(1), 5);
        idx.insert(ObjectId(1), 3); // duplicate: no-op
        assert_eq!(idx.locations(ObjectId(1)), &[3, 5]);
        assert!(idx.holds(5, ObjectId(1)));
        assert!(!idx.holds(4, ObjectId(1)));
        idx.remove(ObjectId(1), 3);
        assert_eq!(idx.locations(ObjectId(1)), &[5]);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.entries(), 1);
    }

    #[test]
    fn lookup_cost_charges_measured_hops() {
        let idx = chord(64);
        let mut total = LookupCost::ZERO;
        for i in 0..200u64 {
            total.accumulate(idx.lookup_cost(ObjectId(i)));
        }
        assert_eq!(total.lookups, 200);
        assert!(total.hops > 0, "64-node overlay must route");
        let per_hop = DhtModel::default().hop_latency_s + DhtModel::default().proc_s;
        let expect = total.hops as f64 * per_hop;
        assert!((total.latency_s - expect).abs() < 1e-12);
        // Classic Chord: mean hops ≈ ½ log2(N) = 3 at N=64; allow slack.
        let mean = idx.mean_hops();
        assert!((1.0..6.0).contains(&mean), "mean hops {mean}");
    }

    #[test]
    fn cost_grows_logarithmically_with_overlay() {
        let small = chord(16);
        let large = chord(4096);
        let mean_of = |idx: &ChordIndex| {
            for i in 0..500u64 {
                idx.lookup_cost(ObjectId(i.wrapping_mul(0x9E37_79B9)));
            }
            idx.mean_hops()
        };
        let s = mean_of(&small);
        let l = mean_of(&large);
        assert!(s < l, "hops must grow with overlay size");
        assert!(l < s * 4.0, "growth must be sub-linear: {s} -> {l}");
    }

    #[test]
    fn single_node_overlay_is_free() {
        let idx = chord(1);
        let c = idx.lookup_cost(ObjectId(9));
        assert_eq!(c.hops, 0);
        assert_eq!(c.latency_s, 0.0);
        assert_eq!(c.lookups, 1);
    }

    #[test]
    fn membership_tracks_join_and_drop() {
        let mut idx = ChordIndex::new(DhtModel::default(), 7);
        assert_eq!(idx.overlay_size(), 0);
        for e in 0..5 {
            idx.executor_joined(e);
        }
        assert_eq!(idx.overlay_size(), 5);
        idx.insert(ObjectId(1), 2);
        let orphans = idx.drop_executor(2);
        assert_eq!(orphans, vec![ObjectId(1)]);
        assert_eq!(idx.overlay_size(), 4);
    }

    #[test]
    fn membership_changes_charge_stabilization_and_misroutes() {
        let mut idx = ChordIndex::new(DhtModel::default(), 7);
        // Bootstrap joins: members 1, 2, 3, 4 → 1 + 1 + 4 + 4 messages.
        for e in 0..4 {
            idx.executor_joined(e);
        }
        let per_hop = DhtModel::default().hop_latency_s + DhtModel::default().proc_s;
        let ct = idx.take_control_traffic();
        assert_eq!(ct.stabilization_msgs, 10);
        assert!((ct.latency_s - 10.0 * per_hop).abs() < 1e-12);
        assert_eq!(ct.misroutes, 0, "no lookups yet");
        // Harvest drains: a second take is zero.
        assert!(idx.take_control_traffic().is_zero());
        // The stale window after the last rebuild (4 members → 2 lookups)
        // surcharges exactly that many lookups with one misroute hop.
        let mut surcharged = 0u32;
        for i in 0..6u64 {
            let base = {
                let q = idx.queries.get();
                let entry = (q as usize) % idx.ring.len();
                idx.ring.route(entry, ObjectId(100 + i)).1
            };
            let c = idx.lookup_cost(ObjectId(100 + i));
            if c.hops == base + 1 {
                surcharged += 1;
            } else {
                assert_eq!(c.hops, base, "lookup {i}: unexpected hop count");
            }
            assert!((c.latency_s - c.hops as f64 * per_hop).abs() < 1e-12);
        }
        assert_eq!(surcharged, 2, "stale window is O(log N) lookups");
        let ct = idx.take_control_traffic();
        assert_eq!(ct.misroutes, 2);
        assert_eq!(ct.stabilization_msgs, 0);
        // A drop re-opens the window and charges again.
        let _ = DataIndex::drop_executor(&mut idx, 1);
        let ct = idx.take_control_traffic();
        assert_eq!(ct.stabilization_msgs, DhtModel::stabilization_msgs(3));
    }

    #[test]
    fn updates_charge_routed_messages_central_stays_free() {
        let mut idx = chord(64);
        let _ = idx.take_control_traffic(); // drain the bootstrap bill
        for i in 0..50u64 {
            DataIndex::insert(&mut idx, ObjectId(i), (i % 8) as usize);
        }
        let per_hop = DhtModel::default().hop_latency_s + DhtModel::default().proc_s;
        let ct = idx.take_control_traffic();
        assert!(ct.update_msgs > 0, "64-node overlay must route updates");
        assert_eq!(ct.stabilization_msgs, 0, "no membership change");
        assert!((ct.latency_s - ct.update_msgs as f64 * per_hop).abs() < 1e-12);
        // Evictions are updates too.
        for i in 0..8u64 {
            DataIndex::remove(&mut idx, ObjectId(i), (i % 8) as usize);
        }
        assert!(idx.take_control_traffic().update_msgs > 0);
        // Lookup-side hop statistics are unperturbed by update routing.
        assert_eq!(idx.routing_counts(), (0, 0));
        // The centralized index pays nothing for the same history.
        let mut central = CentralIndex::new();
        for i in 0..50u64 {
            DataIndex::insert(&mut central, ObjectId(i), (i % 8) as usize);
        }
        DataIndex::remove(&mut central, ObjectId(0), 0);
        assert!(DataIndex::take_control_traffic(&mut central).is_zero());
    }

    #[test]
    fn membership_change_batches_partition_handoff_per_new_owner() {
        let mut idx = chord(8);
        // Two copies of every object: a moved object queues 2 records.
        for i in 0..128u64 {
            DataIndex::insert(&mut idx, ObjectId(i), (i % 4) as usize);
            DataIndex::insert(&mut idx, ObjectId(i), 4 + (i % 4) as usize);
        }
        let _ = idx.take_control_traffic(); // drain bootstrap + inserts
        let (r0, t0) = idx.update_batching();
        // Predict the handoff when the ring shrinks 8→7: moved records
        // group under their *new* owner, one routed train per owner,
        // each train keyed by the group's smallest object id.
        let old = ChordRing::new(8, 42);
        let new = ChordRing::new(7, 42);
        let mut groups: BTreeMap<u64, ObjectId> = BTreeMap::new();
        let mut moved_records = 0u64;
        for i in 0..128u64 {
            let obj = ObjectId(i);
            if old.owner_pos(obj) != new.owner_pos(obj) {
                moved_records += 2;
                groups.entry(new.owner_pos(obj)).or_insert(obj);
            }
        }
        assert!(moved_records > 0, "an 8→7 shrink must move some ownership");
        // Replicate the flush: sorted owner order, rotating entry point.
        let mut uq = idx.update_queries;
        let mut expect_msgs = 0u64;
        for rep in groups.values() {
            let entry = (uq as usize) % new.len();
            uq += 1;
            expect_msgs += new.route(entry, *rep).1 as u64;
        }
        // Drop an executor holding nothing, so the purge queues no
        // evictions and the handoff is isolated.
        let orphans = DataIndex::drop_executor(&mut idx, 17);
        assert!(orphans.is_empty());
        let ct = idx.take_control_traffic();
        assert_eq!(ct.stabilization_msgs, DhtModel::stabilization_msgs(7));
        let (r1, t1) = idx.update_batching();
        assert_eq!(r1 - r0, moved_records, "every moved record queues once");
        assert_eq!(
            t1 - t0,
            groups.len() as u64,
            "one message train per receiving owner, not per record"
        );
        assert_eq!(
            ct.update_msgs, expect_msgs,
            "each train charges its own routed hops"
        );
    }

    #[test]
    fn same_owner_updates_batch_into_one_message() {
        let mut idx = chord(64);
        let _ = idx.take_control_traffic(); // drain the bootstrap bill
        // Pick the owner arc holding the most of the first 10k object
        // ids — by pigeonhole it owns at least ⌈10000/64⌉ of them,
        // plenty for a 20-record batch.
        let mut by_owner: BTreeMap<u64, Vec<ObjectId>> = BTreeMap::new();
        for i in 0..10_000u64 {
            by_owner
                .entry(idx.ring.owner_pos(ObjectId(i)))
                .or_default()
                .push(ObjectId(i));
        }
        let group = by_owner.into_values().max_by_key(|g| g.len()).unwrap();
        let (r0, t0) = idx.update_batching();
        for (i, &obj) in group.iter().take(20).enumerate() {
            DataIndex::insert(&mut idx, obj, i % 8);
        }
        // Predict the single train: entered at the rotating update entry
        // point, routed toward the group's smallest object id.
        let entry = (idx.update_queries as usize) % idx.ring.len();
        let (_, hops) = idx.ring.route(entry, group[0]);
        let ct = idx.take_control_traffic();
        let (r1, t1) = idx.update_batching();
        assert_eq!(r1 - r0, 20, "twenty records queued");
        assert_eq!(t1 - t0, 1, "same-owner records share one train");
        assert_eq!(ct.update_msgs, hops as u64, "the train bills its hops once");
        assert_eq!(ct.stabilization_msgs, 0);
        // Nothing left pending: the next harvest is free.
        assert!(idx.take_control_traffic().is_zero());
    }

    #[test]
    fn flush_policy_delays_billing_until_a_threshold_trips() {
        let mut idx = chord(16);
        let _ = idx.take_control_traffic(); // drain the bootstrap bill

        // Age threshold 3: a small batch rides out two harvests
        // unbilled — the pinned batching delay — and drains on the third.
        idx.set_flush_policy(1000, 3);
        DataIndex::insert(&mut idx, ObjectId(1), 0);
        DataIndex::insert(&mut idx, ObjectId(2), 1);
        assert_eq!(idx.pending_update_records(), 2);
        let (_, t0) = idx.update_batching();
        assert_eq!(idx.take_control_traffic().update_msgs, 0, "age 1 of 3");
        assert_eq!(idx.take_control_traffic().update_msgs, 0, "age 2 of 3");
        assert_eq!(idx.pending_update_records(), 2, "still buffered");
        assert_eq!(idx.update_batching().1, t0, "no train left yet");
        let _ = idx.take_control_traffic();
        assert!(
            idx.update_batching().1 > t0,
            "the third harvest flushes the aged batch"
        );
        assert_eq!(idx.pending_update_records(), 0);
        assert!(idx.take_control_traffic().is_zero(), "drained");

        // Size threshold 2: the record that fills the buffer flushes
        // inline, at queue time, however young the batch is.
        idx.set_flush_policy(2, 1000);
        DataIndex::insert(&mut idx, ObjectId(3), 2);
        assert_eq!(
            idx.take_control_traffic().update_msgs,
            0,
            "one record stays under both thresholds"
        );
        let (_, t1) = idx.update_batching();
        DataIndex::insert(&mut idx, ObjectId(4), 3);
        assert_eq!(idx.pending_update_records(), 0, "second record filled the buffer");
        assert!(
            idx.update_batching().1 > t1,
            "the full buffer flushed inline, not at a harvest"
        );
    }

    #[test]
    fn central_has_no_control_plane() {
        let mut idx = CentralIndex::new();
        DataIndex::insert(&mut idx, ObjectId(1), 0);
        let _ = DataIndex::lookup_cost(&idx, ObjectId(1));
        assert!(DataIndex::take_control_traffic(&mut idx).is_zero());
    }

    #[test]
    fn zero_cost_model_is_free_but_still_routes() {
        let zero = DhtModel {
            hop_latency_s: 0.0,
            proc_s: 0.0,
        };
        let idx = ChordIndex::with_nodes(32, zero, 3);
        let c = idx.lookup_cost(ObjectId(77));
        assert_eq!(c.latency_s, 0.0);
        assert_eq!(c.lookups, 1);
    }
}
