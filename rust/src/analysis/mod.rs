//! Figure/table regeneration harness.
//!
//! * [`model`] — the analytic "Model (local disk)" / "Model (persistent
//!   storage)" envelope lines the paper plots alongside measurements.
//! * [`figures`] — one runner per evaluation figure; each returns plain
//!   row structs that the `cargo bench` targets print and write as CSV
//!   under `results/`.

pub mod figures;
pub mod model;
